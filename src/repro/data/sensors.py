"""Synthetic sensor-data generators standing in for MHEALTH/PAMAP2/CWRU.

The real datasets are not shipped in this offline container, so we generate
signal families with the same *structure* the paper exploits:

* **HAR** (MHEALTH-like): each activity class is a characteristic mixture of
  body-motion harmonics per IMU channel (class-specific fundamental +
  harmonics + per-instance phase/amplitude jitter + sensor noise + gravity
  drift).  Within-class instances are highly correlated (the premise of the
  paper's memoization, §3.2.1) while classes are separable by a small CNN.

* **Bearing fault** (CWRU-like): rotation fundamental + fault-type-specific
  impulse trains (inner/outer race, ball defects at characteristic
  frequencies) + load-dependent noise — sampled faster, needing wider
  windows and more clusters (paper A.2).

All generators are pure functions of a PRNG key: fully deterministic,
restart-safe (the fault-tolerance property the data pipeline needs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["har_window", "har_stream", "har_dataset", "bearing_window",
           "bearing_stream", "bearing_dataset", "class_signatures"]


def _class_params(n_classes: int, channels: int, t: int):
    """Deterministic per-class structure: a *shared* dominant gait component
    plus class-specific APERIODIC transients (one or two localized impact
    events at class-coded positions with class-coded widths and channel
    signs).  As in real IMU data (heel strikes, impacts), the class identity
    is timing/geometry-borne: a single localized event spreads across the
    whole spectrum, so spectral top-m compression — which keeps the shared
    dominant harmonics — destroys it, while geometry-preserving coresets
    keep the event points (the paper's Table-1 phenomenon)."""
    k = jax.random.PRNGKey(1234)
    k1, k3, k4 = jax.random.split(k, 3)
    lo, hi = int(0.10 * t), int(0.90 * t)
    # three weak events per class at class-coded positions
    pos = jnp.round(lo + (hi - lo)
                    * jax.random.uniform(k1, (n_classes, 3)))      # (L, 3)
    width = 0.8 + 1.2 * jax.random.uniform(k3, (n_classes, 3))
    amp = 0.45 + 0.25 * jax.random.uniform(k4, (n_classes, 3, channels))
    sign = jnp.sign(jax.random.normal(jax.random.fold_in(k4, 1),
                                      (n_classes, 3, channels)))
    return pos, width, amp * sign


def har_window(key: jax.Array, label: jnp.ndarray, t: int = 60,
               channels: int = 3, n_classes: int = 12, fs: float = 50.0,
               noise: float = 0.12) -> jnp.ndarray:
    """One (T, C) window of the given activity class."""
    pos, width, amp = _class_params(n_classes, channels, t)
    kp, kn, ka, kj = jax.random.split(key, 4)
    tgrid = jnp.arange(t) / fs
    idx = jnp.arange(t, dtype=jnp.float32)

    # shared dominant gait component: a RICH quasi-periodic spectrum
    # (identical for every class, instance-jittered phases) — real IMU gait
    # occupies many strong harmonics, which is exactly what top-m spectral
    # compression keeps, leaving no coefficient budget for the weak
    # class-coded transients
    n_harm = 14
    hfreq = 0.8 * (1 + jnp.arange(n_harm, dtype=jnp.float32) * 0.72)  # <9 Hz
    hamp = 1.0 / (1.0 + 0.28 * jnp.arange(n_harm, dtype=jnp.float32))
    hphase = (2.3 * jnp.arange(n_harm)[:, None]
              + 0.35 * jax.random.normal(kp, (n_harm, channels)))
    base = jnp.sum(hamp[None, :, None]
                   * jnp.sin(2 * jnp.pi * hfreq[None, :, None]
                             * tgrid[:, None, None] + hphase[None]),
                   axis=1) / 2.0                        # (T, C)

    # three weak class-coded transient events (aperiodic; +-1 sample jitter):
    # individually below the shared component's spectral floor, jointly
    # decisive for a matched-filter classifier
    jit = jax.random.randint(kj, (3,), -1, 2).astype(jnp.float32)
    amp_jit = 1.0 + 0.15 * jax.random.normal(ka, (channels,))
    sig = base
    for e in range(3):
        ev = jnp.exp(-0.5 * ((idx - pos[label, e] - jit[e])
                             / width[label, e]) ** 2)
        sig = sig + ev[:, None] * amp[label, e] * amp_jit
    return sig + noise * jax.random.normal(kn, (t, channels))


def har_stream(key: jax.Array, n: int, t: int = 60, channels: int = 3,
               n_classes: int = 12, dwell: int = 8):
    """A stream of n windows with temporally-continuous activities (the
    paper's AAC premise): labels change only every ~``dwell`` windows.
    Returns (windows (n, T, C), labels (n,))."""
    kl, kw = jax.random.split(key)
    n_segments = (n + dwell - 1) // dwell
    seg_labels = jax.random.randint(kl, (n_segments,), 0, n_classes)
    labels = jnp.repeat(seg_labels, dwell)[:n]
    keys = jax.random.split(kw, n)
    windows = jax.vmap(
        lambda k, l: har_window(k, l, t, channels, n_classes))(keys, labels)
    return windows, labels


def har_dataset(key: jax.Array, n: int, t: int = 60, channels: int = 3,
                n_classes: int = 12):
    """IID windows for classifier training. Returns (windows, labels)."""
    kl, kw = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    keys = jax.random.split(kw, n)
    windows = jax.vmap(
        lambda k, l: har_window(k, l, t, channels, n_classes))(keys, labels)
    return windows, labels


def class_signatures(t: int = 60, channels: int = 3,
                     n_classes: int = 12) -> jnp.ndarray:
    """Noise-free per-class ground-truth traces — the memoization bank the
    sensor stores (paper Fig. 8 step 1a)."""
    keys = jax.random.split(jax.random.PRNGKey(7), n_classes)
    return jnp.stack([
        har_window(keys[c], jnp.asarray(c), t, channels, n_classes, noise=0.0)
        for c in range(n_classes)])


# ---------------------------------------------------------------------------
# Bearing fault (CWRU-like)
# ---------------------------------------------------------------------------

_FAULT_FREQ = jnp.asarray([0.0, 3.585, 5.415, 4.7135, 3.585, 5.415, 4.7135,
                           3.585, 5.415, 4.7135])  # xRPM defect multipliers
_FAULT_SEV = jnp.asarray([0.0, 0.6, 0.6, 0.6, 1.2, 1.2, 1.2, 2.0, 2.0, 2.0])


def bearing_window(key: jax.Array, label: jnp.ndarray, t: int = 120,
                   rpm_hz: float = 15.0, fs: float = 1200.0,
                   noise: float = 0.15) -> jnp.ndarray:
    """(T, 1) vibration window: class 0 = healthy, 1-9 = fault type x severity.

    Defect frequencies follow the CWRU characteristic multipliers (BPFI/BPFO/
    BSF); impulse trains are a few samples wide so a 120-sample window holds
    ~4-7 defect strikes — resolvable by both the classifier and a 15-20
    cluster coreset (paper A.2)."""
    kp, kn, kj = jax.random.split(key, 3)
    tgrid = jnp.arange(t) / fs
    phase = jax.random.uniform(kp, maxval=2 * jnp.pi)
    base = (jnp.sin(2 * jnp.pi * rpm_hz * tgrid + phase)
            + 0.3 * jnp.sin(2 * jnp.pi * 2 * rpm_hz * tgrid + 1.7 * phase))
    f_def = _FAULT_FREQ[label] * rpm_hz
    sev = _FAULT_SEV[label]
    jitter = 1.0 + 0.05 * jax.random.normal(kj, ())
    impulses = sev * jnp.cos(jnp.pi * f_def * jitter * tgrid + phase) ** 4
    ring = sev * 0.4 * jnp.sin(2 * jnp.pi * 5.1 * rpm_hz * tgrid) * impulses
    sig = base + impulses + ring + noise * jax.random.normal(kn, (t,))
    return sig[:, None]


def bearing_stream(key: jax.Array, n: int, t: int = 120, n_classes: int = 10,
                   dwell: int = 16):
    kl, kw = jax.random.split(key)
    n_segments = (n + dwell - 1) // dwell
    seg_labels = jax.random.randint(kl, (n_segments,), 0, n_classes)
    labels = jnp.repeat(seg_labels, dwell)[:n]
    keys = jax.random.split(kw, n)
    windows = jax.vmap(lambda k, l: bearing_window(k, l, t))(keys, labels)
    return windows, labels


def bearing_dataset(key: jax.Array, n: int, t: int = 120, n_classes: int = 10):
    kl, kw = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    keys = jax.random.split(kw, n)
    windows = jax.vmap(lambda k, l: bearing_window(k, l, t))(keys, labels)
    return windows, labels
