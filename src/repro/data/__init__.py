from .sensors import (  # noqa: F401
    har_stream, bearing_stream, har_dataset, bearing_dataset, class_signatures,
)
from .lm import lm_batches, LMTask  # noqa: F401
