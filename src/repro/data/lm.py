"""Deterministic synthetic LM token pipeline.

A fixed "corpus" of template documents (Zipf-distributed tokens with strong
local n-gram structure) is generated from a seed; batches are pure functions
of (seed, step) — the restart-safety property the fault-tolerant trainer
relies on: after checkpoint restore at step k, batch k+1 is identical to the
one the crashed run would have seen.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["LMTask", "lm_batches"]


@dataclasses.dataclass(frozen=True)
class LMTask:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    n_templates: int = 64
    template_len: int = 256


def _templates(task: LMTask) -> jnp.ndarray:
    """(n_templates, template_len) Zipf-ish token sequences with bigram
    structure a small model can actually learn."""
    key = jax.random.PRNGKey(task.seed)
    k1, k2 = jax.random.split(key)
    # Zipf marginal
    ranks = jnp.arange(1, task.vocab + 1)
    probs = 1.0 / ranks
    probs = probs / probs.sum()
    base = jax.random.choice(k1, task.vocab,
                             (task.n_templates, task.template_len), p=probs)
    # bigram smoothing: every odd position strongly depends on its neighbour
    shifted = (base + 1) % task.vocab
    mask = (jnp.arange(task.template_len) % 2).astype(bool)
    det = jnp.where(mask[None, :], jnp.roll(shifted, 1, axis=1), base)
    noise = jax.random.bernoulli(k2, 0.05, det.shape)
    rand = jax.random.randint(k2, det.shape, 0, task.vocab)
    return jnp.where(noise, rand, det)


def lm_batches(task: LMTask, step: jnp.ndarray | int) -> dict:
    """Batch for ``step``: {tokens (B, S+1)} — callers slice inputs/labels."""
    tmpl = _templates(task)
    key = jax.random.fold_in(jax.random.PRNGKey(task.seed + 1), step)
    kt, ko = jax.random.split(key)
    n_chunks = (task.seq_len + 1 + task.template_len - 1) // task.template_len
    idx = jax.random.randint(kt, (task.batch, n_chunks), 0, task.n_templates)
    seq = tmpl[idx].reshape(task.batch, -1)[:, :task.seq_len + 1]
    offset = jax.random.randint(ko, (task.batch, 1), 0, task.vocab)
    seq = (seq + offset * 0) % task.vocab        # keep deterministic+simple
    return {"tokens": seq.astype(jnp.int32)}
