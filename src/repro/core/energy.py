"""Energy-harvesting model: sources, storage, prediction (paper §2, §4.1).

Models the EH node end to end:

* **Harvest traces** for the paper's source modalities (RF, WiFi, piezo /
  body-movement, solar) — synthetic but calibrated to the orders of magnitude
  the paper cites (harvested sources deliver "scant microwatts" to milliwatts;
  Fig. 1b).  Real deployments would substitute measured traces (the paper uses
  traces from ResiRCA and Bonito); the interface is identical: energy (µJ) per
  scheduling slot.

* **Supercapacitor storage** with charge inefficiency — harvested energy is
  "used directly ... rather than stored for some distant future use".

* **Moving-average power predictor** (paper Fig. 8, step 2a — same predictor
  as Origin [47]).

* **Per-action energy costs** from the paper's Table 2 (µJ): the D0–D4
  strategy ladder.

Everything is jnp-based so the whole EH-WSN simulation can run inside a
single ``lax.scan`` over time slots.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "EnergyCosts", "TABLE2_COSTS", "BEARING_COST_SCALE", "D5_RAW",
    "harvest_trace", "EH_SOURCES",
    "fleet_source_assignment", "fleet_harvest_traces", "supercap_step",
    "supercap_step_direct", "SUPERCAP_CAP_UJ", "SUPERCAP_CHARGE_EFF",
    "BrownoutConfig", "fleet_phase_offsets", "fleet_alive_traces",
    "PredictorState", "predictor_init", "predictor_update",
    "predictor_forecast",
]


# Table 2's sixth row is the raw-transmission BASELINE, not a scheduler
# decision: ``EnergyCosts.total(D5_RAW)`` is the 70.16 µJ raw offload, while
# decision *code* 5 is ``repro.core.decision.DEFER`` (sensing only).  The two
# tables used to disagree silently; keep the indices distinct by name.
D5_RAW = 5


@dataclasses.dataclass(frozen=True)
class EnergyCosts:
    """µJ per action — paper Table 2 (sensor column + comm column).

    ``sense``       : pre-inference cost shared by every decision (correlation
                      engine ≈ D0's sensor energy).
    ``dnn16``/``dnn12``: quantized on-node inference (D1 uses the full DNN).
    ``coreset_cluster``/``coreset_sampling``: construction cost (D3/D4).
    ``tx_result``   : transmit a classification result (8.27 µJ).
    ``tx_coreset``  : transmit a coreset payload (15.97 µJ).
    ``tx_raw``      : transmit the raw 240 B window (70.16 µJ).
    ``aux_head``    : the intermittent lane's early-exit auxiliary head — a
                      single (pooled-activation x n_classes) matmul, priced
                      at its MAC share of the quantized DNN.
    ``stage_split`` : fraction of the quantized-DNN energy spent by each of
                      the three inference stages (conv1→pool, conv2→pool,
                      dense+head), from the MAC counts of the default
                      :class:`repro.models.har.HARConfig` (28 800 / 307 200 /
                      124 416 MACs); :meth:`stage_costs` normalizes, so the
                      tuple only has to be *proportional*.
    """

    sense: float = 0.54
    dnn_full: float = 29.23
    dnn16: float = 16.58
    dnn12: float = 9.95          # interpolated: 12/16 of dnn16's dynamic part
    coreset_cluster: float = 1.07
    coreset_sampling: float = 0.87
    tx_result: float = 8.27
    tx_coreset: float = 15.97
    tx_raw: float = 70.16
    aux_head: float = 0.41
    stage_split: tuple[float, float, float] = (0.0626, 0.6672, 0.2702)

    def __post_init__(self):
        if len(self.stage_split) != 3 or min(self.stage_split) <= 0.0:
            raise ValueError(
                f"stage_split must be 3 positive per-stage fractions, got "
                f"{self.stage_split}")

    def decision_costs(self) -> tuple[float, ...]:
        """(9,) µJ per DECISION code D0..D4 + DEFER + the intermittent lane's
        D6/D7/D8 — the single cost table.

        Both :meth:`total` (Table 2 row totals) and
        :func:`repro.core.decision.decision_energy` derive from this tuple,
        so the scheduler's affordability gates and the reported Table 2
        ladder can no longer disagree (they used to: ``total`` dropped
        ``sense`` from the D3/D4 rows).

        Rows 6-8 are the FIXED per-slot part of the intermittent decisions
        (see docs/ENERGY_MODEL.md): the stages actually executed in the slot
        add :meth:`stage_costs` entries on top, so unlike D0-D5 these rows
        are a floor, not the whole spend.
        """
        return (
            self.sense + self.tx_result,                        # D0 memoize
            self.dnn_full + self.tx_result,                     # D1 full DNN
            self.dnn16 + self.tx_result,                        # D2 quantized
            self.sense + self.coreset_cluster + self.tx_coreset,   # D3
            self.sense + self.coreset_sampling + self.tx_coreset,  # D4
            self.sense,                                         # DEFER
            self.sense,                                         # D6 partial
            self.sense + self.aux_head + self.tx_result,        # D7 early exit
            self.sense + self.tx_result,                        # D8 staged full
        )

    def stage_costs(self, quant_bits: int = 16) -> tuple[float, float, float]:
        """(3,) µJ per inference stage of the intermittent lane, summing to
        the quantized-DNN energy at ``quant_bits`` (``dnn16``/``dnn12``):
        running all three stages — in one slot or across brown-outs — costs
        exactly one on-node quantized inference (D2's compute part)."""
        base = {16: self.dnn16, 12: self.dnn12}.get(quant_bits, self.dnn16)
        tot = sum(self.stage_split)
        return tuple(base * f / tot for f in self.stage_split)

    def total(self, row: int) -> float:
        """Total µJ of paper Table 2 rows: 0..4 = D0..D4 (identical to the
        decision ladder), row :data:`D5_RAW` = raw offload.

        Row 5 here is the raw-transmission baseline — NOT decision code 5
        (``repro.core.decision.DEFER``); DEFER's sensing-only cost is
        ``decision_costs()[DEFER]``.
        """
        return (self.decision_costs()[:5] + (self.tx_raw,))[row]


TABLE2_COSTS = EnergyCosts()

# Heterogeneous-fleet cost scale for bearing-vibration monitors relative to
# the HAR wearable ladder above.  Table 2 prices a 50 Hz / 3-channel IMU
# window; a predictive-maintenance node samples vibration at kHz rates, so
# every stage of its ladder (sensing front-end, MACs over the longer window,
# payload bytes on the wire) costs proportionally more per scheduling slot.
# 1.5x is the ratio of the bearing window's MAC count to HAR's once the
# stream is resampled onto the shared (T, C) grid the mixed fleet runs —
# deliberately a single scalar on the WHOLE ladder so the decision structure
# (which rung is affordable when) is preserved, only shifted.
BEARING_COST_SCALE = 1.5


# ---------------------------------------------------------------------------
# Harvest traces (µJ per slot).  Orders of magnitude follow Fig. 1b: RF/WiFi
# harvest µW-level, piezo/body-movement mW bursts, solar mW with diurnal and
# occlusion structure.  One "slot" is one sensing window (paper: 60 samples at
# 50 Hz with 30 overlap => 0.6 s).
# ---------------------------------------------------------------------------

SLOT_SECONDS = 0.6


def _bursty(key: jax.Array, n: int, mean_power_uw: float, burstiness: float,
            period: float) -> jnp.ndarray:
    """Log-normal modulated sinusoid: fickle income with occasional droughts."""
    k1, k2 = jax.random.split(key)
    t = jnp.arange(n) * SLOT_SECONDS
    base = 0.5 * (1.0 + jnp.sin(2 * jnp.pi * t / period))
    noise = jnp.exp(burstiness * jax.random.normal(k1, (n,)) - 0.5 * burstiness ** 2)
    dropout = (jax.random.uniform(k2, (n,)) > 0.15).astype(jnp.float32)
    power = mean_power_uw * base * noise * dropout          # µW
    return power * SLOT_SECONDS                             # µJ per slot


EH_SOURCES = ("rf", "wifi", "piezo", "solar")


def harvest_trace(key: jax.Array, n: int, source: str = "rf") -> jnp.ndarray:
    """µJ harvested in each of ``n`` slots for a named source modality."""
    if source == "rf":
        return _bursty(key, n, mean_power_uw=45.0, burstiness=0.9, period=40.0)
    if source == "wifi":
        return _bursty(key, n, mean_power_uw=70.0, burstiness=1.2, period=15.0)
    if source == "piezo":
        # body movement: strong while active, near-zero at rest
        k1, k2 = jax.random.split(key)
        active = (jax.random.uniform(k1, (n,)) > 0.35).astype(jnp.float32)
        jitter = 1.0 + 0.3 * jax.random.normal(k2, (n,))
        return jnp.maximum(250.0 * active * jitter, 0.0) * SLOT_SECONDS
    if source == "solar":
        k1, _ = jax.random.split(key)
        t = jnp.arange(n) * SLOT_SECONDS
        diurnal = jnp.maximum(jnp.sin(2 * jnp.pi * t / (n * SLOT_SECONDS)), 0.0)
        clouds = 0.6 + 0.4 * jax.random.uniform(k1, (n,))
        return 800.0 * diurnal * clouds * SLOT_SECONDS
    raise ValueError(f"unknown EH source {source!r}; options: {EH_SOURCES}")


def fleet_source_assignment(n_nodes: int, sources=EH_SOURCES):
    """Node -> harvest-modality index for a fleet: round-robin over
    ``sources``.  The single source of truth for which node draws which
    modality (``fleet_harvest_traces`` generates with it; reporting code
    groups by it)."""
    import numpy as np

    return np.arange(n_nodes) % len(tuple(sources))


def fleet_harvest_traces(key: jax.Array, n_nodes: int, n_slots: int,
                         sources=EH_SOURCES) -> jnp.ndarray:
    """(N, S) heterogeneous per-node harvest: node ``i`` draws the modality
    :func:`fleet_source_assignment` gives it, with its own key fold, so no
    two nodes see the same income — the fleet-simulation analogue of a
    deployment where every wearable sits in a different energy environment."""
    import numpy as np

    sources = tuple(sources)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_nodes))
    out = jnp.zeros((n_nodes, n_slots), jnp.float32)
    node_src = fleet_source_assignment(n_nodes, sources)
    for si, src in enumerate(sources):
        sel = np.nonzero(node_src == si)[0]
        if sel.size == 0:
            continue
        traces = jax.vmap(lambda k: harvest_trace(k, n_slots, src))(keys[sel])
        out = out.at[sel].set(traces)
    return out


# ---------------------------------------------------------------------------
# Node churn: dropout/rejoin alive traces (intermittent execution)
# ---------------------------------------------------------------------------
#
# Harvested deployments are intermittent by construction: a node runs while
# its supercapacitor allows and browns out otherwise (Gobieski et al.,
# arXiv:1810.07751; Islam et al.'s energy-adaptive intermittent inference).
# The fleet engine models this as a per-node boolean *alive trace*: a
# duty-cycled square wave with a per-node activity phase offset (no two
# nodes wake in sync) plus random per-slot glitches (brown-outs mid-burst).
# Seeded exactly like ``fleet_harvest_traces``: node ``i`` draws from
# ``fold_in(key, i)``, so traces are reproducible and extendable per node.

def fleet_phase_offsets(key: jax.Array, n_nodes: int,
                        period: int = 16) -> jnp.ndarray:
    """(N,) int32 per-node activity phase offsets in ``[0, period)``.

    The single source of truth for where each node sits in its duty cycle —
    :func:`fleet_alive_traces` consumes these, and reporting code can group
    nodes by wake phase the same way ``fleet_source_assignment`` groups by
    harvest modality."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_nodes))
    return jax.vmap(
        lambda k: jax.random.randint(jax.random.fold_in(k, 0), (), 0, period)
    )(keys).astype(jnp.int32)


def fleet_alive_traces(key: jax.Array, n_nodes: int, n_slots: int, *,
                       duty: float = 0.75, period: int = 16,
                       p_glitch: float = 0.05) -> jnp.ndarray:
    """(N, S) bool — per-node dropout/rejoin process for a churny fleet.

    Node ``i`` is up while its phase-offset duty cycle says so
    (``(t + phase_i) % period < duty * period``) and it doesn't glitch
    (an independent per-slot brown-out with probability ``p_glitch``).
    ``duty=1.0, p_glitch=0.0`` yields the all-True trace — the fixed,
    always-registered fleet the engine simulated before churn existed —
    which the equivalence tests pin bitwise against the churn-free path.
    """
    if not 0.0 <= duty <= 1.0:
        raise ValueError(f"duty must be in [0, 1], got {duty}")
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_nodes))
    phases = fleet_phase_offsets(key, n_nodes, period)
    t = jnp.arange(n_slots, dtype=jnp.int32)
    on = ((t[None, :] + phases[:, None]) % period
          < jnp.asarray(duty * period, jnp.float32))           # (N, S)
    glitch = jax.vmap(
        lambda k: jax.random.uniform(jax.random.fold_in(k, 1), (n_slots,))
        < p_glitch)(keys)
    return on & ~glitch


# ---------------------------------------------------------------------------
# Supercap storage
# ---------------------------------------------------------------------------

SUPERCAP_CAP_UJ = 200.0       # hard storage capacity
SUPERCAP_CHARGE_EFF = 0.8     # charging inefficiency on energy that is stored


def supercap_step(stored_uj: jnp.ndarray, harvested_uj: jnp.ndarray,
                  spent_uj: jnp.ndarray, cap_uj: float = SUPERCAP_CAP_UJ,
                  charge_eff: float = SUPERCAP_CHARGE_EFF) -> jnp.ndarray:
    """One storage update: lossy charging, hard capacity, floor at 0.

    NOTE: the zero floor silently forgives debt — a caller that spends more
    than ``stored + charge_eff * harvested`` executes on energy that never
    existed.  The legacy decision ladder does exactly that (it budgets
    against the *forecast*); :func:`supercap_step_direct` plus the strict
    mode of :func:`repro.core.decision.choose_decision` is the debt-free
    accounting the brown-out lane uses.
    """
    return jnp.clip(stored_uj + charge_eff * harvested_uj - spent_uj, 0.0, cap_uj)


def supercap_step_direct(stored_uj: jnp.ndarray, harvested_uj: jnp.ndarray,
                         spent_uj: jnp.ndarray,
                         cap_uj: float = SUPERCAP_CAP_UJ,
                         charge_eff: float = SUPERCAP_CHARGE_EFF
                         ) -> jnp.ndarray:
    """Store-and-execute storage update (paper §2's ERR: harvested energy is
    "used directly ... rather than stored").

    Energy spent in the slot it was harvested bypasses the charging loss;
    only the *surplus* pays ``charge_eff`` on its way into the supercap, and
    any deficit draws on ``stored``.  Whenever the caller keeps
    ``spent <= stored + harvested`` (the strict decision mode guarantees
    it), the zero floor never engages — debt cannot be clip-forgiven.
    """
    direct = jnp.minimum(spent_uj, harvested_uj)
    return jnp.clip(stored_uj + charge_eff * (harvested_uj - direct)
                    - (spent_uj - direct), 0.0, cap_uj)


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    """Supercapacitor brown-out hysteresis (µJ) — endogenous churn.

    A node whose post-slot charge falls below ``off_uj`` browns out: its MCU
    powers down, the whole node carry (predictor, AAC continuity, PRNG
    stream) freezes, and it emits DEFER with a zero payload.  The harvester
    keeps trickle-charging the supercap while the node is down; once the
    charge recovers to at least ``restart_uj`` the node reboots into its
    frozen state.  ``off_uj < restart_uj`` is the hysteresis band that stops
    a node on the threshold from oscillating every slot (Gobieski et al.,
    arXiv:1810.07751; Islam et al., arXiv:2503.06663).

    Frozen + hashable so the fleet engines can key their compile caches on
    it like the cost table.
    """

    off_uj: float = 5.0
    restart_uj: float = 25.0

    def __post_init__(self):
        if not 0.0 <= self.off_uj <= self.restart_uj:
            raise ValueError(
                f"BrownoutConfig needs 0 <= off_uj <= restart_uj, got "
                f"off_uj={self.off_uj}, restart_uj={self.restart_uj}")


# ---------------------------------------------------------------------------
# Moving-average power predictor (paper Fig. 8 step 2a; same as Origin [47])
# ---------------------------------------------------------------------------

class PredictorState(NamedTuple):
    history: jnp.ndarray   # (W,) or (N, W) ring buffer of recent harvest (µJ/slot)
    pos: jnp.ndarray       # () or (N,) int32 write cursor


def predictor_init(window: int = 8, batch: int | None = None) -> PredictorState:
    """Scalar-node state by default; ``batch=N`` builds the stacked per-node
    state the fleet engine carries through its scan."""
    if batch is None:
        return PredictorState(history=jnp.zeros((window,)),
                              pos=jnp.zeros((), jnp.int32))
    return PredictorState(history=jnp.zeros((batch, window)),
                          pos=jnp.zeros((batch,), jnp.int32))


def predictor_update(state: PredictorState, harvested_uj: jnp.ndarray) -> PredictorState:
    """Ring-buffer write; works on scalar (W,) and batched (N, W) states."""
    w = state.history.shape[-1]
    if state.history.ndim == 1:
        history = state.history.at[state.pos % w].set(harvested_uj)
    else:
        n = state.history.shape[0]
        history = state.history.at[jnp.arange(n), state.pos % w].set(harvested_uj)
    return PredictorState(history=history, pos=state.pos + 1)


def predictor_forecast(state: PredictorState, horizon_slots: int = 1) -> jnp.ndarray:
    """Expected µJ income over the next ``horizon_slots`` slots.  Returns ()
    for a scalar state, (N,) for a batched one."""
    w = state.history.shape[-1]
    filled = jnp.minimum(state.pos, w).astype(jnp.float32)
    mean = jnp.sum(state.history, axis=-1) / jnp.maximum(filled, 1.0)
    return mean * horizon_slots
