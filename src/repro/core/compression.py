"""Coreset codecs for distributed collectives — Seeker's C1–C3 mapped to TPU.

The paper compresses the sensor→host radio payload with coresets; on a TPU
fleet the scarce link is ICI, and the two dominant payloads are

* **data-parallel gradient reductions** (training), and
* **edge-tier → host-tier activation transfers** (disaggregated serving,
  the literal D3/D4 offload path).

Two codecs, direct images of the paper's two constructions:

* :func:`topk_compress` — *importance sampling*: keep the k largest-magnitude
  entries (importance ∝ |g|), ship ``(value, index)`` pairs, accumulate what
  was dropped into an **error-feedback** residual (the unbiased-estimator role
  the paper's Horvitz-Thompson weights play).

* :func:`kmeans1d` — *clustering*: a 1-D k-means codebook over tensor values;
  the wire format is the paper's ``(center, radius, count)`` triple per
  cluster plus a 4-bit code per element.  Recovery can optionally re-dither
  uniformly within each cluster radius — the 2r-approximation of §3.2.2.

:func:`coreset_allreduce` runs inside ``shard_map``: compress locally,
``all_gather`` the compact payload over the reduction axes, decompress + sum.
Wire-byte accounting (:func:`wire_bytes_dense_psum` vs
:func:`wire_bytes_topk_allgather`) feeds the roofline collective term.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "CompressionConfig", "topk_compress", "topk_decompress",
    "topk_block_compress", "topk_block_decompress", "kmeans1d",
    "kmeans1d_decompress", "Kmeans1dCoreset", "coreset_allreduce",
    "compress_activation", "decompress_activation",
    "wire_bytes_dense_psum", "wire_bytes_topk_allgather",
    "wire_bytes_kmeans1d",
]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    method: str = "topk"              # "topk" | "topk_block" | "none"
    topk_ratio: float = 1.0 / 64.0    # fraction of entries kept
    block: int = 32768                # topk_block span (int16 offsets)
    kmeans_k: int = 16                # codebook size (4-bit codes)
    kmeans_iters: int = 4             # paper's fixed Lloyd budget
    error_feedback: bool = True
    min_size: int = 2048              # leaves smaller than this go uncompressed


# ---------------------------------------------------------------------------
# Importance-sampling codec (top-k by magnitude + error feedback)
# ---------------------------------------------------------------------------

def topk_compress(flat: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(values, indices) of the k largest-|.| entries of a 1-D tensor."""
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_decompress(values: jnp.ndarray, indices: jnp.ndarray, n: int) -> jnp.ndarray:
    return jnp.zeros((n,), dtype=values.dtype).at[indices].add(values)


def topk_block_compress(flat: jnp.ndarray, ratio: float,
                        block: int = 32768) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-local top-k: keep k_b largest-|.| entries of every ``block``-span
    and address them with int16 *offsets* (block id is implicit in position).

    Wire cost per kept entry drops from 6 B (bf16 value + int32 index) to
    4 B (bf16 value + int16 offset) — a 1.5x payload cut that moves the
    compression-vs-dense crossover fan-in from ~85 to ~128 devices at 1/64
    sparsity (§Perf cell C iteration log).  Block-local selection is also
    what the paper's fixed-function sampler computes (per-window, not
    global).

    Returns (values (n_blocks, k_b) same-dtype, offsets (n_blocks, k_b)
    int16).  The tensor is zero-padded to a block multiple by the caller.
    """
    n = flat.size
    assert n % block == 0, (n, block)
    nb = n // block
    k_b = max(1, int(block * ratio))
    x = flat.reshape(nb, block)
    _, off = jax.lax.top_k(jnp.abs(x), k_b)                  # (nb, k_b)
    vals = jnp.take_along_axis(x, off, axis=1)
    return vals, off.astype(jnp.int16)


def topk_block_decompress(values: jnp.ndarray, offsets: jnp.ndarray,
                          n: int) -> jnp.ndarray:
    nb, k_b = values.shape
    block = n // nb
    out = jnp.zeros((nb, block), values.dtype)
    out = out.at[jnp.arange(nb)[:, None], offsets.astype(jnp.int32)].add(values)
    return out.reshape(n)


# ---------------------------------------------------------------------------
# Clustering codec (1-D k-means codebook = the paper's center/radius/count)
# ---------------------------------------------------------------------------

class Kmeans1dCoreset(NamedTuple):
    centers: jnp.ndarray   # (k,)
    radii: jnp.ndarray     # (k,)  max |x - center| per cluster
    counts: jnp.ndarray    # (k,)  int32
    codes: jnp.ndarray     # (N,)  int32 in [0, k) — 4 bits on the wire for k<=16


def kmeans1d(flat: jnp.ndarray, k: int = 16, iters: int = 4) -> Kmeans1dCoreset:
    """Fixed-budget 1-D Lloyd (sorted-centroid bucketing via searchsorted)."""
    lo = jnp.min(flat)
    hi = jnp.max(flat)
    centers0 = jnp.linspace(lo, hi, k).astype(flat.dtype)

    def lloyd(centers, _):
        mids = 0.5 * (centers[1:] + centers[:-1])
        codes = jnp.searchsorted(mids, flat)
        onehot = jax.nn.one_hot(codes, k, dtype=flat.dtype)
        counts = jnp.sum(onehot, axis=0)
        sums = onehot.T @ flat
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centers)
        return jnp.sort(new), None

    centers, _ = jax.lax.scan(lloyd, centers0, None, length=iters)
    mids = 0.5 * (centers[1:] + centers[:-1])
    codes = jnp.searchsorted(mids, flat).astype(jnp.int32)
    onehot = jax.nn.one_hot(codes, k, dtype=flat.dtype)
    counts = jnp.sum(onehot, axis=0).astype(jnp.int32)
    err = jnp.abs(flat - centers[codes])
    radii = jnp.max(onehot * err[:, None], axis=0)
    return Kmeans1dCoreset(centers=centers, radii=radii, counts=counts, codes=codes)


def kmeans1d_decompress(cs: Kmeans1dCoreset, key: jax.Array | None = None) -> jnp.ndarray:
    """codes -> values; with a key, dithers uniformly within each cluster
    radius (the paper's uniform-redistribution recovery)."""
    vals = cs.centers[cs.codes]
    if key is not None:
        u = jax.random.uniform(key, cs.codes.shape, minval=-1.0, maxval=1.0)
        vals = vals + u * cs.radii[cs.codes]
    return vals


# ---------------------------------------------------------------------------
# shard_map collective: compressed all-reduce over one or more mesh axes
# ---------------------------------------------------------------------------

def _leaf_allreduce_topk(g: jnp.ndarray, e: jnp.ndarray | None, axis_names,
                         cfg: CompressionConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.reshape(-1).astype(jnp.float32)
    if e is not None:
        flat = flat + e.reshape(-1)
    n = flat.size
    k = max(1, int(n * cfg.topk_ratio))
    vals, idx = topk_compress(flat, k)
    wire_vals = vals.astype(jnp.bfloat16)
    gathered_v = wire_vals
    gathered_i = idx
    for ax in axis_names:
        gathered_v = jax.lax.all_gather(gathered_v, ax).reshape(-1)
        gathered_i = jax.lax.all_gather(gathered_i, ax).reshape(-1)
    ndev = 1
    for ax in axis_names:
        ndev *= jax.lax.psum(1, ax)
    dense = jnp.zeros((n,), jnp.float32).at[gathered_i].add(
        gathered_v.astype(jnp.float32))
    mean = dense / ndev
    residual = flat - topk_decompress(wire_vals.astype(jnp.float32), idx, n)
    return mean.reshape(g.shape).astype(g.dtype), residual.reshape(g.shape)


def _leaf_allreduce_block(g: jnp.ndarray, e: jnp.ndarray | None, axis_names,
                          cfg: CompressionConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-local top-k variant: int16 offsets on the wire (4 B/entry)."""
    flat = g.reshape(-1).astype(jnp.float32)
    if e is not None:
        flat = flat + e.reshape(-1)
    n = flat.size
    block = min(cfg.block, n)
    pad = (-n) % block
    fp = jnp.pad(flat, (0, pad))
    vals, off = topk_block_compress(fp, cfg.topk_ratio, block)
    wire_vals = vals.astype(jnp.bfloat16)
    gv, go = wire_vals, off
    for ax in axis_names:
        gv = jax.lax.all_gather(gv, ax).reshape(-1, vals.shape[1])
        go = jax.lax.all_gather(go, ax).reshape(-1, off.shape[1])
    ndev = 1
    for ax in axis_names:
        ndev *= jax.lax.psum(1, ax)
    nb = fp.size // block
    # gathered rows cycle through the nb local blocks per device
    row_block = jnp.tile(jnp.arange(nb), gv.shape[0] // nb)
    idx = row_block[:, None] * block + go.astype(jnp.int32)
    dense = jnp.zeros((fp.size,), jnp.float32).at[idx.reshape(-1)].add(
        gv.reshape(-1).astype(jnp.float32))
    mean = dense[:n] / ndev
    local = topk_block_decompress(wire_vals.astype(jnp.float32), off, fp.size)
    residual = flat - local[:n]
    return mean.reshape(g.shape).astype(g.dtype), residual.reshape(g.shape)


def coreset_allreduce(grads, axis_names, cfg: CompressionConfig,
                      ef_state=None):
    """Compressed mean-all-reduce of a gradient pytree inside shard_map.

    Args:
        grads: local (per data-shard) gradient pytree.
        axis_names: tuple of mesh axis names to reduce over (("data",) or
            ("pod", "data")).
        cfg: codec config.
        ef_state: pytree like grads with the error-feedback residuals
            (pass None to disable / on step 0 use zeros).

    Returns (mean_grads, new_ef_state).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = (jax.tree_util.tree_flatten(ef_state)[0]
                 if ef_state is not None else [None] * len(leaves))
    out, new_ef = [], []
    for g, e in zip(leaves, ef_leaves):
        if cfg.method == "none" or g.size < cfg.min_size:
            m = g
            for ax in axis_names:
                m = jax.lax.pmean(m, ax)
            out.append(m)
            new_ef.append(jnp.zeros_like(g))
        elif cfg.method == "topk_block":
            m, r = _leaf_allreduce_block(g, e if cfg.error_feedback else None,
                                         axis_names, cfg)
            out.append(m)
            new_ef.append(r.astype(g.dtype))
        else:
            m, r = _leaf_allreduce_topk(g, e if cfg.error_feedback else None,
                                        axis_names, cfg)
            out.append(m)
            new_ef.append(r.astype(g.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_ef))


# ---------------------------------------------------------------------------
# Activation codec for the edge->host offload (D3 path, distributed)
# ---------------------------------------------------------------------------

def compress_activation(x: jnp.ndarray, cfg: CompressionConfig) -> Kmeans1dCoreset:
    """Clustering-coreset compression of an activation tensor (any shape)."""
    return kmeans1d(x.reshape(-1).astype(jnp.float32), cfg.kmeans_k, cfg.kmeans_iters)


def decompress_activation(cs: Kmeans1dCoreset, shape, dtype=jnp.float32,
                          key: jax.Array | None = None) -> jnp.ndarray:
    return kmeans1d_decompress(cs, key).reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Wire-byte accounting (feeds the roofline collective term)
# ---------------------------------------------------------------------------

def wire_bytes_dense_psum(n_elems: int, ndev: int, bytes_per_elem: int = 2) -> float:
    """Ring all-reduce moves ~2·(N/ndev)·(ndev-1) ≈ 2N bytes per device."""
    return 2.0 * n_elems * bytes_per_elem * (ndev - 1) / ndev


def wire_bytes_topk_allgather(n_elems: int, ndev: int, ratio: float,
                              bytes_val: int = 2, bytes_idx: int = 4) -> float:
    """All-gather of compressed payloads: each device receives
    (ndev-1)·k·(val+idx) bytes."""
    k = max(1, int(n_elems * ratio))
    return (ndev - 1) * k * (bytes_val + bytes_idx)


def wire_bytes_kmeans1d(n_elems: int, k: int = 16, bits_code: int = 4,
                        bytes_center: int = 2, bytes_radius: int = 1,
                        bits_count: int = 4) -> float:
    """Point-to-point transfer of a clustering-coreset payload."""
    return (n_elems * bits_code / 8.0
            + k * (bytes_center + bytes_radius)
            + k * bits_count / 8.0)
