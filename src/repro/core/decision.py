"""Seeker's energy-aware decision flow (paper §4.1, Fig. 8).

Per sensing window the node chooses one of:

====  =========================================================== ============
code  action                                                      paper
====  =========================================================== ============
0     D0 — memoization hit: transmit the label only               §3.2.1
1     D1 — full-precision DNN on-node, transmit result            Table 2
2     D2 — quantized (16/12-bit) DNN on-node, transmit result     §4
3     D3 — clustering coreset, offload; host recovers + infers    §3.2.2
4     D4 — sampling coreset, offload; host GAN-recovers + infers  §3.2.2/A.1
5     DEFER — not even D4 affordable: store-and-execute later     §2 (ERR)
6     D6 — intermittent: inference suspended mid-stage            2503.06663
7     D7 — intermittent: early exit from the auxiliary head       2503.06663
8     D8 — intermittent: staged inference completed, full depth   1810.07751
====  =========================================================== ============

Codes 6-8 are emitted by the *intermittent lane*
(:func:`repro.serving.edge_host.intermittent_lane_step`), never by
:func:`choose_decision` itself: the ladder walk is unchanged, and the lane
engages only on slots the ladder would DEFER (or while a staged inference is
already in flight).  See docs/ENERGY_MODEL.md.

The selector is a pure jnp function of (correlation, stored energy, forecast
income, costs) so it can run inside ``lax.scan`` over a trace; the *executor*
applies the chosen compute with ``lax.switch`` so all branches have a single
static shape.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .energy import EnergyCosts

__all__ = ["D0_MEMO", "D1_DNN_FULL", "D2_DNN_QUANT", "D3_CLUSTER", "D4_SAMPLING",
           "DEFER", "D6_PARTIAL", "D7_EARLY_EXIT", "D8_STAGED_FULL",
           "N_INTERMITTENT_DECISIONS", "IntermittentConfig",
           "DecisionOutcome", "choose_decision", "decision_energy"]

D0_MEMO = 0
D1_DNN_FULL = 1
D2_DNN_QUANT = 2
D3_CLUSTER = 3
D4_SAMPLING = 4
DEFER = 5
D6_PARTIAL = 6        # staged inference advanced/suspended, nothing on the wire
D7_EARLY_EXIT = 7     # confidence-tagged result from the auxiliary head
D8_STAGED_FULL = 8    # staged inference reached full depth and transmitted

N_INTERMITTENT_DECISIONS = D8_STAGED_FULL + 1   # histogram bins, lane enabled


@dataclasses.dataclass(frozen=True)
class IntermittentConfig:
    """Energy-adaptive intermittent-inference lane (Islam et al.,
    arXiv:2503.06663; Gobieski et al., arXiv:1810.07751).

    The lane engages on slots the ladder DEFERs (or while an inference is in
    flight), executes as many quantized-DNN stages as this slot's
    ``stored + harvested`` budget strictly affords, and suspends the staged
    activations in the scan carry across slots — and across brown-outs.

    ``min_exit_stage``: earliest completed stage (1 or 2) whose auxiliary
    head may emit an early-exit result when the remaining stages are
    unaffordable.
    ``exit_threshold``: minimum auxiliary-head confidence (max softmax) for
    an early exit; 0.0 exits whenever affordable, any value > 1.0 disables
    early exit entirely (the lane then only ever completes at full depth).

    Frozen + hashable so the fleet engines can key their compile caches on
    it like the cost table and :class:`repro.core.energy.BrownoutConfig`.
    """

    min_exit_stage: int = 1
    exit_threshold: float = 0.0

    def __post_init__(self):
        if self.min_exit_stage not in (1, 2):
            raise ValueError(
                f"min_exit_stage must be 1 or 2 (the stages with an "
                f"auxiliary head), got {self.min_exit_stage}")
        if not self.exit_threshold >= 0.0:
            raise ValueError(
                f"exit_threshold must be >= 0.0, got {self.exit_threshold}")


class DecisionOutcome(NamedTuple):
    decision: jnp.ndarray   # () int32 in [0, 5]
    spend: jnp.ndarray      # () float µJ this slot will consume


def decision_energy(costs: EnergyCosts) -> jnp.ndarray:
    """(9,) µJ cost vector indexed by decision code (DEFER costs only
    sensing; rows 6-8 are the intermittent lane's FIXED per-slot parts —
    executed stages add :meth:`EnergyCosts.stage_costs` on top).  Derived
    from :meth:`EnergyCosts.decision_costs` — the same table
    ``EnergyCosts.total`` reports, so the scheduler's gates and the Table 2
    ladder cannot drift apart again."""
    return jnp.asarray(costs.decision_costs(), dtype=jnp.float32)


def choose_decision(max_corr: jnp.ndarray, stored_uj: jnp.ndarray,
                    forecast_uj: jnp.ndarray, costs: EnergyCosts,
                    corr_threshold: float = 0.95,
                    allow_full_dnn: bool = False,
                    harvested_uj: jnp.ndarray | None = None,
                    cost_scale: jnp.ndarray | None = None
                    ) -> DecisionOutcome:
    """Fig. 8 walk: memo gate -> local DNN if affordable -> cluster coreset ->
    sampling coreset -> defer.

    ``allow_full_dnn`` mirrors the paper's deployment choice: the EH node
    normally runs only the quantized DNNs (D2); D1 exists for the fully
    powered baselines.

    ``harvested_uj`` switches on STRICT energy accounting (store-and-execute,
    paper §2): a decision must be payable from ``stored + harvested`` this
    slot alone — the forecast still ranks options upstream (it drives AAC's
    ``select_k``) but can no longer mint energy the node never harvested.
    The memo gate is energy-gated too (a hit the node cannot transmit is not
    a hit), and when not even DEFER's sensing cost is payable the spend
    clamps to zero — the state the fleet engines' brown-out lane turns into
    endogenous churn.  Without ``harvested_uj`` the legacy forecast-budget
    walk is bitwise unchanged.
    """
    strict = harvested_uj is not None
    budget = stored_uj + (harvested_uj if strict else forecast_uj)
    cost = decision_energy(costs)
    # heterogeneous fleets: scale the WHOLE ladder per task (a bearing
    # node's front-end pays BEARING_COST_SCALE per window); None leaves the
    # table untouched — identical jaxpr to the pre-lane scheduler
    if cost_scale is not None:
        cost = cost * cost_scale

    memo_hit = max_corr >= corr_threshold
    if strict:
        memo_hit = jnp.logical_and(memo_hit, budget >= cost[D0_MEMO])
    can_full = budget >= cost[D1_DNN_FULL]
    can_quant = budget >= cost[D2_DNN_QUANT]
    can_cluster = budget >= cost[D3_CLUSTER]
    can_sample = budget >= cost[D4_SAMPLING]

    dnn_choice = jnp.where(jnp.logical_and(allow_full_dnn, can_full),
                           D1_DNN_FULL, D2_DNN_QUANT)
    can_dnn = jnp.where(allow_full_dnn, jnp.logical_or(can_full, can_quant), can_quant)

    # prefer clustering over sampling when affordable (paper: "the former is
    # preferred, when possible")
    offload = jnp.where(can_cluster, D3_CLUSTER,
                        jnp.where(can_sample, D4_SAMPLING, DEFER))
    local = jnp.where(can_dnn, dnn_choice, offload)
    decision = jnp.where(memo_hit, D0_MEMO, local).astype(jnp.int32)
    spend = cost[decision]
    if strict:
        # every non-DEFER choice is gated affordable above; this clamp only
        # bites DEFER when the node cannot even pay for sensing
        spend = jnp.where(budget >= spend, spend, jnp.zeros_like(spend))
    return DecisionOutcome(decision=decision, spend=spend)
