"""Seeker's core contribution: coresets, recovery, memoization, energy model,
decision flow, and the distributed coreset codecs."""
from .coreset import (  # noqa: F401
    ClusterCoreset, SamplingCoreset, points_from_window, window_from_points,
    kmeans_coreset, importance_weights, importance_coreset,
    topk_importance_coreset, quantize_uniform, dequantize_uniform,
    encode_cluster_coreset, decode_cluster_coreset, raw_payload_bytes,
    cluster_payload_bytes, sampling_payload_bytes,
)
from .recovery import (  # noqa: F401
    recover_cluster_points, recover_cluster_window, GeneratorParams,
    init_generator, generator_apply, recover_sampling_window,
    init_discriminator, discriminator_apply,
)
from .memo import pearson, signature_correlations, memo_decision, MemoResult  # noqa: F401
from .energy import (  # noqa: F401
    EnergyCosts, TABLE2_COSTS, D5_RAW, harvest_trace, EH_SOURCES,
    fleet_source_assignment, fleet_harvest_traces, supercap_step,
    supercap_step_direct, SUPERCAP_CAP_UJ, SUPERCAP_CHARGE_EFF,
    BrownoutConfig, fleet_phase_offsets, fleet_alive_traces,
    PredictorState, predictor_init, predictor_update, predictor_forecast,
)
from .aac import AACTable, make_aac_table, select_k  # noqa: F401
from .decision import (  # noqa: F401
    D0_MEMO, D1_DNN_FULL, D2_DNN_QUANT, D3_CLUSTER, D4_SAMPLING, DEFER,
    D6_PARTIAL, D7_EARLY_EXIT, D8_STAGED_FULL, N_INTERMITTENT_DECISIONS,
    IntermittentConfig, DecisionOutcome, choose_decision, decision_energy,
)
from .compression import (  # noqa: F401
    CompressionConfig, topk_compress, topk_decompress, kmeans1d,
    kmeans1d_decompress, Kmeans1dCoreset, coreset_allreduce,
    compress_activation, decompress_activation,
)
