"""Activity-Aware Coreset construction (AAC — paper §5.2).

Not every activity needs the default 12 clusters: simple periodic activities
(walking, running) survive with as few as 8, complex ones need the full
budget.  Naively this is circular — you need the class to size the coreset
that detects the class — which the paper breaks with the *temporal
continuity* of human activity: the previously completed inference predicts
the current class.

Runtime structure:

* an offline-built **accuracy table** ``acc[class, k]`` (built by
  ``benchmarks/fig6_clusters.py``, analogous to paper Fig. 6),
* :func:`select_k` picks the smallest ``k`` whose predicted accuracy drop is
  within tolerance *and* whose construction+tx energy fits the budget —
  falling back to fewer clusters under energy pressure (paper: "if the system
  does not have enough energy to form the default 12 clusters, it will resort
  to forming a smaller number of clusters with minimum accuracy loss").

For the bearing-fault workload the paper tweaks AAC to be *energy-aware
only* (no class conditioning): pass ``class_aware=False``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .coreset import cluster_payload_bytes

__all__ = ["AACTable", "make_aac_table", "select_k", "aac_payload_bytes"]


class AACTable(NamedTuple):
    """``acc``: (n_classes, n_k) accuracy estimate per (class, k-index).
    ``ks``: (n_k,) the cluster counts the table indexes (ascending)."""

    acc: jnp.ndarray
    ks: jnp.ndarray


def make_aac_table(acc: jnp.ndarray, ks) -> AACTable:
    ks = jnp.asarray(ks, dtype=jnp.int32)
    assert acc.shape[-1] == ks.shape[0]
    return AACTable(acc=jnp.asarray(acc, jnp.float32), ks=ks)


def _cluster_energy_uj(k: jnp.ndarray, base_cost: float, tx_per_byte: float,
                       bytes_center: int = 2, bytes_radius: int = 1) -> jnp.ndarray:
    """Energy of building + transmitting a k-cluster coreset: construction is
    ~linear in k (the parallel engine does all clusters at once but reads all
    points per iteration), tx is linear in payload bytes."""
    payload = k.astype(jnp.float32) * (bytes_center + bytes_radius) + jnp.ceil(k / 2.0)
    return base_cost * k.astype(jnp.float32) / 12.0 + tx_per_byte * payload


def select_k(table: AACTable, pred_class: jnp.ndarray, energy_uj: jnp.ndarray,
             acc_tol: float = 0.02, base_cost: float = 1.07,
             tx_per_byte: float = 0.38, class_aware: bool = True) -> jnp.ndarray:
    """Pick the number of clusters for the *current* window.

    Args:
        table: offline accuracy table.
        pred_class: () int32 — previous inference's label (temporal continuity).
        energy_uj: () float — predicted available energy for this slot.
        acc_tol: acceptable accuracy drop vs the table's per-class max.
        class_aware: False = paper's bearing-fault variant (energy-only).

    Returns () int32: a value from ``table.ks`` (smallest acceptable; if none
    is affordable, the cheapest k — degrade rather than drop, paper §5.2).
    """
    if class_aware:
        row = table.acc[pred_class]                     # (n_k,)
    else:
        row = jnp.min(table.acc, axis=0)                # worst-class bound
    best = jnp.max(row)
    acc_ok = row >= best - acc_tol
    cost = _cluster_energy_uj(table.ks, base_cost, tx_per_byte)
    energy_ok = cost <= energy_uj
    ok = acc_ok & energy_ok
    # smallest acceptable k; fall back to the smallest k in the table
    idx = jnp.argmax(ok)                                 # first True (ks ascending)
    any_ok = jnp.any(ok)
    return jnp.where(any_ok, table.ks[idx], table.ks[0])


def aac_payload_bytes(ks: jnp.ndarray) -> jnp.ndarray:
    """Vectorized payload accounting for a trace of selected k values."""
    per_k = jnp.asarray([cluster_payload_bytes(int(k)) for k in ks])
    return per_k
