"""Classical lossy compression baselines (paper Table 1 / Fig 10 rivals).

Top-m coefficient selection in three transform domains, with the same wire
accounting as the coresets (1 B index + 2 B quantized value per kept
coefficient, per channel):

* DCT-II (orthonormal, via explicit basis matmul — T is tiny),
* Haar DWT (as many doubling levels as T admits),
* Fourier (rFFT; complex coefficients cost two values).

These are *context-blind*: the paper's point is that at iso-ratio they shred
the class-discriminative features of low-dimensional sensor data while
coresets preserve geometry (Table 1: 5-18% accuracy loss vs <=0.76%).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["dct_compress", "dwt_compress", "fourier_compress",
           "classical_payload_bytes"]


def _dct_basis(t: int) -> jnp.ndarray:
    n = jnp.arange(t)
    k = jnp.arange(t)[:, None]
    basis = jnp.cos(math.pi / t * (n[None, :] + 0.5) * k)
    scale = jnp.where(k == 0, jnp.sqrt(1.0 / t), jnp.sqrt(2.0 / t))
    return basis * scale                                   # (T, T) orthonormal


def _topm_reconstruct(coeffs: jnp.ndarray, m: int) -> jnp.ndarray:
    """Zero all but the m largest-|.| coefficients (per channel)."""
    mag = jnp.abs(coeffs)
    thresh = -jnp.sort(-mag, axis=0)[m - 1:m, :]
    return jnp.where(mag >= thresh, coeffs, 0.0)


def dct_compress(window: jnp.ndarray, m: int) -> jnp.ndarray:
    """(T, C) -> (T, C) reconstruction from m DCT coefficients/channel."""
    t = window.shape[0]
    B = _dct_basis(t)
    coeffs = B @ window                                    # (T, C)
    kept = _topm_reconstruct(coeffs, m)
    return B.T @ kept


def _haar_levels(t: int, max_levels: int = 8) -> int:
    lv = 0
    while t % 2 == 0 and lv < max_levels:
        t //= 2
        lv += 1
    return lv


def dwt_compress(window: jnp.ndarray, m: int) -> jnp.ndarray:
    """Haar DWT, top-m coefficients, inverse transform."""
    t, c = window.shape
    levels = max(_haar_levels(t), 1)
    s = window
    details = []
    for _ in range(levels):
        even, odd = s[0::2], s[1::2]
        details.append((even - odd) / jnp.sqrt(2.0))
        s = (even + odd) / jnp.sqrt(2.0)
    flat = jnp.concatenate([s] + details[::-1], axis=0)
    kept = _topm_reconstruct(flat, m)
    # inverse
    n_s = s.shape[0]
    s_rec = kept[:n_s]
    off = n_s
    for d in details[::-1]:
        dd = kept[off:off + d.shape[0]]
        off += d.shape[0]
        even = (s_rec + dd) / jnp.sqrt(2.0)
        odd = (s_rec - dd) / jnp.sqrt(2.0)
        s_rec = jnp.stack([even, odd], axis=1).reshape(-1, c)
    return s_rec


def fourier_compress(window: jnp.ndarray, m: int) -> jnp.ndarray:
    """rFFT, keep m/2 complex coefficients (m real values), inverse."""
    t = window.shape[0]
    coeffs = jnp.fft.rfft(window, axis=0)
    keep = max(m // 2, 1)
    mag = jnp.abs(coeffs)
    thresh = -jnp.sort(-mag, axis=0)[keep - 1:keep, :]
    kept = jnp.where(mag >= thresh, coeffs, 0.0)
    return jnp.fft.irfft(kept, n=t, axis=0)


def classical_payload_bytes(m: int, bytes_index: int = 1,
                            bytes_value: int = 2) -> int:
    return m * (bytes_index + bytes_value)
