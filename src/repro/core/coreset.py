"""Coreset construction — the heart of Seeker (paper §3.1).

Two construction families, exactly as in the paper:

* **Importance sampling** (cheap, less accurate): magnitude/frequency-driven
  weighted selection of ``m`` sample points from a sensor window.  Unbiased
  under the sampling distribution; ≤7 refinement iterations in the paper's
  hardware — here selection is a single Gumbel-top-k pass (the iterative
  hardware loop is an artifact of the serial MCU datapath, not the math).

* **K-means clustering** (more expensive, more accurate): Lloyd's algorithm
  with a *fixed* iteration budget (paper: converges within 4 iterations) and
  the paper's hardware working-set trick — only per-cluster ``(sum, radius,
  count)`` is kept, never the member points.

Both produce compact, *recoverable* payloads (see :mod:`repro.core.recovery`)
whose byte-accounting reproduces the paper's arithmetic:
raw 60-pt window = 240 B, 12-cluster coreset = 36 B, +4 bit/cluster point
counts = 42 B (5.7x), activity-aware sizing → ≈8.9x (§5.2).

All functions are pure JAX (jit/vmap/scan friendly).  The Pallas-accelerated
versions (the paper's fixed-function coreset engine, C7) live in
``repro.kernels.kmeans_coreset`` / ``repro.kernels.importance_sampling`` and
are validated against these references.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "ClusterCoreset",
    "SamplingCoreset",
    "points_from_window",
    "window_from_points",
    "kmeans_coreset",
    "importance_weights",
    "importance_coreset",
    "topk_importance_coreset",
    "quantize_uniform",
    "dequantize_uniform",
    "encode_cluster_coreset",
    "decode_cluster_coreset",
    "raw_payload_bytes",
    "cluster_payload_bytes",
    "sampling_payload_bytes",
]


class ClusterCoreset(NamedTuple):
    """Clustering coreset: k N-spherical clusters (paper Fig. 4, right).

    ``centers``: (k, D) cluster centers.
    ``radii``:   (k,)  max distance of any member from its center.
    ``counts``:  (k,)  number of member points (the +4-bit recovery parameter,
                 paper §3.2.2 — never observed >16 in the paper or here).
    """

    centers: jnp.ndarray
    radii: jnp.ndarray
    counts: jnp.ndarray


class SamplingCoreset(NamedTuple):
    """Importance-sampling coreset (paper Fig. 4, left).

    ``indices``: (m,) selected time indices (sorted ascending).
    ``values``:  (m, C) selected sample values.
    ``weights``: (m,) inverse-probability weights making sums unbiased.
    ``mean``/``var``: (C,) first/second moments of the *full* window — the
        latent-space conditioning of the paper's recovery GAN (appendix A.1).
    """

    indices: jnp.ndarray
    values: jnp.ndarray
    weights: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray


# ---------------------------------------------------------------------------
# Window <-> point-cloud plumbing
# ---------------------------------------------------------------------------

def points_from_window(window: jnp.ndarray, time_scale: float | None = None) -> jnp.ndarray:
    """Lift a (T, C) sensor window to a (T, C+1) point cloud.

    Clustering operates on the *geometry* of the signal, so the time axis must
    be a coordinate.  ``time_scale`` makes time commensurate with the value
    range; by default it is the window's peak-to-peak value range (so a
    straight line through time stays "straight" in cluster space).
    """
    if window.ndim == 1:
        window = window[:, None]
    t = window.shape[0]
    if time_scale is None:
        ptp = jnp.max(window) - jnp.min(window)
        time_scale = jnp.maximum(ptp, 1e-6)
    tcoord = jnp.linspace(0.0, 1.0, t, dtype=window.dtype) * time_scale
    return jnp.concatenate([tcoord[:, None], window], axis=-1)


def window_from_points(points: jnp.ndarray, t: int) -> jnp.ndarray:
    """Inverse of :func:`points_from_window`: sort by the time coordinate and
    resample onto a regular (T, C) grid by linear interpolation in time."""
    order = jnp.argsort(points[:, 0])
    pts = points[order]
    src = (pts[:, 0] - pts[0, 0]) / jnp.maximum(pts[-1, 0] - pts[0, 0], 1e-9)
    grid = jnp.linspace(0.0, 1.0, t)
    cols = [jnp.interp(grid, src, pts[:, 1 + c])
            for c in range(points.shape[1] - 1)]
    return jnp.stack(cols, axis=-1)


def channel_cluster_coresets(window: jnp.ndarray, k: int,
                             iters: int = 4) -> ClusterCoreset:
    """Per-channel 2-D (time, value) clustering coresets — the layout of the
    paper's per-channel FIFO hardware (the 240 B / 36 B / 42 B arithmetic is
    per channel).  Returns a ClusterCoreset with leading channel dim:
    centers (C, k, 2), radii (C, k), counts (C, k)."""
    if window.ndim == 1:
        window = window[:, None]

    def one(col):
        return kmeans_coreset(points_from_window(col[:, None]), k, iters)

    return jax.vmap(one, in_axes=1)(window)


# ---------------------------------------------------------------------------
# K-means clustering coreset (paper §3.1 "Coreset Construction Using
# Clustering"; hardware constraints from §4.2)
# ---------------------------------------------------------------------------

def _init_centers(points: jnp.ndarray, k: int) -> jnp.ndarray:
    """Evenly-strided init — deterministic and cheap, matching the paper's
    fixed-function hardware (no RNG on the sensor)."""
    n = points.shape[0]
    stride_idx = (jnp.arange(k) * n) // k
    return points[stride_idx]


def kmeans_coreset(points: jnp.ndarray, k: int, iters: int = 4) -> ClusterCoreset:
    """Lloyd's k-means with a fixed iteration budget (paper: 4 iterations).

    Only ``(sum, count, radius)`` per cluster survive an iteration — the
    paper's hardware working-set observation (§4.2 item 3) — which is also the
    right VMEM footprint for the Pallas kernel.

    Args:
        points: (N, D) point cloud (use :func:`points_from_window` for
            time-series windows).
        k: number of clusters (paper default 12 for HAR, 15–20 for bearing).
        iters: fixed Lloyd iterations (paper hardware: 4).
    """
    n = points.shape[0]
    centers0 = _init_centers(points, k)

    def lloyd(centers, _):
        d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=1)                       # (N,)
        onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)  # (N, k)
        counts = jnp.sum(onehot, axis=0)                      # (k,)
        sums = onehot.T @ points                              # (k, D)
        new_centers = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centers
        )
        return new_centers, None

    centers, _ = jax.lax.scan(lloyd, centers0, None, length=iters)

    d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)
    counts = jnp.sum(onehot, axis=0).astype(jnp.int32)
    dist = jnp.sqrt(jnp.take_along_axis(d2, assign[:, None], axis=1)[:, 0])
    radii = jnp.max(onehot * dist[:, None], axis=0)
    del n
    return ClusterCoreset(centers=centers, radii=radii, counts=counts)


# ---------------------------------------------------------------------------
# Importance-sampling coreset (paper §3.1 "Coreset Construction Using
# Importance Sampling")
# ---------------------------------------------------------------------------

def importance_weights(window: jnp.ndarray, spread: float = 0.25) -> jnp.ndarray:
    """Importance of each sample = contribution to the frequency response
    (paper: "high enough magnitude in the frequency response") plus a uniform
    floor that guarantees temporal spread.

    Implemented as the magnitude of the mean-detrended signal blended with the
    per-sample spectral energy envelope; a ``spread`` fraction of uniform mass
    keeps far-apart samples selectable (paper: "sampling data which are far
    enough from each other").
    """
    if window.ndim == 1:
        window = window[:, None]
    t = window.shape[0]
    detrended = window - jnp.mean(window, axis=0, keepdims=True)
    mag = jnp.sum(jnp.abs(detrended), axis=-1)
    # spectral envelope: inverse FFT of the top-half spectrum magnitude
    spec = jnp.abs(jnp.fft.rfft(detrended, axis=0))
    # energy each time step contributes to the dominant bands
    envelope = jnp.sum(jnp.abs(jnp.fft.irfft(spec * (spec > jnp.median(spec)), n=t, axis=0)), axis=-1)
    w = mag + envelope
    w = w / jnp.maximum(jnp.sum(w), 1e-9)
    uniform = jnp.full((t,), 1.0 / t, dtype=w.dtype)
    return (1.0 - spread) * w + spread * uniform


def _moments(window: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    if window.ndim == 1:
        window = window[:, None]
    return jnp.mean(window, axis=0), jnp.var(window, axis=0)


def importance_coreset(window: jnp.ndarray, m: int, key: jax.Array,
                       spread: float = 0.25) -> SamplingCoreset:
    """Weighted sampling *without replacement* of ``m`` points via the
    Gumbel-top-k trick (single pass — replaces the MCU's ≤7 serial refinement
    iterations with a parallel selection, same distribution family)."""
    if window.ndim == 1:
        window = window[:, None]
    t = window.shape[0]
    w = importance_weights(window, spread=spread)
    g = -jnp.log(-jnp.log(jax.random.uniform(key, (t,), minval=1e-9, maxval=1.0)))
    scores = jnp.log(jnp.maximum(w, 1e-12)) + g
    _, idx = jax.lax.top_k(scores, m)
    idx = jnp.sort(idx)
    mean, var = _moments(window)
    # Horvitz-Thompson style weights: 1 / (m * p_i) keeps weighted sums unbiased
    weights = 1.0 / jnp.maximum(m * w[idx], 1e-9)
    return SamplingCoreset(indices=idx, values=window[idx], weights=weights,
                           mean=mean, var=var)


def topk_importance_coreset(window: jnp.ndarray, m: int,
                            spread: float = 0.25) -> SamplingCoreset:
    """Deterministic variant (pure top-m by importance) — what the paper's
    fixed-function sampler computes when no RNG is available."""
    if window.ndim == 1:
        window = window[:, None]
    w = importance_weights(window, spread=spread)
    _, idx = jax.lax.top_k(w, m)
    idx = jnp.sort(idx)
    mean, var = _moments(window)
    weights = 1.0 / jnp.maximum(m * w[idx], 1e-9)
    return SamplingCoreset(indices=idx, values=window[idx], weights=weights,
                           mean=mean, var=var)


# ---------------------------------------------------------------------------
# Quantized wire encoding + byte accounting (paper §3.2, §4)
# ---------------------------------------------------------------------------

def quantize_uniform(x: jnp.ndarray, bits: int, lo: jnp.ndarray | float,
                     hi: jnp.ndarray | float) -> jnp.ndarray:
    """Symmetric-range uniform quantization to ``bits`` bits (codes as int32)."""
    levels = (1 << bits) - 1
    xc = jnp.clip(x, lo, hi)
    scale = jnp.maximum(hi - lo, 1e-9)
    return jnp.round((xc - lo) / scale * levels).astype(jnp.int32)


def dequantize_uniform(codes: jnp.ndarray, bits: int, lo: jnp.ndarray | float,
                       hi: jnp.ndarray | float) -> jnp.ndarray:
    levels = (1 << bits) - 1
    scale = jnp.maximum(hi - lo, 1e-9)
    return codes.astype(jnp.float32) / levels * scale + lo


class EncodedClusterCoreset(NamedTuple):
    """The wire format of Table/§3.2: per cluster 2 B center + 1 B radius +
    4 bit count, plus a (lo, hi) range pair shared by the whole payload."""

    center_codes: jnp.ndarray  # (k, D) int32, packed at `center_bits/D` bits per dim
    radius_codes: jnp.ndarray  # (k,)  int32, 8-bit
    counts: jnp.ndarray        # (k,)  int32, 4-bit on the wire
    lo: jnp.ndarray
    hi: jnp.ndarray


def encode_cluster_coreset(cs: ClusterCoreset, center_bits: int = 16,
                           radius_bits: int = 8) -> EncodedClusterCoreset:
    d = cs.centers.shape[-1]
    per_dim_bits = max(center_bits // d, 1)
    lo = jnp.min(cs.centers)
    hi = jnp.max(cs.centers)
    center_codes = quantize_uniform(cs.centers, per_dim_bits, lo, hi)
    rhi = jnp.maximum(jnp.max(cs.radii), 1e-9)
    radius_codes = quantize_uniform(cs.radii, radius_bits, 0.0, rhi)
    return EncodedClusterCoreset(center_codes, radius_codes, cs.counts, lo, rhi * 0 + hi)


def decode_cluster_coreset(enc: EncodedClusterCoreset, center_bits: int = 16,
                           radius_bits: int = 8) -> ClusterCoreset:
    d = enc.center_codes.shape[-1]
    per_dim_bits = max(center_bits // d, 1)
    centers = dequantize_uniform(enc.center_codes, per_dim_bits, enc.lo, enc.hi)
    # radius range was [0, hi-ish]; reuse hi-lo scale conservatively
    rhi = jnp.maximum(enc.hi - enc.lo, 1e-9)
    radii = dequantize_uniform(enc.radius_codes, radius_bits, 0.0, rhi)
    return ClusterCoreset(centers=centers, radii=radii, counts=enc.counts)


def raw_payload_bytes(t: int, bytes_per_value: int = 4) -> int:
    """Paper: 60 fp32 points = 240 B."""
    return t * bytes_per_value


def cluster_payload_bytes(k: int, bytes_center: int = 2, bytes_radius: int = 1,
                          bits_count: int = 4, recoverable: bool = True) -> int:
    """Paper: 12 clusters -> 36 B; +4 bit/cluster counts -> 42 B (§3.2.2)."""
    base = k * (bytes_center + bytes_radius)
    if recoverable:
        base += math.ceil(k * bits_count / 8)
    return base


def sampling_payload_bytes(m: int, bytes_index: int = 1, bytes_value: int = 2,
                           with_moments: bool = True, bytes_moment: int = 2,
                           channels: int = 1) -> int:
    """m selected points: 1 B index + 2 B quantized value per channel;
    +mean/var per channel when the GAN-recovery conditioning is shipped
    (paper A.1)."""
    base = m * (bytes_index + bytes_value * channels)
    if with_moments:
        base += 2 * bytes_moment * channels
    return base
