"""Recoverable coreset reconstruction (paper §3.2.2 + appendix A.1).

Two recovery paths, exactly mirroring the paper:

* **Clustering coreset recovery** — each cluster ships ``(center, radius,
  count)``; the host re-synthesizes ``count`` points uniformly inside the
  cluster ball, a *2r-approximate* representation of the original
  distribution (paper Fig. 7a).  DNNs trained on full-size data can then be
  applied unchanged.

* **Importance-sampling coreset recovery** — the dropped points are
  re-synthesized by a small *generator* network conditioned on the window's
  first/second moments (and optionally the predicted class), trained
  adversarially against a discriminator (paper Fig. 7b / appendix A.1).  The
  generator is a few-hundred-k-parameter MLP that lives on the host.

Both recoveries are pure JAX so they can run inside the host pod's jitted
serve step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .coreset import ClusterCoreset, SamplingCoreset, window_from_points

__all__ = [
    "recover_cluster_points",
    "recover_cluster_window",
    "GeneratorParams",
    "init_generator",
    "generator_apply",
    "recover_sampling_window",
    "init_discriminator",
    "discriminator_apply",
]


# ---------------------------------------------------------------------------
# Clustering recovery: uniform redistribution inside each cluster ball
# ---------------------------------------------------------------------------

def _uniform_in_ball(key: jax.Array, n: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    """n points in the unit d-ball with radius ~ U[0, 1] (norm trick).

    NOT volume-uniform (radius ~ u^(1/d)): a cluster of a time-series point
    cloud is a *curve segment* through the ball, so member distances from the
    center are near-uniform in [0, r] rather than shell-concentrated.
    Matching that radial law reconstructs windows markedly better (host-side
    accuracy on recovered coresets ~0.70 vs ~0.55 with volume-uniform
    sampling on the HAR workload) while keeping the support — and therefore
    the paper's 2r-approximation bound — identical.
    """
    knorm, kdir = jax.random.split(key)
    dirs = jax.random.normal(kdir, (n, d), dtype=dtype)
    dirs = dirs / jnp.maximum(jnp.linalg.norm(dirs, axis=-1, keepdims=True), 1e-9)
    radii = jax.random.uniform(knorm, (n, 1), dtype=dtype)
    return dirs * radii


def recover_cluster_points(cs: ClusterCoreset, key: jax.Array,
                           n_points: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Re-synthesize a fixed-size point cloud from a clustering coreset.

    Emits ``n_points`` candidate points (JAX needs static shapes) of which the
    first ``sum(counts)`` — selected proportionally per cluster — are valid;
    the returned mask marks validity.  Points are distributed uniformly
    within each cluster's ball: the paper's 2r-approximation.
    """
    k, d = cs.centers.shape
    # assign each of the n_points slots to a cluster, proportional to counts
    total = jnp.maximum(jnp.sum(cs.counts), 1)
    # slot i belongs to cluster c where cum_counts[c-1] <= floor(i*total/n) < cum_counts[c]
    cum = jnp.cumsum(cs.counts)
    slot_pos = (jnp.arange(n_points) * total) // n_points      # (n_points,) in [0, total)
    slot_cluster = jnp.searchsorted(cum, slot_pos, side="right")
    slot_cluster = jnp.clip(slot_cluster, 0, k - 1)
    mask = jnp.arange(n_points) < total

    offs = _uniform_in_ball(key, n_points, d, dtype=cs.centers.dtype)
    pts = cs.centers[slot_cluster] + offs * cs.radii[slot_cluster][:, None]
    return pts, mask


def recover_cluster_window(cs: ClusterCoreset, key: jax.Array, t: int) -> jnp.ndarray:
    """Full pipeline: coreset -> synthesized points -> regular (T, C) window.

    Accepts either a joint N-D coreset (centers (k, D)) or the per-channel
    layout from :func:`repro.core.coreset.channel_cluster_coresets`
    (centers (C, k, 2)) — the latter is what the paper's per-channel sensor
    hardware produces."""
    if cs.centers.ndim == 3:                      # per-channel (C, k, 2)
        c = cs.centers.shape[0]
        keys = jax.random.split(key, c)

        def one(centers, radii, counts, kk):
            sub = ClusterCoreset(centers, radii, counts)
            pts, _ = recover_cluster_points(sub, kk, n_points=t)
            return window_from_points(pts, t)[:, 0]

        cols = jax.vmap(one)(cs.centers, cs.radii, cs.counts, keys)
        return cols.T                              # (T, C)
    pts, _mask = recover_cluster_points(cs, key, n_points=t)
    return window_from_points(pts, t)


# ---------------------------------------------------------------------------
# Importance-sampling recovery: conditional generator (the paper's GAN)
# ---------------------------------------------------------------------------

class GeneratorParams(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray
    w3: jnp.ndarray
    b3: jnp.ndarray


def init_generator(key: jax.Array, t: int, channels: int, latent: int = 16,
                   hidden: int = 128, n_classes: int = 0) -> GeneratorParams:
    """Generator g(noise, mean, var[, class]) -> (T, C) window.

    A few hundred thousand parameters at most — the paper stresses the
    generator itself is tiny even though GAN *training* is heavyweight.
    """
    in_dim = latent + 2 * channels + n_classes
    out_dim = t * channels
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / jnp.sqrt(in_dim)
    s2 = 1.0 / jnp.sqrt(hidden)
    return GeneratorParams(
        w1=jax.random.normal(k1, (in_dim, hidden)) * s1,
        b1=jnp.zeros((hidden,)),
        w2=jax.random.normal(k2, (hidden, hidden)) * s2,
        b2=jnp.zeros((hidden,)),
        w3=jax.random.normal(k3, (hidden, out_dim)) * s2,
        b3=jnp.zeros((out_dim,)),
    )


def generator_apply(params: GeneratorParams, noise: jnp.ndarray,
                    mean: jnp.ndarray, var: jnp.ndarray,
                    class_onehot: jnp.ndarray | None = None,
                    t: int | None = None) -> jnp.ndarray:
    """Synthesize a full (T, C) window from the coreset's latent conditioning."""
    cond = [noise, mean, jnp.sqrt(jnp.maximum(var, 0.0))]
    if class_onehot is not None:
        cond.append(class_onehot)
    h = jnp.concatenate(cond, axis=-1)
    h = jnp.tanh(h @ params.w1 + params.b1)
    h = jnp.tanh(h @ params.w2 + params.b2)
    out = h @ params.w3 + params.b3
    channels = mean.shape[-1]
    t = t if t is not None else out.shape[-1] // channels
    return out.reshape(out.shape[:-1] + (t, channels))


def recover_sampling_window(params: GeneratorParams, cs: SamplingCoreset,
                            key: jax.Array, t: int,
                            class_onehot: jnp.ndarray | None = None,
                            latent: int = 16) -> jnp.ndarray:
    """Paper A.1: generator fills in the dropped samples; the points the
    sensor *did* transmit are written back verbatim at their indices."""
    noise = jax.random.normal(key, (latent,), dtype=cs.values.dtype)
    synth = generator_apply(params, noise, cs.mean, cs.var, class_onehot, t=t)
    return synth.at[cs.indices].set(cs.values)


# ---------------------------------------------------------------------------
# Discriminator (training-time only; lives in examples/gan_recovery_train.py)
# ---------------------------------------------------------------------------

class DiscriminatorParams(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray
    w3: jnp.ndarray
    b3: jnp.ndarray


def init_discriminator(key: jax.Array, t: int, channels: int,
                       hidden: int = 128) -> DiscriminatorParams:
    in_dim = t * channels
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / jnp.sqrt(in_dim)
    s2 = 1.0 / jnp.sqrt(hidden)
    return DiscriminatorParams(
        w1=jax.random.normal(k1, (in_dim, hidden)) * s1,
        b1=jnp.zeros((hidden,)),
        w2=jax.random.normal(k2, (hidden, hidden)) * s2,
        b2=jnp.zeros((hidden,)),
        w3=jax.random.normal(k3, (hidden, 1)) * s2,
        b3=jnp.zeros((1,)),
    )


def discriminator_apply(params: DiscriminatorParams, window: jnp.ndarray) -> jnp.ndarray:
    h = window.reshape(window.shape[:-2] + (-1,))
    h = jax.nn.leaky_relu(h @ params.w1 + params.b1, 0.2)
    h = jax.nn.leaky_relu(h @ params.w2 + params.b2, 0.2)
    return (h @ params.w3 + params.b3)[..., 0]
