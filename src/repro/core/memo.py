"""Data memoization via signature correlation (paper §3.2.1, decision D0).

For two instances of the same class the sensor signal is highly correlated;
the node stores one ground-truth trace per label and, on a fresh window,
computes the Pearson correlation against every stored signature.  If any
coefficient clears the threshold (paper default 0.95) the node skips DNN
inference entirely and transmits only the label (~6% of compute removed,
paper Fig. 11c).

The Pallas-accelerated signature bank lives in ``repro.kernels.correlation``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = ["pearson", "signature_correlations", "memo_decision", "MemoResult"]


def pearson(a: jnp.ndarray, b: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pearson correlation along ``axis`` (broadcasting elsewhere)."""
    am = a - jnp.mean(a, axis=axis, keepdims=True)
    bm = b - jnp.mean(b, axis=axis, keepdims=True)
    num = jnp.sum(am * bm, axis=axis)
    den = jnp.sqrt(jnp.sum(am * am, axis=axis) * jnp.sum(bm * bm, axis=axis))
    return num / jnp.maximum(den, 1e-9)


def signature_correlations(window: jnp.ndarray, signatures: jnp.ndarray) -> jnp.ndarray:
    """Correlate a (T, C) window against an (L, T, C) signature bank.

    Per-channel Pearson correlations are averaged across channels (the
    paper's multi-channel FIFO treats channels independently).
    Returns (L,) mean correlations.
    """
    if window.ndim == 1:
        window = window[:, None]
    if signatures.ndim == 2:
        signatures = signatures[:, :, None]
    corr = pearson(signatures, window[None], axis=1)   # (L, C)
    return jnp.mean(corr, axis=-1)


class MemoResult(NamedTuple):
    hit: jnp.ndarray        # () bool — some signature cleared the threshold
    label: jnp.ndarray      # () int32 — argmax signature (valid iff hit)
    max_corr: jnp.ndarray   # () float — best coefficient (for logging/decision)


def memo_decision(window: jnp.ndarray, signatures: jnp.ndarray,
                  threshold: float = 0.95) -> MemoResult:
    """The D0 gate of the paper's decision flow (Fig. 8, steps 1a/1b)."""
    corr = signature_correlations(window, signatures)
    best = jnp.argmax(corr)
    max_corr = corr[best]
    return MemoResult(hit=max_corr >= threshold, label=best.astype(jnp.int32),
                      max_corr=max_corr)
