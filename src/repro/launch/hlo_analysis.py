"""While-aware HLO analysis: FLOPs and collective bytes with loop trip counts.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — but our
models scan over layers (and the chunked attention scans over chunk pairs),
so raw cost_analysis undercounts a 60-layer model by ~60x.  This module
re-derives per-device totals from the partitioned HLO text:

* computations are parsed into symbol tables (every defining line carries
  its shape),
* ``dot``/``convolution`` FLOPs are computed from output + contracting dims,
* collective payload bytes are taken from instruction output shapes
  (all-reduce counted 2x: ring = reduce-scatter + all-gather),
* the call graph (``body=``, ``condition=``, ``calls=``, ``to_apply=``) is
  walked from ENTRY with multipliers: a while body multiplies by its trip
  count (parsed from the loop-bound constant in its condition computation),
  branches of a conditional contribute their max.

Numbers are per-device (the partitioned module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from functools import lru_cache

__all__ = ["HloStats", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_RE = re.compile(r"(?:body|condition|calls|to_apply)=([^,)\s]+|\{[^}]*\})")
_WHILE_RE = re.compile(r"\bwhile\(")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(")


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLLECTIVES})
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLLECTIVES})
    warnings: list = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "warnings": self.warnings[:20],
        }


def _first_shape(text: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    shape = [int(d) for d in dims.split(",") if d]
    return dtype, shape


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(txt: str) -> dict[str, list[str]]:
    """name -> list of body lines (including the header line)."""
    comps: dict[str, list[str]] = {}
    cur_name = None
    cur: list[str] = []
    for line in txt.splitlines():
        stripped = line.rstrip()
        if cur_name is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{$", stripped)
            if m:
                cur_name = m.group(1)
                cur = [stripped]
                if stripped.startswith("ENTRY") or " ENTRY " in stripped:
                    comps["__entry__"] = cur
        else:
            cur.append(stripped)
            if stripped == "}":
                comps[cur_name] = cur
                cur_name = None
    return comps


# operand reference, optionally preceded by its inline type — newer jax
# prints `dot(%lhs, %rhs)`, 0.4.x prints `dot(f32[64,64]{1,0} %lhs, ...)`
_OPERAND = r"(?:([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s+)?%?([\w.\-]+)"


def _operand_shape(match_groups, symtab) -> list[int] | None:
    """Shape of an _OPERAND match: inline type if printed, else symtab."""
    dtype, dims, name = match_groups
    if dtype is not None:
        return [int(d) for d in dims.split(",") if d]
    entry = symtab.get(name)
    return None if entry is None else entry[1]


def _dot_flops(line: str, symtab: dict[str, tuple[str, list[int]]]) -> float:
    """2 * prod(output) * prod(lhs contracting dims)."""
    out = _first_shape(line.split("=", 1)[1])
    if out is None:
        return 0.0
    _, out_shape = out
    m = re.search(r"\bdot\(\s*" + _OPERAND, line)
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contracted = 1
    if m and cd:
        lhs_shape = _operand_shape(m.groups(), symtab)
        if lhs_shape is not None:
            for d in cd.group(1).split(","):
                if d and int(d) < len(lhs_shape):
                    contracted *= lhs_shape[int(d)]
        else:
            return -1.0   # unresolved operand — caller records a warning
    return 2.0 * math.prod(out_shape or [1]) * contracted


def _conv_flops(line: str, symtab: dict[str, tuple[str, list[int]]]) -> float:
    out = _first_shape(line.split("=", 1)[1])
    if out is None:
        return 0.0
    _, out_shape = out
    m = re.search(r"\bconvolution\(\s*" + _OPERAND + r"\s*,\s*" + _OPERAND,
                  line)
    if not m:
        return 0.0
    rhs_shape = _operand_shape(m.groups()[3:], symtab)
    if rhs_shape is None:
        return -1.0
    # kernel: spatial... x in_ch x out_ch (exact dim order varies; product
    # over all kernel dims / out_ch gives per-output MACs)
    total_kernel = math.prod(rhs_shape or [1])
    out_ch = out_shape[-1] if out_shape else 1
    per_out = max(total_kernel // max(out_ch, 1), 1)
    return 2.0 * math.prod(out_shape or [1]) * per_out


def analyze_hlo(txt: str) -> HloStats:
    comps = _split_computations(txt)
    stats = HloStats()

    # per-computation: symbol table + local costs + callees
    local: dict[str, dict] = {}
    for name, lines in comps.items():
        symtab: dict[str, tuple[str, list[int]]] = {}
        header = lines[0]
        # fusion-style headers carry typed params: (p: f32[2,3], q: s32[])
        for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])",
                              header):
            sh = _first_shape(pm.group(2))
            if sh:
                symtab[pm.group(1)] = sh
        for line in lines[1:]:
            dm = _DEF_RE.match(line)
            if dm:
                sh = _first_shape(dm.group(2))
                if sh:
                    symtab[dm.group(1)] = sh

        flops = 0.0
        hbm = 0.0
        coll_b = {op: 0.0 for op in _COLLECTIVES}
        coll_c = {op: 0.0 for op in _COLLECTIVES}
        callees: list[tuple[str, str]] = []   # (callee, relation)
        whiles: list[tuple[str, str]] = []    # (body, condition)
        for line in lines[1:]:
            # HBM traffic proxy: output + resolved-operand bytes of every
            # top-level op that actually touches memory (fusion internals are
            # registers; shape-only ops are free)
            dm0 = _DEF_RE.match(line)
            if dm0 and not any(
                    f" {skip}(" in line for skip in
                    ("get-tuple-element", "tuple", "parameter", "constant",
                     "bitcast", "after-all", "iota")):
                rhs = dm0.group(2)
                out_sh = _first_shape(rhs)
                if out_sh and out_sh[0] in _DTYPE_BYTES:
                    hbm += math.prod(out_sh[1] or [1]) * _DTYPE_BYTES[out_sh[0]]
                for opm in re.finditer(
                        r"[(,]\s*(?:([a-z0-9]+)\[([0-9,]*)\]"
                        r"(?:\{[^}]*\})?\s+)?%([\w.\-]+)", rhs):
                    dtype, dims, opname = opm.groups()
                    if dtype is None:
                        osh = symtab.get(opname)
                    else:
                        osh = (dtype, [int(d) for d in dims.split(",") if d])
                    if osh is not None and osh[0] in _DTYPE_BYTES:
                        hbm += math.prod(osh[1] or [1]) * _DTYPE_BYTES[osh[0]]
            if " dot(" in line:
                f = _dot_flops(line, symtab)
                if f < 0:
                    stats.warnings.append(f"unresolved dot operand in {name}")
                else:
                    flops += f
            elif " convolution(" in line:
                f = _conv_flops(line, symtab)
                if f < 0:
                    stats.warnings.append(f"unresolved conv operand in {name}")
                else:
                    flops += f
            for op in _COLLECTIVES:
                if f" {op}(" in line or f" {op}-start(" in line:
                    rhs = line.split("=", 1)[1] if "=" in line else line
                    head = rhs.split(op)[0]
                    b = _all_shapes_bytes(head)
                    mult = 2.0 if op == "all-reduce" else 1.0
                    coll_b[op] += b * mult
                    coll_c[op] += 1
            if _WHILE_RE.search(line):
                body = re.search(r"body=%?([\w.\-]+)", line)
                cond = re.search(r"condition=%?([\w.\-]+)", line)
                if body and cond:
                    whiles.append((body.group(1), cond.group(1)))
            else:
                for cm in _CALL_RE.finditer(line):
                    target = cm.group(1)
                    if target.startswith("{"):
                        for t in re.findall(r"%?([\w.\-]+)", target):
                            callees.append((t, "branch"))
                    else:
                        callees.append((target.lstrip("%"), "call"))
        local[name] = {"flops": flops, "hbm": hbm, "coll_b": coll_b,
                       "coll_c": coll_c, "callees": callees, "whiles": whiles}

    def trip_count(cond_name: str) -> float:
        cond = comps.get(cond_name)
        if cond is None:
            return 1.0
        consts = [int(c) for line in cond for c in _CONST_RE.findall(line)]
        if consts:
            # loop bound constant (conditions are tiny: iv < N, or a fused
            # wrapped_compare against N) — max int constant is the bound
            return float(max(consts))
        stats.warnings.append(f"no trip count for condition {cond_name}")
        return 1.0

    memo: dict[str, tuple] = {}

    def walk(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        if name not in local or depth > 64:
            return (0.0, 0.0, {op: 0.0 for op in _COLLECTIVES},
                    {op: 0.0 for op in _COLLECTIVES})
        lc = local[name]
        flops = lc["flops"]
        hbm = lc["hbm"]
        cb = dict(lc["coll_b"])
        cc = dict(lc["coll_c"])
        branch_best = None
        for callee, rel in lc["callees"]:
            sub = walk(callee, depth + 1)
            if rel == "branch":
                if branch_best is None or sub[0] > branch_best[0]:
                    branch_best = sub
            else:
                flops += sub[0]
                hbm += sub[1]
                for op in _COLLECTIVES:
                    cb[op] += sub[2][op]
                    cc[op] += sub[3][op]
        if branch_best is not None:
            flops += branch_best[0]
            hbm += branch_best[1]
            for op in _COLLECTIVES:
                cb[op] += branch_best[2][op]
                cc[op] += branch_best[3][op]
        for body, cond in lc["whiles"]:
            n = trip_count(cond)
            sub = walk(body, depth + 1)
            flops += n * sub[0]
            hbm += n * sub[1]
            for op in _COLLECTIVES:
                cb[op] += n * sub[2][op]
                cc[op] += n * sub[3][op]
        memo[name] = (flops, hbm, cb, cc)
        return memo[name]

    entry = None
    for name, lines in comps.items():
        if lines and ("ENTRY" in lines[0]):
            entry = name
            break
    if entry is None:
        # fall back: computation with the most instructions
        entry = max(comps, key=lambda n: len(comps[n]))
        stats.warnings.append("no ENTRY found; using largest computation")

    flops, hbm, cb, cc = walk(entry)
    stats.flops = flops
    stats.hbm_bytes = hbm
    stats.collective_bytes = cb
    stats.collective_counts = cc
    return stats
