"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires together configs, mesh, sharded train step, synthetic data, and the
fault-tolerant loop.  On this CPU container use ``--smoke`` (reduced config,
1 device); on a real fleet drop ``--smoke`` and the same code path builds the
production mesh and shards the full model (the dry-run proves the program
compiles for it).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs import ARCHS, get_config, get_smoke
from repro.core.compression import CompressionConfig
from repro.data.lm import LMTask, lm_batches
from repro.launch.mesh import make_production_mesh
from repro.train import (TrainHyper, TrainLoopConfig, init_train_state,
                         make_compressed_train_step, make_train_step,
                         run_training)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true",
                    help="Seeker coreset gradient compression over DP")
    ap.add_argument("--budget-source", default=None,
                    help="EH trace gating steps (rf|wifi|piezo|solar)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    hyper = TrainHyper(peak_lr=args.lr, warmup=max(args.steps // 10, 1),
                       total_steps=args.steps)
    task = LMTask(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    compression = (CompressionConfig() if args.compress_grads else None)

    state = init_train_state(jax.random.PRNGKey(0), cfg, hyper, compression)
    if args.smoke:
        step = (jax.jit(make_train_step(cfg, hyper))
                if not args.compress_grads else None)
        if step is None:
            mesh = shd.make_mesh_compat((jax.device_count(),), ("data",))
            step = jax.jit(make_compressed_train_step(
                cfg, hyper, compression, mesh, dp_axes=("data",)))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = shd.DP_TP_RULES if args.compress_grads else shd.FSDP_RULES
        ctx = shd.use_sharding(mesh, rules)
        ctx.__enter__()
        if args.compress_grads:
            dp = ("pod", "data") if args.multi_pod else ("data",)
            step = jax.jit(make_compressed_train_step(cfg, hyper, compression,
                                                      mesh, dp_axes=dp))
        else:
            step = jax.jit(make_train_step(cfg, hyper))

    def batch_fn(s):
        return lm_batches(task, s)

    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=max(args.steps // 4, 1),
                           log_every=max(args.steps // 20, 1),
                           budget_source=args.budget_source)
    state, log = run_training(state, step, batch_fn, loop)
    for m in log:
        print(m)


if __name__ == "__main__":
    main()
