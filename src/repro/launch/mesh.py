"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init and only then builds meshes.

Topology: TPU v5e-style pods of 256 chips arranged (16, 16):
  * single-pod: (16 data, 16 model) — FSDP x TP inside the pod.
  * multi-pod:  (2 pod, 16 data, 16 model) — the "pod" axis is data-parallel
    across the DCN/ICI-bridged pods (gradient all-reduce crosses it; the
    edge-host serving tier also pairs pods over it).
"""
from __future__ import annotations

import jax

from ..sharding import make_mesh_compat

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (elastic re-mesh, tests)."""
    return make_mesh_compat(shape, axes)
