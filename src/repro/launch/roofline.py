"""Roofline analysis: three terms per (arch x shape x mesh) from the dry-run.

    compute term    = HLO_FLOPs_per_device / 197e12        (bf16 peak/chip)
    memory term     = HLO_bytes_per_device / 819e9          (HBM BW/chip)
    collective term = collective_bytes_per_device / 50e9    (ICI link BW)

Sources: trip-count-corrected HLO analysis (repro.launch.hlo_analysis) for
FLOPs and collective bytes; XLA ``cost_analysis()['bytes accessed']`` scaled
by the correction ratio (corrected_flops / raw_flops) for HBM bytes — XLA's
own per-op accounting, loop-corrected (documented approximation; the
analyzer's raw operand-sum is kept in the JSON as an upper bound).

MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference), D = tokens
processed per step; the ratio MODEL_FLOPS / HLO_FLOPS flags remat and
redundancy waste.

Usage: ``python -m repro.launch.roofline [--tag TAG]`` — prints the markdown
table and writes experiments/roofline<tag>.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

_SUGGEST = {
    ("compute", "train"): "raise arithmetic intensity: fewer remat recomputes"
        " / larger per-device batch; compute term is the roofline itself once"
        " MODEL/HLO ratio ~1",
    ("compute", "prefill"): "prefill is compute-bound by design; reduce"
        " non-model FLOPs (attention masking waste, dispatch overhead)",
    ("compute", "decode"): "decode compute is tiny; batch more requests",
    ("memory", "train"): "cut activation traffic: fuse CE, fewer f32"
        " casts, tighter remat policy",
    ("memory", "prefill"): "stream KV to the cache layout directly;"
        " bf16 end-to-end",
    ("memory", "decode"): "decode is weight/KV-bound: quantize KV (paper C6),"
        " shard KV wider, batch more",
    ("collective", "train"): "compress the DP gradient reduction with coreset"
        " codecs (paper C1-C3), overlap FSDP gathers with compute",
    ("collective", "prefill"): "re-shard to cut resharding collectives;"
        " sequence-parallel attention",
    ("collective", "decode"): "split-KV softmax reductions dominate: shard KV"
        " on heads where divisible, batch on data axis",
}


def load_cells(tag: str = "") -> list[dict]:
    suffix = f"__{tag}.json" if tag else ".json"
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*{suffix}"))):
        base = os.path.basename(path)[:-len(".json")]
        parts = base.split("__")
        if tag:
            if len(parts) != 4 or parts[3] != tag:
                continue
        elif len(parts) != 3:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    hlo = cell.get("hlo_analysis", {})
    raw = cell.get("cost_analysis", {})
    flops = hlo.get("flops", 0.0)
    raw_flops = raw.get("flops", 0.0)
    ratio = (flops / raw_flops) if raw_flops else 1.0
    hbm_bytes = raw.get("bytes_accessed", 0.0) * ratio
    coll_bytes = hlo.get("total_collective_bytes", 0.0)

    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    kind = cell["cell"]["kind"]
    n_active = cell.get("active_params", 0)
    b = cell["cell"]["global_batch"]
    s = cell["cell"]["seq_len"]
    tokens = b * s if kind != "decode" else b
    n_dev = cell.get("n_devices", 1)
    mult = 6 if kind == "train" else 2
    model_flops_dev = mult * n_active * tokens / n_dev
    useful = model_flops_dev / flops if flops else 0.0

    # roofline fraction: useful model FLOP/s achievable if the step runs at
    # the bound of its dominant term
    step_time = max(terms.values())
    frac = (model_flops_dev / step_time) / PEAK_FLOPS if step_time > 0 else 0.0

    ma = cell.get("memory_analysis", {})
    fit_gib = (ma.get("argument_bytes", 0) + ma.get("temp_bytes", 0)
               + ma.get("output_bytes", 0) - ma.get("alias_bytes", 0)) / 2**30

    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant, "model_flops_dev": model_flops_dev,
        "hlo_flops_dev": flops, "useful_ratio": useful,
        "roofline_frac": frac, "fit_gib": fit_gib,
        "suggest": _SUGGEST.get((dominant, kind), ""),
        "kind": kind,
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | MODEL/HLO | roofline frac | fit GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
            f"| {r['t_collective']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']*100:.1f}% "
            f"| {r['fit_gib']:.1f} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    args = ap.parse_args()
    cells = load_cells(args.tag)
    rows = [r for c in cells if (r := roofline_row(c)) is not None]
    if args.mesh != "both":
        rows = [r for r in rows if r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    table = markdown_table(rows)
    print(table)
    skipped = [c for c in cells if c.get("status") == "skipped"]
    errors = [c for c in cells if c.get("status") == "error"]
    print(f"\n{len(rows)} cells, {len(skipped)} skipped, {len(errors)} errors")
    for c in errors:
        print(f"  ERROR {c['arch']} {c['shape']} {c['mesh']}: {c.get('error')}")
    out_path = os.path.join(RESULTS_DIR, "..",
                            f"roofline{'_' + args.tag if args.tag else ''}.md")
    with open(out_path, "w") as f:
        f.write(table)
    print("wrote", os.path.normpath(out_path))


if __name__ == "__main__":
    main()
