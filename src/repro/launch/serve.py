"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Prefill + batched decode with the serving engine; ``--edge-host`` runs the
Seeker HAR edge-host pipeline instead (the paper's system, §4).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import init_params
from repro.serving import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--edge-host", action="store_true",
                    help="run the Seeker HAR edge-host pipeline instead")
    args = ap.parse_args()

    if args.edge_host:
        from repro.configs.seeker_har import HAR
        from repro.core.recovery import init_generator
        from repro.data.sensors import class_signatures, har_stream
        from repro.core.energy import harvest_trace
        from repro.models.har import har_init
        from repro.serving import seeker_simulate

        key = jax.random.PRNGKey(0)
        params = har_init(key, HAR)
        gen = init_generator(key, HAR.window, HAR.channels)
        wins, labels = har_stream(key, 64)
        res = seeker_simulate(
            wins, labels, harvest_trace(key, 64, "rf"),
            signatures=class_signatures(), qdnn_params=params,
            host_params=params, gen_params=gen, har_cfg=HAR)
        print(f"completed {float(res['completed_frac'])*100:.1f}% | "
              f"acc(completed) {float(res['accuracy_completed'])*100:.1f}% | "
              f"mean payload {float(jnp.mean(res['payload_bytes'])):.1f} B "
              f"vs raw {float(res['raw_bytes'][0]):.0f} B")
        return

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    extra = {}
    if cfg.encoder_layers:
        extra["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    if cfg.vision_patches:
        extra["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vision_patches, cfg.d_model), cfg.dtype)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(params, cfg, prompt, args.max_new,
                   key=key, temperature=args.temperature, **extra)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
