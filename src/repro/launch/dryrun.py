"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE VERY FIRST LINES set XLA_FLAGS before any jax import — jax locks the
device count at first init.  Do NOT import this module from test/bench
processes that want 1 device; run it as ``python -m repro.launch.dryrun``.

Per cell this produces (and caches to experiments/dryrun/<cell>.json):
  * compiled.memory_analysis(): per-device argument/output/temp bytes
    (proves the cell fits 16 GB HBM),
  * compiled.cost_analysis(): per-device HLO FLOPs + bytes accessed,
  * collective bytes + op counts parsed from the partitioned HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not report these,
  * lowering/compile wall time.

The roofline table (EXPERIMENTS.md §Roofline) is derived from these JSONs by
``repro.launch.roofline``.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import sharding as shd                      # noqa: E402
from repro.configs import ARCHS, get_config, long_context_ok  # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch.shapes import SHAPES, ShapeCell      # noqa: E402
from repro.models import (abstract_cache, abstract_params, cache_specs,  # noqa: E402
                          decode_step, forward, param_specs)
from repro.models.config import ModelConfig            # noqa: E402
from repro.train import (TrainHyper, init_train_state, make_train_step,  # noqa: E402
                         train_state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# Per-arch production layouts (validated in EXPERIMENTS.md §Perf):
# models too small for 16-way tensor parallelism run pure-DP across the
# whole mesh (mamba2 train collective term: 90 GiB -> 2.3 GiB/dev/step).
ARCH_RULES = {
    "mamba2-130m": shd.PURE_DP_RULES,
}

# Per-arch step hyper-parameters: microbatch counts chosen so the train
# cells fit 16 GiB HBM (yi-34b §Perf iteration log); grok additionally runs
# bf16 AdamW moments (params+opt 13.9 -> 9.5 GiB/dev).
import jax.numpy as _jnp                                   # noqa: E402
from repro.optim import OptConfig as _OptConfig            # noqa: E402

ARCH_HYPER = {
    "yi-34b": TrainHyper(microbatch=8),
    "grok-1-314b": TrainHyper(microbatch=8,
                              opt=_OptConfig(moment_dtype=_jnp.bfloat16)),
    "gemma3-12b": TrainHyper(microbatch=16),
    "recurrentgemma-2b": TrainHyper(microbatch=64),
    "whisper-small": TrainHyper(microbatch=64),
    "gemma-2b": TrainHyper(microbatch=64),
    "qwen2-vl-2b": TrainHyper(microbatch=64),
    "deepseek-moe-16b": TrainHyper(microbatch=64),
    "tinyllama-1.1b": TrainHyper(microbatch=64),
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device payload bytes of every collective in partitioned HLO.

    Convention (documented in EXPERIMENTS.md): bytes = output shape of the
    instruction; all-reduce counted twice (ring = reduce-scatter +
    all-gather).  `-start` variants (async) counted once; `-done` ignored.
    """
    out = {op: {"count": 0, "bytes": 0} for op in _COLLECTIVES}
    for m in _SHAPE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[op]["count"] += 1
        out[op]["bytes"] += b * (2 if op == "all-reduce" else 1)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# Input specs per (arch, shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell, plus the
    logical sharding specs — no device allocation ever happens."""
    b, s = cell.global_batch, cell.seq_len
    extras_sds, extras_spec = {}, {}
    if cfg.encoder_layers:
        extras_sds["enc_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        extras_spec["enc_frames"] = ("batch", None, "embed_act")
    if cfg.vision_patches and cell.kind != "decode":
        extras_sds["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_patches, cfg.d_model), cfg.dtype)
        extras_spec["patch_embeds"] = ("batch", None, "embed_act")

    n_text = s - (cfg.vision_patches if cell.kind != "decode" else 0)
    if cell.kind == "train":
        sds = {"tokens": jax.ShapeDtypeStruct((b, n_text + 1), jnp.int32),
               **extras_sds}
        spec = {"tokens": ("batch", None), **extras_spec}
        return {"batch": sds, "batch_spec": spec}
    if cell.kind == "prefill":
        sds = {"tokens": jax.ShapeDtypeStruct((b, n_text), jnp.int32),
               **extras_sds}
        spec = {"tokens": ("batch", None), **extras_spec}
        return {"tokens": sds, "tokens_spec": spec}
    # decode: KV/state cache of seq_len + one new token
    return {
        "cache": abstract_cache(cfg, b, s),
        "cache_spec": cache_specs(cfg, b, s),
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "tokens_spec": ("batch", None),
    }


def _ns(mesh, rules, spec_tree, sds_tree):
    return shd.tree_named_shardings(spec_tree, sds_tree, mesh, rules)


def build_lowered(arch: str, shape_name: str, mesh, rules=shd.FSDP_RULES,
                  cfg: ModelConfig | None = None, hyper: TrainHyper | None = None,
                  compress: bool = False, dp_axes: tuple[str, ...] | None = None):
    """Lower the cell's step function with full sharding annotations.

    ``compress``: Seeker coreset gradient compression over the DP axes
    (train cells only; pairs with DP_TP_RULES — params replicated on data)."""
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape_name]
    hyper = hyper or TrainHyper()
    specs = input_specs(cfg, cell)
    p_sds = abstract_params(cfg)
    p_spec = param_specs(cfg)

    with shd.use_sharding(mesh, rules):
        if cell.kind == "train":
            from repro.core.compression import CompressionConfig
            ccfg = CompressionConfig() if compress else None
            state_sds = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, hyper,
                                         ccfg))
            state_spec = train_state_specs(cfg, ccfg)
            state_sh = _ns(mesh, rules, state_spec, state_sds)
            batch_sh = _ns(mesh, rules, specs["batch_spec"], specs["batch"])
            metrics_sh = jax.tree_util.tree_map(
                lambda _: jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                {"loss": 0, "grad_norm": 0, "lr": 0})
            if compress:
                from repro.train import make_compressed_train_step
                # manual (DP) axes = every axis the batch shards over,
                # unless the caller pins them (e.g. ("pod",) = compress the
                # slow inter-pod link only, dense ICI reduction within pod)
                batch_rule = rules.get("batch") or ()
                dp = dp_axes or tuple(
                    a for a in batch_rule if a in mesh.shape) or \
                    tuple(a for a in ("pod", "data") if a in mesh.shape)
                step = make_compressed_train_step(cfg, hyper, ccfg, mesh,
                                                  dp_axes=dp)
                jitted = jax.jit(step, donate_argnums=(0,))
            else:
                step = make_train_step(cfg, hyper)
                jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                                 out_shardings=(state_sh, metrics_sh),
                                 donate_argnums=(0,))
            return jitted.lower(state_sds, specs["batch"])

        params_sh = _ns(mesh, rules, p_spec, p_sds)
        if cell.kind == "prefill":
            def prefill_step(params, batch):
                tokens = batch.pop("tokens")
                return forward(params, cfg, tokens, return_cache=True,
                               cache_len=cell.seq_len, **batch)

            tok_sh = _ns(mesh, rules, specs["tokens_spec"], specs["tokens"])
            jitted = jax.jit(prefill_step, in_shardings=(params_sh, tok_sh))
            return jitted.lower(p_sds, specs["tokens"])

        # decode
        def serve_step(params, cache, tokens):
            return decode_step(params, cfg, cache, tokens)

        cache_sh = _ns(mesh, rules, specs["cache_spec"], specs["cache"])
        tok_sh = jax.sharding.NamedSharding(
            mesh, shd.spec_for(specs["tokens_spec"], specs["tokens"].shape,
                               mesh, rules))
        jitted = jax.jit(serve_step,
                         in_shardings=(params_sh, cache_sh, tok_sh),
                         donate_argnums=(1,))
        return jitted.lower(p_sds, specs["cache"], specs["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules=shd.FSDP_RULES, tag: str = "", compress: bool = False,
             cfg: ModelConfig | None = None,
             hyper: TrainHyper | None = None,
             dp_axes: tuple[str, ...] | None = None) -> dict:
    cell = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "tag": tag, "status": "ok"}
    cfg = cfg or get_config(arch)
    if rules is shd.FSDP_RULES:
        rules = ARCH_RULES.get(arch, rules)
    if hyper is None and cell.kind == "train":
        hyper = ARCH_HYPER.get(arch)
    if shape_name == "long_500k" and not long_context_ok(arch):
        result["status"] = "skipped"
        result["reason"] = ("pure full-attention arch: long_500k skipped per "
                            "assignment spec (see DESIGN.md §4)")
        return result
    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered = build_lowered(arch, shape_name, mesh, rules=rules, cfg=cfg,
                                hyper=hyper, compress=compress,
                                dp_axes=dp_axes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # jax 0.4.x: one dict per program
            ca = ca[0] if ca else {}
        result["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        try:
            ma = compiled.memory_analysis()
            result["memory_analysis"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
        except Exception as e:  # pragma: no cover
            result["memory_analysis"] = {"error": str(e)}
        txt = compiled.as_text()
        result["collectives"] = parse_collectives(txt)   # raw (loop bodies once)
        from repro.launch.hlo_analysis import analyze_hlo
        result["hlo_analysis"] = analyze_hlo(txt).to_json()  # trip-count corrected
        result["hlo_chars"] = len(txt)
        result["timings"] = {"lower_s": round(t_lower, 2),
                             "compile_s": round(t_compile, 2)}
        result["n_devices"] = mesh.size
        result["params"] = cfg.param_count()
        result["active_params"] = cfg.active_param_count()
        result["cell"] = dataclasses.asdict(cell)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    return result


def cell_path(arch: str, shape_name: str, mesh_name: str, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="fsdp", choices=["fsdp", "dp_tp"])
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--compress", action="store_true",
                    help="Seeker coreset gradient compression (train cells)")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rules = {"fsdp": shd.FSDP_RULES, "dp_tp": shd.DP_TP_RULES}[args.rules]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {shape} {mesh_name}: "
                              f"{prev['status']}")
                        continue
                print(f"[run]    {arch} {shape} {mesh_name} ...", flush=True)
                res = run_cell(arch, shape, multi, rules=rules, tag=args.tag,
                               compress=args.compress)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    n_ok += 1
                    ma = res.get("memory_analysis", {})
                    print(f"  ok: flops/dev={res['cost_analysis']['flops']:.3e}"
                          f" args/dev={ma.get('argument_bytes', 0)/2**30:.2f}GiB"
                          f" temp/dev={ma.get('temp_bytes', 0)/2**30:.2f}GiB"
                          f" coll/dev={res['collectives']['total_bytes']/2**30:.3f}GiB"
                          f" compile={res['timings']['compile_s']}s", flush=True)
                elif res["status"] == "skipped":
                    n_skip += 1
                    print(f"  skipped: {res['reason']}")
                else:
                    n_err += 1
                    print(f"  ERROR: {res['error']}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
