"""Seeker edge-host serving: the paper's full decision flow (Fig. 8),
single-node and distributed (pod-axis) variants.

Single-node simulation (:func:`seeker_simulate`) reproduces the paper's
system evaluation: per sensing window, the node

  1. correlates against the signature bank (D0 memoization),
  2. forecasts harvestable energy (moving-average predictor),
  3. picks D0-D4 / DEFER from the Table-2 cost ladder,
  4. executes: quantized DNN on-node (D2) or coreset offload (D3/D4) with
     host-side recovery + full-precision DNN,
  5. ensembles across sensors.

Distributed variant (:func:`edge_host_serve_step`): pods pair up as
edge/host tiers — each pod builds cluster coresets for its local sensor
batch, ships the *quantized coreset payload* (centers/radii/counts, the 42-B
wire format scaled up) to its peer over ``collective_permute`` across the
"pod" mesh axis, recovers the peer's payload, and runs host inference.  The
collective moves coreset bytes instead of raw windows: the paper's 5.7-8.9x
reduction shows up directly in the dry-run's collective-permute operand
sizes (see benchmarks/comm_volume.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.aac import AACTable, select_k
from ..core.coreset import (channel_cluster_coresets, cluster_payload_bytes,
                            kmeans_coreset, points_from_window,
                            raw_payload_bytes, sampling_payload_bytes)
from ..core.decision import (D0_MEMO, D2_DNN_QUANT, D3_CLUSTER, D4_SAMPLING,
                             DEFER, choose_decision, decision_energy)
from ..core.energy import (EnergyCosts, PredictorState, predictor_forecast,
                           predictor_init, predictor_update, supercap_step)
from ..core.memo import signature_correlations
from ..core.recovery import (GeneratorParams, recover_cluster_window,
                             recover_sampling_window)
from ..core.coreset import importance_coreset
from ..models.har import HARConfig, har_apply, har_apply_quantized

__all__ = ["SeekerNodeState", "seeker_node_init", "seeker_sensor_step",
           "seeker_sensor_step_given_corr", "seeker_host_step",
           "seeker_simulate", "seeker_simulate_reference",
           "edge_host_serve_step", "fleet_serve_step", "WirePayload",
           "encode_wire_coresets", "decode_wire_coresets",
           "wire_payload_nbytes"]


class SeekerNodeState(NamedTuple):
    stored_uj: jnp.ndarray          # supercap charge
    predictor: PredictorState
    prev_label: jnp.ndarray         # temporal continuity for AAC


def seeker_node_init(predictor_window: int = 8,
                     initial_uj: float = 50.0) -> SeekerNodeState:
    return SeekerNodeState(
        stored_uj=jnp.asarray(initial_uj, jnp.float32),
        predictor=predictor_init(predictor_window),
        prev_label=jnp.zeros((), jnp.int32))


class SensorStepOut(NamedTuple):
    decision: jnp.ndarray           # () int32
    label_or_neg: jnp.ndarray       # () int32: >=0 for D0/D2 results
    logits: jnp.ndarray             # (L,) on-node logits (D2) or zeros
    coreset_centers: jnp.ndarray    # (k_max, D)
    coreset_radii: jnp.ndarray      # (k_max,)
    coreset_counts: jnp.ndarray     # (k_max,)
    coreset_k: jnp.ndarray          # () int32 — AAC-selected k
    samp_idx: jnp.ndarray           # (m,) int32 — D4 payload
    samp_vals: jnp.ndarray          # (m, C)
    samp_mean: jnp.ndarray          # (C,)
    samp_var: jnp.ndarray           # (C,)
    payload_bytes: jnp.ndarray      # () float
    state: SeekerNodeState


def seeker_sensor_step(window: jnp.ndarray, state: SeekerNodeState,
                       harvested_uj: jnp.ndarray, *, signatures: jnp.ndarray,
                       qdnn_params: dict, har_cfg: HARConfig,
                       aac_table: AACTable | None, costs: EnergyCosts,
                       key: jax.Array, k_max: int = 12, m_samples: int = 20,
                       quant_bits: int = 16,
                       corr_threshold: float = 0.95) -> SensorStepOut:
    """One sensing slot on the EH node (paper Fig. 8, all branches traced)."""
    corr = signature_correlations(window, signatures)
    return seeker_sensor_step_given_corr(
        window, state, harvested_uj, corr, qdnn_params=qdnn_params,
        har_cfg=har_cfg, aac_table=aac_table, costs=costs, key=key,
        k_max=k_max, m_samples=m_samples, quant_bits=quant_bits,
        corr_threshold=corr_threshold)


def seeker_sensor_step_given_corr(
        window: jnp.ndarray, state: SeekerNodeState,
        harvested_uj: jnp.ndarray, corr: jnp.ndarray, *, qdnn_params: dict,
        har_cfg: HARConfig, aac_table: AACTable | None, costs: EnergyCosts,
        key: jax.Array, k_max: int = 12, m_samples: int = 20,
        quant_bits: int = 16, corr_threshold: float = 0.95) -> SensorStepOut:
    """Sensor step with the signature correlations precomputed.

    The fleet engine computes ``corr`` for ALL nodes at once through the
    batched :func:`repro.kernels.signature_corr_op` hot path, then vmaps this
    function over nodes; the single-node path computes it per window.
    """
    max_corr = jnp.max(corr)
    memo_label = jnp.argmax(corr).astype(jnp.int32)

    predictor = predictor_update(state.predictor, harvested_uj)
    forecast = predictor_forecast(predictor)
    outcome = choose_decision(max_corr, state.stored_uj, forecast, costs,
                              corr_threshold=corr_threshold)
    decision = outcome.decision

    # --- D2: quantized DNN on-node (executed unconditionally, masked out) ---
    logits = har_apply_quantized(qdnn_params, window[None], quant_bits)[0]
    dnn_label = jnp.argmax(logits).astype(jnp.int32)

    # --- D3: AAC clustering coreset (per-channel, as the paper's FIFO) -----
    if aac_table is not None:
        k_sel = select_k(aac_table, state.prev_label,
                         state.stored_uj + forecast)
    else:
        k_sel = jnp.asarray(k_max, jnp.int32)
    cs = channel_cluster_coresets(window, k=k_max, iters=4)  # (C, k, 2)
    # zero out clusters beyond the AAC-selected k (static k_max buffer)
    keep = jnp.arange(k_max) < k_sel
    centers = jnp.where(keep[None, :, None], cs.centers, 0.0)
    radii = jnp.where(keep[None, :], cs.radii, 0.0)
    counts = jnp.where(keep[None, :], cs.counts, 0)

    # --- D4: importance-sampling coreset -----------------------------------
    sc = importance_coreset(window, m_samples, key)

    # --- bookkeeping --------------------------------------------------------
    t = window.shape[0]
    c = window.shape[1] if window.ndim > 1 else 1
    bytes_by_decision = jnp.asarray([
        2.0,                                              # D0: a label
        2.0, 2.0,                                         # D1/D2: a result
        0.0,                                              # D3: AAC (below)
        float(sampling_payload_bytes(m_samples, channels=c)),
        0.0,                                              # DEFER
    ])
    aac_bytes = (k_sel.astype(jnp.float32) * 3.0
                 + jnp.ceil(k_sel.astype(jnp.float32) / 2.0)) * c
    payload = jnp.where(decision == D3_CLUSTER, aac_bytes,
                        bytes_by_decision[decision])

    stored = supercap_step(state.stored_uj, harvested_uj, outcome.spend)
    label = jnp.where(decision == D0_MEMO, memo_label,
                      jnp.where(decision == D2_DNN_QUANT, dnn_label, -1))
    prev = jnp.where(label >= 0, label, state.prev_label)
    new_state = SeekerNodeState(stored_uj=stored, predictor=predictor,
                                prev_label=prev)
    return SensorStepOut(
        decision=decision, label_or_neg=label.astype(jnp.int32),
        logits=jnp.where(decision == D2_DNN_QUANT, logits, 0.0),
        coreset_centers=centers, coreset_radii=radii, coreset_counts=counts,
        coreset_k=k_sel, samp_idx=sc.indices, samp_vals=sc.values,
        samp_mean=sc.mean, samp_var=sc.var,
        payload_bytes=payload, state=new_state)


def seeker_host_step(out: SensorStepOut, *, host_params: dict,
                     gen_params: GeneratorParams, har_cfg: HARConfig,
                     key: jax.Array, t: int) -> jnp.ndarray:
    """Host side: recover the offloaded representation and infer (D3/D4);
    pass through on-node results (D0/D2). Returns (n_classes,) logits."""
    from ..core.coreset import ClusterCoreset, SamplingCoreset

    k1, k2 = jax.random.split(key)
    cs = ClusterCoreset(out.coreset_centers, out.coreset_radii,
                        out.coreset_counts)
    win_cluster = recover_cluster_window(cs, k1, t)
    sc = SamplingCoreset(out.samp_idx, out.samp_vals,
                         jnp.ones_like(out.samp_idx, jnp.float32),
                         out.samp_mean, out.samp_var)
    win_sampling = recover_sampling_window(gen_params, sc, k2, t)

    logit_cluster = har_apply(host_params, win_cluster[None])[0]
    logit_sampling = har_apply(host_params, win_sampling[None])[0]
    onehot = (jax.nn.one_hot(out.label_or_neg, logit_cluster.shape[-1])
              * 8.0)                                     # confident on-node result
    return jnp.where(out.decision == D3_CLUSTER, logit_cluster,
                     jnp.where(out.decision == D4_SAMPLING, logit_sampling,
                               jnp.where(out.decision == DEFER,
                                         jnp.zeros_like(logit_cluster),
                                         onehot)))


def seeker_simulate(windows: jnp.ndarray, labels: jnp.ndarray,
                    harvest: jnp.ndarray, *, signatures, qdnn_params,
                    host_params, gen_params, har_cfg: HARConfig,
                    aac_table: AACTable | None = None,
                    costs: EnergyCosts | None = None, n_sensors: int = 3,
                    key: jax.Array | None = None, quant_bits: int = 16):
    """Run the full Seeker system over a window stream.

    windows (S, T, C); harvest (S,) µJ per slot. The stream is replicated to
    ``n_sensors`` nodes with independent noise phases (sensor ensemble).
    Returns dict of traces: decisions, predictions, payload bytes, energy.

    Thin wrapper over :func:`repro.serving.fleet.seeker_fleet_simulate` with
    N = ``n_sensors`` replicated nodes — one fully batched scan instead of the
    per-sensor Python loop of :func:`seeker_simulate_reference`.
    """
    from .fleet import seeker_fleet_simulate

    key = key if key is not None else jax.random.PRNGKey(0)
    s, t, c = windows.shape
    fleet = seeker_fleet_simulate(
        windows, jnp.broadcast_to(harvest[None], (n_sensors, s)),
        signatures=signatures, qdnn_params=qdnn_params,
        host_params=host_params, gen_params=gen_params, har_cfg=har_cfg,
        aac_table=aac_table, costs=costs, key=key, quant_bits=quant_bits)
    # sensor ensemble (paper: host ensembles multiple sensors)
    ens_logits = jnp.mean(fleet["logits"], axis=1)           # (S, L)
    preds = jnp.argmax(ens_logits, axis=-1)
    completed = fleet["decisions"][:, 0] != DEFER
    return {
        "preds": preds,
        "labels": labels,
        "accuracy_completed": jnp.sum((preds == labels) & completed)
            / jnp.maximum(jnp.sum(completed), 1),
        "accuracy_scheduled": jnp.mean((preds == labels) & completed),
        "completed_frac": jnp.mean(completed.astype(jnp.float32)),
        "decisions": fleet["decisions"][:, 0],
        "payload_bytes": fleet["payload_bytes"][:, 0],
        "raw_bytes": float(raw_payload_bytes(t)) * jnp.ones((s,)),
        "stored_uj": fleet["stored_uj"][:, 0],
        "k_trace": fleet["k_trace"][:, 0],
    }


def seeker_simulate_reference(windows: jnp.ndarray, labels: jnp.ndarray,
                              harvest: jnp.ndarray, *, signatures,
                              qdnn_params, host_params, gen_params,
                              har_cfg: HARConfig,
                              aac_table: AACTable | None = None,
                              costs: EnergyCosts | None = None,
                              n_sensors: int = 3,
                              key: jax.Array | None = None,
                              quant_bits: int = 16):
    """Legacy per-sensor simulation: a Python loop of single-node scans.

    Kept as the semantics oracle for the fleet engine — tests assert
    :func:`seeker_fleet_simulate` reproduces these traces node for node.
    """
    costs = costs or EnergyCosts()
    key = key if key is not None else jax.random.PRNGKey(0)
    n, t, c = windows.shape

    def step(carry, inp):
        state, k = carry
        window, harvested = inp
        k, k1, k2 = jax.random.split(k, 3)
        out = seeker_sensor_step(
            window, state, harvested, signatures=signatures,
            qdnn_params=qdnn_params, har_cfg=har_cfg, aac_table=aac_table,
            costs=costs, key=k1, quant_bits=quant_bits)
        host_logits = seeker_host_step(out, host_params=host_params,
                                       gen_params=gen_params,
                                       har_cfg=har_cfg, key=k2, t=t)
        trace = {"decision": out.decision, "payload": out.payload_bytes,
                 "stored": out.state.stored_uj, "k": out.coreset_k,
                 "logits": host_logits}
        return (out.state, k), trace

    traces = []
    for sidx in range(n_sensors):
        init = (seeker_node_init(), jax.random.fold_in(key, sidx))
        _, tr = jax.lax.scan(step, init, (windows, harvest))
        traces.append(tr)
    # sensor ensemble (paper: host ensembles multiple sensors)
    ens_logits = sum(tr["logits"] for tr in traces) / n_sensors
    preds = jnp.argmax(ens_logits, axis=-1)
    completed = traces[0]["decision"] != DEFER
    return {
        "preds": preds,
        "labels": labels,
        "accuracy_completed": jnp.sum((preds == labels) & completed)
            / jnp.maximum(jnp.sum(completed), 1),
        "accuracy_scheduled": jnp.mean((preds == labels) & completed),
        "completed_frac": jnp.mean(completed.astype(jnp.float32)),
        "decisions": traces[0]["decision"],
        "payload_bytes": traces[0]["payload"],
        "raw_bytes": float(raw_payload_bytes(t)) * jnp.ones((n,)),
        "stored_uj": traces[0]["stored"],
        "k_trace": traces[0]["k"],
    }


# ---------------------------------------------------------------------------
# Coreset wire format (what actually crosses the pod axis)
# ---------------------------------------------------------------------------

class WirePayload(NamedTuple):
    """Quantized cluster-coreset payload as it crosses the wire: int16 center
    codes, int8 radius codes, int8 counts (modelling the paper's 2 B center /
    1 B radius / 4-bit count format, §3.2.2), plus the per-window float
    ranges needed to dequantize on the host side."""

    c_codes: jnp.ndarray    # (B, C, k, 2) int16
    r_codes: jnp.ndarray    # (B, C, k) int8
    n_codes: jnp.ndarray    # (B, C, k) int8
    lo: jnp.ndarray         # (B, 1, 1, 1) center range low
    hi: jnp.ndarray         # (B, 1, 1, 1) center range high
    rhi: jnp.ndarray        # (B, 1, 1) radius range high


def encode_wire_coresets(centers: jnp.ndarray, radii: jnp.ndarray,
                         counts: jnp.ndarray) -> WirePayload:
    """Quantize per-channel cluster coresets for transmission.

    centers (B, C, k, 2), radii (B, C, k), counts (B, C, k) — the batched
    output of :func:`repro.core.coreset.channel_cluster_coresets`.
    """
    lo = jnp.min(centers, axis=(1, 2, 3), keepdims=True)
    hi = jnp.max(centers, axis=(1, 2, 3), keepdims=True)
    c_codes = jnp.round((centers - lo) / jnp.maximum(hi - lo, 1e-9)
                        * 65535.0 - 32768.0).astype(jnp.int16)
    rhi = jnp.max(radii, axis=(1, 2), keepdims=True)
    r_codes = jnp.round(radii / jnp.maximum(rhi, 1e-9) * 255.0 - 128.0
                        ).astype(jnp.int8)
    n_codes = jnp.clip(counts, 0, 15).astype(jnp.int8)
    return WirePayload(c_codes, r_codes, n_codes, lo, hi, rhi)


def decode_wire_coresets(p: WirePayload):
    """Host-side dequantization; returns (centers, radii, counts int32)."""
    centers = ((p.c_codes.astype(jnp.float32) + 32768.0) / 65535.0
               * (p.hi - p.lo) + p.lo)
    radii = (p.r_codes.astype(jnp.float32) + 128.0) / 255.0 * p.rhi
    return centers, radii, p.n_codes.astype(jnp.int32)


def wire_payload_nbytes(k: int, channels: int) -> int:
    """Bytes the quantized code tensors put on the wire per window (the
    collective_permute operand size, excluding the 3 float range scalars):
    per channel, k x (2-D int16 center + int8 radius + int8 count) — the
    paper's §3.2.2 accounting at the tensor field widths."""
    return channels * cluster_payload_bytes(k, bytes_center=4, bytes_radius=1,
                                            bits_count=8)


# ---------------------------------------------------------------------------
# Distributed edge-host step (pod-axis disaggregation, for the dry-run)
# ---------------------------------------------------------------------------

def _edge_encode_coresets(win: jnp.ndarray, k: int) -> WirePayload:
    """Edge half of a serving tier: per-channel cluster coresets for the
    LOCAL window batch (B, T, C), quantized to the wire format — the only
    tensors that ever cross the mesh."""
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=k, iters=4))(win)
    return encode_wire_coresets(centers, radii, counts)


def _host_recover_infer(payload: WirePayload, host_params: dict,
                        key: jax.Array, t: int) -> jnp.ndarray:
    """Host half of a serving tier: dequantize a received payload batch,
    recover windows, run the full-precision DNN -> (B, n_classes) logits."""
    from ..core.coreset import ClusterCoreset

    centers, radii, counts = decode_wire_coresets(payload)
    keys = jax.random.split(key, centers.shape[0])
    wins_rec = jax.vmap(lambda c, r, n, kk: recover_cluster_window(
        ClusterCoreset(c, r, n), kk, t))(centers, radii, counts, keys)
    return har_apply(host_params, wins_rec)


def edge_host_serve_step(windows: jnp.ndarray, *, signatures, qdnn_params,
                         host_params, gen_params, har_cfg: HARConfig,
                         mesh, k: int = 12, quant_bits: int = 16,
                         key: jax.Array | None = None):
    """Paired-tier serving across the "pod" mesh axis.

    Each pod is the *edge* for its own sensor batch (memoization + quantized
    DNN + cluster-coreset construction) and the *host* for its peer pod: the
    quantized coreset payload crosses pods via ``collective_permute`` —
    coreset bytes on the wire instead of raw windows (8.9x fewer, paper C3).

    windows: (B, T, C) globally, sharded over ("pod", "data") on B.
    Returns (B, n_classes) host logits for the *peer's* windows, in the peer
    pod's shards.
    """
    from jax.sharding import PartitionSpec as P

    key = key if key is not None else jax.random.PRNGKey(0)
    t = windows.shape[1]

    def tier(win):
        # --- edge side: local sensors, quantized wire format (2B centers /
        # 1B radii / 4b counts modelled as int16/int8/int8 tensors: what
        # collective_permute actually moves) ---------------------------------
        payload = _edge_encode_coresets(win, k)

        # --- cross-pod transfer: coreset payload only ----------------------
        npods = jax.lax.psum(1, "pod")
        perm = [(i, (i + 1) % npods) for i in range(npods)]
        payload = WirePayload(*(jax.lax.ppermute(f, "pod", perm)
                                for f in payload))

        # --- host side: recover the peer's coresets and infer ---------------
        return _host_recover_infer(payload, host_params, key, t)

    from ..sharding import shard_map_compat
    fn = shard_map_compat(
        tier, mesh,
        in_specs=(P(("pod", "data")) if "pod" in mesh.shape else P("data"),),
        out_specs=P(("pod", "data")) if "pod" in mesh.shape else P("data"),
        axis_names=frozenset(a for a in ("pod", "data") if a in mesh.shape))
    return fn(windows)


def fleet_serve_step(windows: jnp.ndarray, *, host_params,
                     har_cfg: HARConfig, mesh, k: int = 12,
                     key: jax.Array | None = None):
    """Sharded-fleet edge→host tier: gather ONLY coreset payloads to the host.

    The companion to :func:`repro.serving.fleet.seeker_fleet_simulate_sharded`
    for the offload decisions (D3): each shard builds per-channel cluster
    coresets for its *local* node tile and quantizes them to the compact wire
    format; the int16/int8 code tensors are then ``all_gather``-ed over the
    fleet's node axes (minor axis first, so global node order is preserved)
    to the host tier, which dequantizes, recovers windows, and runs the
    full-precision DNN for the whole fleet.  Raw windows and node state never
    leave their shard — only coreset bytes cross the mesh, reproducing the
    paper's edge-host communication asymmetry at the collective level.

    Args:
        windows: (N, T, C) fleet sensor windows, one per node.  N that does
            not divide the mesh quantum is padded with zero windows and the
            padding is sliced off the returned logits.
        mesh: mesh whose FLEET_RULES node axes carry the fleet.

    Returns dict: ``host_logits`` (N, L) for every node, ``wire_bytes`` —
    total quantized payload bytes gathered across the mesh, ``raw_bytes`` —
    the raw-window equivalent (the communication the gather avoided).
    """
    from ..sharding import node_mesh_axes, shard_map_compat

    key = key if key is not None else jax.random.PRNGKey(0)
    n, t, c = windows.shape
    axis_names, quantum = node_mesh_axes(mesh)
    if not axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has none of the FLEET_RULES node axes")
    pad = (-n) % quantum
    if pad:
        windows = jnp.pad(windows, ((0, pad), (0, 0), (0, 0)))

    def tier(win, kk):
        # --- edge side: coresets + wire quantization for LOCAL nodes only --
        payload = _edge_encode_coresets(win, k)

        # --- node axis -> host tier: the quantized codes are ALL that moves.
        # Gather the minor mesh axis first so the concatenated node order
        # matches the global (pod-major) layout of the padded fleet.
        for ax in reversed(axis_names):
            payload = WirePayload(*(jax.lax.all_gather(f, ax, axis=0,
                                                       tiled=True)
                                    for f in payload))

        # --- host side: dequantize, recover, full-precision inference ------
        return _host_recover_infer(payload, host_params, kk, t)
        # -> (N+pad, L) replicated

    from jax.sharding import PartitionSpec as P
    fn = shard_map_compat(tier, mesh, in_specs=(P(axis_names), P()),
                          out_specs=P(), axis_names=frozenset(axis_names))
    logits = fn(windows, key)[:n]
    return {
        "host_logits": logits,
        "wire_bytes": n * wire_payload_nbytes(k, c),
        "raw_bytes": n * raw_payload_bytes(t) * c,
    }
