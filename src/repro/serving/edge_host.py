"""Seeker edge-host serving: the paper's full decision flow (Fig. 8),
single-node and distributed (pod-axis) variants.

Single-node simulation (:func:`seeker_simulate`) reproduces the paper's
system evaluation: per sensing window, the node

  1. correlates against the signature bank (D0 memoization),
  2. forecasts harvestable energy (moving-average predictor),
  3. picks D0-D4 / DEFER from the Table-2 cost ladder,
  4. executes: quantized DNN on-node (D2) or coreset offload (D3/D4) with
     host-side recovery + full-precision DNN,
  5. ensembles across sensors.

Distributed variant (:func:`edge_host_serve_step`): pods pair up as
edge/host tiers — each pod builds cluster coresets for its local sensor
batch, ships the *quantized coreset payload* (centers/radii/counts, the 42-B
wire format scaled up) to its peer over ``collective_permute`` across the
"pod" mesh axis, recovers the peer's payload, and runs host inference.  The
collective moves coreset bytes instead of raw windows: the paper's 5.7-8.9x
reduction shows up directly in the dry-run's collective-permute operand
sizes (see benchmarks/comm_volume.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.aac import AACTable, select_k
from ..core.coreset import (channel_cluster_coresets, cluster_payload_bytes,
                            kmeans_coreset, points_from_window,
                            raw_payload_bytes, sampling_payload_bytes)
from ..core.decision import (D0_MEMO, D2_DNN_QUANT, D3_CLUSTER, D4_SAMPLING,
                             DEFER, D6_PARTIAL, D7_EARLY_EXIT, D8_STAGED_FULL,
                             IntermittentConfig, choose_decision,
                             decision_energy)
from ..core.energy import (BrownoutConfig, EnergyCosts, PredictorState,
                           predictor_forecast, predictor_init,
                           predictor_update, supercap_step,
                           supercap_step_direct)
from ..core.memo import signature_correlations
from ..core.recovery import (GeneratorParams, recover_cluster_window,
                             recover_sampling_window)
from ..core.coreset import importance_coreset
from ..models.har import (HARConfig, har_act_buffer, har_apply,
                          har_apply_aux, har_apply_quantized,
                          har_apply_stage)

__all__ = ["SeekerNodeState", "seeker_node_init", "seeker_sensor_step",
           "seeker_sensor_step_given_corr", "seeker_host_step",
           "seeker_simulate", "seeker_simulate_reference",
           "edge_host_serve_step", "fleet_serve_step", "WirePayload",
           "encode_wire_coresets", "decode_wire_coresets",
           "wire_payload_nbytes", "wire_payload_to_bytes",
           "wire_payload_from_bytes", "WireSamplePayload",
           "encode_wire_samples", "decode_wire_samples",
           "wire_sample_nbytes", "IntermittentState",
           "intermittent_node_init", "intermittent_fleet_init",
           "IntermittentLaneOut", "intermittent_lane_step"]


class SeekerNodeState(NamedTuple):
    stored_uj: jnp.ndarray          # supercap charge
    predictor: PredictorState
    prev_label: jnp.ndarray         # temporal continuity for AAC


def seeker_node_init(predictor_window: int = 8,
                     initial_uj: float = 50.0) -> SeekerNodeState:
    return SeekerNodeState(
        stored_uj=jnp.asarray(initial_uj, jnp.float32),
        predictor=predictor_init(predictor_window),
        prev_label=jnp.zeros((), jnp.int32))


class SensorStepOut(NamedTuple):
    decision: jnp.ndarray           # () int32
    label_or_neg: jnp.ndarray       # () int32: >=0 for D0/D2 results
    logits: jnp.ndarray             # (L,) on-node logits (D2) or zeros
    coreset_centers: jnp.ndarray    # (k_max, D)
    coreset_radii: jnp.ndarray      # (k_max,)
    coreset_counts: jnp.ndarray     # (k_max,)
    coreset_k: jnp.ndarray          # () int32 — AAC-selected k
    samp_idx: jnp.ndarray           # (m,) int32 — D4 payload
    samp_vals: jnp.ndarray          # (m, C)
    samp_mean: jnp.ndarray          # (C,)
    samp_var: jnp.ndarray           # (C,)
    payload_bytes: jnp.ndarray      # () float
    state: SeekerNodeState


def seeker_sensor_step(window: jnp.ndarray, state: SeekerNodeState,
                       harvested_uj: jnp.ndarray, *, signatures: jnp.ndarray,
                       qdnn_params: dict, har_cfg: HARConfig,
                       aac_table: AACTable | None, costs: EnergyCosts,
                       key: jax.Array, k_max: int = 12, m_samples: int = 20,
                       quant_bits: int = 16,
                       corr_threshold: float = 0.95) -> SensorStepOut:
    """One sensing slot on the EH node (paper Fig. 8, all branches traced)."""
    corr = signature_correlations(window, signatures)
    return seeker_sensor_step_given_corr(
        window, state, harvested_uj, corr, qdnn_params=qdnn_params,
        har_cfg=har_cfg, aac_table=aac_table, costs=costs, key=key,
        k_max=k_max, m_samples=m_samples, quant_bits=quant_bits,
        corr_threshold=corr_threshold)


def seeker_sensor_step_given_corr(
        window: jnp.ndarray, state: SeekerNodeState,
        harvested_uj: jnp.ndarray, corr: jnp.ndarray, *, qdnn_params: dict,
        har_cfg: HARConfig, aac_table: AACTable | None, costs: EnergyCosts,
        key: jax.Array, k_max: int = 12, m_samples: int = 20,
        quant_bits: int = 16, corr_threshold: float = 0.95,
        strict_energy: bool = False,
        cost_scale: jnp.ndarray | None = None) -> SensorStepOut:
    """Sensor step with the signature correlations precomputed.

    The fleet engine computes ``corr`` for ALL nodes at once through the
    batched :func:`repro.kernels.signature_corr_op` hot path, then vmaps this
    function over nodes; the single-node path computes it per window.

    ``strict_energy`` switches the ladder to store-and-execute accounting:
    the decision must be payable from ``stored + harvested`` this slot (the
    forecast still ranks AAC's k but cannot mint energy), and the storage
    update uses :func:`repro.core.energy.supercap_step_direct` so debt is
    never clip-forgiven.  ``False`` keeps the legacy path bitwise.

    ``cost_scale`` is the heterogeneous-task lane's per-node ladder scale
    (see :class:`repro.serving.fleet_lanes.TaskLaneConfig`); ``None`` keeps
    the homogeneous-fleet jaxpr bitwise.
    """
    max_corr = jnp.max(corr)
    memo_label = jnp.argmax(corr).astype(jnp.int32)

    predictor = predictor_update(state.predictor, harvested_uj)
    forecast = predictor_forecast(predictor)
    outcome = choose_decision(
        max_corr, state.stored_uj, forecast, costs,
        corr_threshold=corr_threshold,
        harvested_uj=harvested_uj if strict_energy else None,
        cost_scale=cost_scale)
    decision = outcome.decision

    # --- D2: quantized DNN on-node (executed unconditionally, masked out) ---
    logits = har_apply_quantized(qdnn_params, window[None], quant_bits)[0]
    dnn_label = jnp.argmax(logits).astype(jnp.int32)

    # --- D3: AAC clustering coreset (per-channel, as the paper's FIFO) -----
    if aac_table is not None:
        k_sel = select_k(aac_table, state.prev_label,
                         state.stored_uj + forecast)
    else:
        k_sel = jnp.asarray(k_max, jnp.int32)
    cs = channel_cluster_coresets(window, k=k_max, iters=4)  # (C, k, 2)
    # zero out clusters beyond the AAC-selected k (static k_max buffer)
    keep = jnp.arange(k_max) < k_sel
    centers = jnp.where(keep[None, :, None], cs.centers, 0.0)
    radii = jnp.where(keep[None, :], cs.radii, 0.0)
    counts = jnp.where(keep[None, :], cs.counts, 0)

    # --- D4: importance-sampling coreset -----------------------------------
    sc = importance_coreset(window, m_samples, key)

    # --- bookkeeping --------------------------------------------------------
    t = window.shape[0]
    c = window.shape[1] if window.ndim > 1 else 1
    bytes_by_decision = jnp.asarray([
        2.0,                                              # D0: a label
        2.0, 2.0,                                         # D1/D2: a result
        0.0,                                              # D3: AAC (below)
        float(sampling_payload_bytes(m_samples, channels=c)),
        0.0,                                              # DEFER
    ])
    aac_bytes = (k_sel.astype(jnp.float32) * 3.0
                 + jnp.ceil(k_sel.astype(jnp.float32) / 2.0)) * c
    payload = jnp.where(decision == D3_CLUSTER, aac_bytes,
                        bytes_by_decision[decision])

    if strict_energy:
        stored = supercap_step_direct(state.stored_uj, harvested_uj,
                                      outcome.spend)
    else:
        stored = supercap_step(state.stored_uj, harvested_uj, outcome.spend)
    label = jnp.where(decision == D0_MEMO, memo_label,
                      jnp.where(decision == D2_DNN_QUANT, dnn_label, -1))
    prev = jnp.where(label >= 0, label, state.prev_label)
    new_state = SeekerNodeState(stored_uj=stored, predictor=predictor,
                                prev_label=prev)
    return SensorStepOut(
        decision=decision, label_or_neg=label.astype(jnp.int32),
        logits=jnp.where(decision == D2_DNN_QUANT, logits, 0.0),
        coreset_centers=centers, coreset_radii=radii, coreset_counts=counts,
        coreset_k=k_sel, samp_idx=sc.indices, samp_vals=sc.values,
        samp_mean=sc.mean, samp_var=sc.var,
        payload_bytes=payload, state=new_state)


def seeker_host_step(out: SensorStepOut, *, host_params: dict,
                     gen_params: GeneratorParams, har_cfg: HARConfig,
                     key: jax.Array, t: int) -> jnp.ndarray:
    """Host side: recover the offloaded representation and infer (D3/D4);
    pass through on-node results (D0/D2). Returns (n_classes,) logits."""
    from ..core.coreset import ClusterCoreset, SamplingCoreset

    k1, k2 = jax.random.split(key)
    cs = ClusterCoreset(out.coreset_centers, out.coreset_radii,
                        out.coreset_counts)
    win_cluster = recover_cluster_window(cs, k1, t)
    sc = SamplingCoreset(out.samp_idx, out.samp_vals,
                         jnp.ones_like(out.samp_idx, jnp.float32),
                         out.samp_mean, out.samp_var)
    win_sampling = recover_sampling_window(gen_params, sc, k2, t)

    logit_cluster = har_apply(host_params, win_cluster[None])[0]
    logit_sampling = har_apply(host_params, win_sampling[None])[0]
    onehot = (jax.nn.one_hot(out.label_or_neg, logit_cluster.shape[-1])
              * 8.0)                                     # confident on-node result
    return jnp.where(out.decision == D3_CLUSTER, logit_cluster,
                     jnp.where(out.decision == D4_SAMPLING, logit_sampling,
                               jnp.where(out.decision == DEFER,
                                         jnp.zeros_like(logit_cluster),
                                         onehot)))


# ---------------------------------------------------------------------------
# Intermittent-inference lane (decision codes D6/D7/D8)
# ---------------------------------------------------------------------------


class IntermittentState(NamedTuple):
    """Per-node staged-inference progress — the intermittent lane's slice of
    the fleet scan carry (see docs/RESUME_CONTRACT.md for the rules a carry
    lane must follow).

    ``active``: a staged inference is in flight (suspended or advancing).
    ``stage``: completed stages (1..3; 3 = logits ready, transmit pending).
    ``acts``: (A,) flat activation buffer holding the last completed stage's
    output (A = :func:`repro.models.har.har_act_buffer`).
    ``src_slot``: the GLOBAL slot index whose window is in flight — emissions
    are scored against this slot's label, not the emission slot's.
    """

    active: jnp.ndarray     # () / (N,) bool
    stage: jnp.ndarray      # () / (N,) int32
    acts: jnp.ndarray       # (A,) / (N, A) float32
    src_slot: jnp.ndarray   # () / (N,) int32


def intermittent_node_init(har_cfg: HARConfig) -> IntermittentState:
    """Idle single-node lane state (nothing in flight)."""
    return IntermittentState(
        active=jnp.zeros((), bool),
        stage=jnp.zeros((), jnp.int32),
        acts=jnp.zeros((har_act_buffer(har_cfg),), jnp.float32),
        src_slot=jnp.zeros((), jnp.int32))


def intermittent_fleet_init(n_nodes: int,
                            har_cfg: HARConfig) -> IntermittentState:
    """Stacked idle lane state for ``n_nodes`` (leading node axis)."""
    return IntermittentState(
        active=jnp.zeros((n_nodes,), bool),
        stage=jnp.zeros((n_nodes,), jnp.int32),
        acts=jnp.zeros((n_nodes, har_act_buffer(har_cfg)), jnp.float32),
        src_slot=jnp.zeros((n_nodes,), jnp.int32))


class IntermittentLaneOut(NamedTuple):
    engaged: jnp.ndarray       # () bool — the lane overrode this slot
    decision: jnp.ndarray      # () int32: D6/D7/D8 or DEFER
    spend: jnp.ndarray         # () float µJ actually consumed
    payload_bytes: jnp.ndarray # () float: 3 B early exit, 2 B full, else 0
    stored_uj: jnp.ndarray     # () post-slot supercap charge
    prev_label: jnp.ndarray    # () int32 AAC continuity after the slot
    emit: jnp.ndarray          # () int32: 0 none, 1 early exit, 2 full depth
    emit_label: jnp.ndarray    # () int32 (valid when emit > 0)
    emit_conf: jnp.ndarray     # () float aux-head max-softmax (early exits)
    emit_src: jnp.ndarray      # () int32 source slot of the emitted window
    emit_stage: jnp.ndarray    # () int32 depth at emission (1/2 early, 3 full)
    state: IntermittentState


def intermittent_lane_step(window: jnp.ndarray, state: SeekerNodeState,
                           harvested_uj: jnp.ndarray,
                           ladder_decision: jnp.ndarray,
                           it: IntermittentState, slot: jnp.ndarray, *,
                           qp: dict, aux_params: dict, har_cfg: HARConfig,
                           costs: EnergyCosts, quant_bits: int,
                           cfg: IntermittentConfig,
                           reserve_uj: float = 0.0,
                           cost_scale: jnp.ndarray | None = None
                           ) -> IntermittentLaneOut:
    """One slot of the energy-adaptive partial-inference lane (paper-adjacent
    intermittent computing: Islam et al. 2503.06663, Gobieski et al.
    1810.07751), for ONE node — the fleet engines vmap this after the ladder
    step.

    Engages when an inference is in flight (resume before starting new work)
    or when the ladder chose DEFER (the freeze-and-lose slot this lane
    converts into progress).  Under STRICT store-and-execute accounting —
    every µJ spent is gated on ``stored + harvested`` this slot, PR 5
    semantics, the forecast mints nothing — it:

    1. pays the sensing cost (zero-clamped exactly like strict DEFER),
    2. executes as many remaining stages as the budget affords
       (:meth:`repro.core.energy.EnergyCosts.stage_costs`), resuming from
       the suspended activation buffer,
    3. on full depth + an affordable ``tx_result``: emits D8,
    4. stalled with ``>= min_exit_stage`` stages done, an affordable
       ``aux_head + tx_result``, and aux confidence ``>= exit_threshold``:
       emits a confidence-tagged early exit, D7,
    5. otherwise suspends (D6 with progress in the carry; plain DEFER when
       nothing was started).

    ``qp`` is the PRE-quantized backbone (:func:`quantize_params` at
    ``quant_bits``) so the vmapped fleet quantizes once per slot, not per
    node.

    ``reserve_uj`` is the brown-out reserve: stage execution and emissions
    are additionally gated on leaving at least this much charge behind
    (the fleet engines pass ``BrownoutConfig.off_uj``).  Without it the
    lane spends every DEFER slot down to zero, tripping the power-down
    hysteresis and losing whole recharge cycles — threshold-aware
    budgeting is what makes staged progress a net win over freeze-and-
    lose (the benchmark's acceptance metric).  Sensing stays mandatory,
    exactly like strict DEFER.
    """
    sense = costs.sense
    tx = costs.tx_result
    aux_c = costs.aux_head
    stage_cost = costs.stage_costs(quant_bits)
    if cost_scale is not None:
        # heterogeneous-task lane: the whole staged ladder scales per node,
        # mirroring choose_decision's scaled D0-D4 table
        sense = sense * cost_scale
        tx = tx * cost_scale
        aux_c = aux_c * cost_scale
        stage_cost = jnp.asarray(stage_cost, jnp.float32) * cost_scale

    engaged = it.active | (ladder_decision == DEFER)
    budget = state.stored_uj + harvested_uj
    sense_ok = budget >= sense
    can_run = engaged & sense_ok
    spend = jnp.where(can_run, sense, 0.0)
    rem = budget - spend

    # resume-before-start: an in-flight inference owns the slot; otherwise
    # capture THIS slot's window as stage-0 input
    fresh = can_run & ~it.active
    a = it.acts.shape[0]
    win_flat = jnp.concatenate([
        window.reshape(-1),
        jnp.zeros((a - window.size,), jnp.float32)])
    buf = jnp.where(fresh, win_flat, it.acts)
    prog = jnp.where(fresh, 0, it.stage)
    src = jnp.where(fresh, slot, it.src_slot)

    # unrolled masked stage walk: each stage runs only if it is the next one
    # AND strictly affordable from what remains — no stage ever executes on
    # energy that does not exist
    for si in range(3):
        out_i = har_apply_stage(qp, buf, si, har_cfg, quant_bits)
        run_i = can_run & (prog == si) & (rem >= stage_cost[si] + reserve_uj)
        buf = jnp.where(run_i, out_i, buf)
        prog = jnp.where(run_i, prog + 1, prog)
        rem = jnp.where(run_i, rem - stage_cost[si], rem)
        spend = jnp.where(run_i, spend + stage_cost[si], spend)

    logits_full = buf[:har_cfg.n_classes]
    done = can_run & (prog == 3)
    emit_full = done & (rem >= tx + reserve_uj)

    aux_logits = har_apply_aux(aux_params, buf, prog, har_cfg, quant_bits)
    conf = jnp.max(jax.nn.softmax(aux_logits))
    emit_early = (can_run & ~done & (prog >= cfg.min_exit_stage)
                  & (rem >= aux_c + tx + reserve_uj)
                  & (conf >= cfg.exit_threshold))

    spend = spend + jnp.where(emit_full, tx, 0.0) \
        + jnp.where(emit_early, aux_c + tx, 0.0)
    emitted = emit_full | emit_early
    label = jnp.where(emit_full, jnp.argmax(logits_full),
                      jnp.argmax(aux_logits)).astype(jnp.int32)

    decision = jnp.where(
        emit_full, D8_STAGED_FULL,
        jnp.where(emit_early, D7_EARLY_EXIT,
                  jnp.where(can_run & (prog > 0), D6_PARTIAL,
                            DEFER))).astype(jnp.int32)
    # D7: 2-B result + 1-B confidence tag; D8: 2-B result
    payload = jnp.where(emit_full, 2.0, jnp.where(emit_early, 3.0, 0.0))
    stored = supercap_step_direct(state.stored_uj, harvested_uj, spend)
    prev_label = jnp.where(emitted, label, state.prev_label)

    new_it = IntermittentState(
        active=jnp.where(can_run, ~emitted & (prog > 0), it.active),
        stage=jnp.where(can_run, prog, it.stage),
        acts=buf,
        src_slot=src)
    return IntermittentLaneOut(
        engaged=engaged, decision=decision, spend=spend,
        payload_bytes=payload, stored_uj=stored, prev_label=prev_label,
        emit=jnp.where(emit_full, 2, jnp.where(emit_early, 1, 0)
                       ).astype(jnp.int32),
        emit_label=label, emit_conf=conf, emit_src=src,
        emit_stage=prog, state=new_it)


def seeker_simulate(windows: jnp.ndarray, labels: jnp.ndarray,
                    harvest: jnp.ndarray, *, signatures, qdnn_params,
                    host_params, gen_params, har_cfg: HARConfig,
                    aac_table: AACTable | None = None,
                    costs: EnergyCosts | None = None, n_sensors: int = 3,
                    key: jax.Array | None = None, quant_bits: int = 16,
                    brownout: BrownoutConfig | None = None,
                    intermittent: IntermittentConfig | None = None,
                    aux_params: dict | None = None):
    """Run the full Seeker system over a window stream.

    windows (S, T, C); harvest (S,) µJ per slot. The stream is replicated to
    ``n_sensors`` nodes with independent noise phases (sensor ensemble).
    Returns dict of traces: decisions, predictions, payload bytes, energy.

    Thin wrapper over :func:`repro.serving.fleet.seeker_fleet_simulate` with
    N = ``n_sensors`` replicated nodes — one fully batched scan instead of the
    per-sensor Python loop of :func:`seeker_simulate_reference`.

    ``brownout`` threads the fleet engine's endogenous brown-out lane
    through the single-node path: strict store-and-execute affordability and
    supercap-hysteresis churn (the returned dict gains per-slot ``alive`` /
    ``brownout`` lanes for sensor 0 plus the ``brownout_slots`` /
    ``brownout_events`` counters).  ``None`` is the legacy path, bitwise.

    ``intermittent`` (with ``aux_params``) threads the staged intermittent-
    inference lane the same way (see :func:`intermittent_lane_step`):
    DEFER slots become staged progress, and ``completed`` then counts
    everything but DEFER *and* D6 suspensions — a suspended inference put
    nothing on the wire yet.
    """
    from .fleet import seeker_fleet_simulate

    key = key if key is not None else jax.random.PRNGKey(0)
    s, t, c = windows.shape
    extra = ({} if intermittent is None else
             dict(intermittent=intermittent, aux_params=aux_params))
    fleet = seeker_fleet_simulate(
        windows, jnp.broadcast_to(harvest[None], (n_sensors, s)),
        signatures=signatures, qdnn_params=qdnn_params,
        host_params=host_params, gen_params=gen_params, har_cfg=har_cfg,
        aac_table=aac_table, costs=costs, key=key, quant_bits=quant_bits,
        brownout=brownout, **extra)
    # sensor ensemble (paper: host ensembles multiple sensors)
    ens_logits = jnp.mean(fleet["logits"], axis=1)           # (S, L)
    preds = jnp.argmax(ens_logits, axis=-1)
    completed = fleet["decisions"][:, 0] != DEFER
    if intermittent is not None:
        completed = completed & (fleet["decisions"][:, 0] != D6_PARTIAL)
    out = {
        "preds": preds,
        "labels": labels,
        "accuracy_completed": jnp.sum((preds == labels) & completed)
            / jnp.maximum(jnp.sum(completed), 1),
        "accuracy_scheduled": jnp.mean((preds == labels) & completed),
        "completed_frac": jnp.mean(completed.astype(jnp.float32)),
        "decisions": fleet["decisions"][:, 0],
        "payload_bytes": fleet["payload_bytes"][:, 0],
        "raw_bytes": float(raw_payload_bytes(t)) * jnp.ones((s,)),
        "stored_uj": fleet["stored_uj"][:, 0],
        "k_trace": fleet["k_trace"][:, 0],
        "alive": fleet["alive"][:, 0],
        "brownout": fleet["brownout"][:, 0],
        "brownout_slots": fleet["brownout_slots"],
        "brownout_events": fleet["brownout_events"],
    }
    if intermittent is not None:
        out.update({
            "it_emit": fleet["it_emit"][:, 0],
            "it_stage": fleet["it_stage"][:, 0],
            "it_full": fleet["it_full"],
            "it_early": fleet["it_early"],
        })
    return out


def seeker_simulate_reference(windows: jnp.ndarray, labels: jnp.ndarray,
                              harvest: jnp.ndarray, *, signatures,
                              qdnn_params, host_params, gen_params,
                              har_cfg: HARConfig,
                              aac_table: AACTable | None = None,
                              costs: EnergyCosts | None = None,
                              n_sensors: int = 3,
                              key: jax.Array | None = None,
                              quant_bits: int = 16):
    """Legacy per-sensor simulation: a Python loop of single-node scans.

    Kept as the semantics oracle for the fleet engine — tests assert
    :func:`seeker_fleet_simulate` reproduces these traces node for node.
    """
    costs = costs or EnergyCosts()
    key = key if key is not None else jax.random.PRNGKey(0)
    n, t, c = windows.shape

    def step(carry, inp):
        state, k = carry
        window, harvested = inp
        k, k1, k2 = jax.random.split(k, 3)
        out = seeker_sensor_step(
            window, state, harvested, signatures=signatures,
            qdnn_params=qdnn_params, har_cfg=har_cfg, aac_table=aac_table,
            costs=costs, key=k1, quant_bits=quant_bits)
        host_logits = seeker_host_step(out, host_params=host_params,
                                       gen_params=gen_params,
                                       har_cfg=har_cfg, key=k2, t=t)
        trace = {"decision": out.decision, "payload": out.payload_bytes,
                 "stored": out.state.stored_uj, "k": out.coreset_k,
                 "logits": host_logits}
        return (out.state, k), trace

    traces = []
    for sidx in range(n_sensors):
        init = (seeker_node_init(), jax.random.fold_in(key, sidx))
        _, tr = jax.lax.scan(step, init, (windows, harvest))
        traces.append(tr)
    # sensor ensemble (paper: host ensembles multiple sensors)
    ens_logits = sum(tr["logits"] for tr in traces) / n_sensors
    preds = jnp.argmax(ens_logits, axis=-1)
    completed = traces[0]["decision"] != DEFER
    return {
        "preds": preds,
        "labels": labels,
        "accuracy_completed": jnp.sum((preds == labels) & completed)
            / jnp.maximum(jnp.sum(completed), 1),
        "accuracy_scheduled": jnp.mean((preds == labels) & completed),
        "completed_frac": jnp.mean(completed.astype(jnp.float32)),
        "decisions": traces[0]["decision"],
        "payload_bytes": traces[0]["payload"],
        "raw_bytes": float(raw_payload_bytes(t)) * jnp.ones((n,)),
        "stored_uj": traces[0]["stored"],
        "k_trace": traces[0]["k"],
    }


# ---------------------------------------------------------------------------
# Coreset wire format (what actually crosses the pod axis)
# ---------------------------------------------------------------------------

class WirePayload(NamedTuple):
    """Quantized cluster-coreset payload as it crosses the wire: int16 center
    codes, int8 radius codes, int8 counts (modelling the paper's 2 B center /
    1 B radius / 4-bit count format, §3.2.2), plus the per-window float
    ranges needed to dequantize on the host side."""

    c_codes: jnp.ndarray    # (B, C, k, 2) int16
    r_codes: jnp.ndarray    # (B, C, k) int8
    n_codes: jnp.ndarray    # (B, C, k) int8
    lo: jnp.ndarray         # (B, 1, 1, 1) center range low
    hi: jnp.ndarray         # (B, 1, 1, 1) center range high
    rhi: jnp.ndarray        # (B, 1, 1) radius range high


def encode_wire_coresets(centers: jnp.ndarray, radii: jnp.ndarray,
                         counts: jnp.ndarray) -> WirePayload:
    """Quantize per-channel cluster coresets for transmission.

    centers (B, C, k, 2), radii (B, C, k), counts (B, C, k) — the batched
    output of :func:`repro.core.coreset.channel_cluster_coresets`.
    """
    lo = jnp.min(centers, axis=(1, 2, 3), keepdims=True)
    hi = jnp.max(centers, axis=(1, 2, 3), keepdims=True)
    c_codes = jnp.round((centers - lo) / jnp.maximum(hi - lo, 1e-9)
                        * 65535.0 - 32768.0).astype(jnp.int16)
    rhi = jnp.max(radii, axis=(1, 2), keepdims=True)
    r_codes = jnp.round(radii / jnp.maximum(rhi, 1e-9) * 255.0 - 128.0
                        ).astype(jnp.int8)
    n_codes = jnp.clip(counts, 0, 15).astype(jnp.int8)
    return WirePayload(c_codes, r_codes, n_codes, lo, hi, rhi)


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


try:                                   # jax.core slimming across versions
    _Tracer = jax.core.Tracer
except AttributeError:                 # pragma: no cover - newest jax only
    from jax._src.core import Tracer as _Tracer


def _is_concrete(x) -> bool:
    """True when ``x`` carries actual values (not a jit/vmap tracer) — value
    validation only runs on the host ingest path, never during tracing."""
    return not isinstance(x, _Tracer)


def decode_wire_coresets(p: WirePayload):
    """Host-side dequantization; returns (centers, radii, counts int32).

    The host queue ingests these payloads from untrusted radio bytes, so the
    decode is defensive: field dtypes and cross-field shapes are validated
    always (static, jit-safe); code-range checks (4-bit counts) additionally
    run whenever the payload is concrete.  Malformed payloads raise
    ``ValueError`` instead of silently dequantizing garbage.
    """
    c_codes, r_codes, n_codes = map(jnp.asarray,
                                    (p.c_codes, p.r_codes, p.n_codes))
    _check(c_codes.dtype == jnp.int16,
           f"wire payload c_codes must be int16, got {c_codes.dtype}")
    _check(r_codes.dtype == jnp.int8,
           f"wire payload r_codes must be int8, got {r_codes.dtype}")
    _check(n_codes.dtype == jnp.int8,
           f"wire payload n_codes must be int8, got {n_codes.dtype}")
    _check(c_codes.ndim >= 2 and c_codes.shape[-1] == 2,
           f"wire payload c_codes must be (..., k, 2) 2-D center codes, "
           f"got shape {c_codes.shape}")
    _check(r_codes.shape == c_codes.shape[:-1],
           f"wire payload r_codes shape {r_codes.shape} does not match "
           f"c_codes {c_codes.shape}")
    _check(n_codes.shape == r_codes.shape,
           f"wire payload n_codes shape {n_codes.shape} does not match "
           f"r_codes {r_codes.shape}")
    for name, f in (("lo", p.lo), ("hi", p.hi), ("rhi", p.rhi)):
        _check(jnp.issubdtype(jnp.asarray(f).dtype, jnp.floating),
               f"wire payload {name} range must be floating, got "
               f"{jnp.asarray(f).dtype}")
    if _is_concrete(n_codes):
        import numpy as np
        nc = np.asarray(n_codes)
        _check(bool((nc >= 0).all() and (nc <= 15).all()),
               f"wire payload counts outside the 4-bit field [0, 15]: "
               f"min {nc.min()}, max {nc.max()}")

    centers = ((c_codes.astype(jnp.float32) + 32768.0) / 65535.0
               * (p.hi - p.lo) + p.lo)
    radii = (r_codes.astype(jnp.float32) + 128.0) / 255.0 * p.rhi
    return centers, radii, n_codes.astype(jnp.int32)


def wire_payload_nbytes(k: int, channels: int) -> int:
    """Bytes the quantized code tensors put on the wire per window (the
    collective_permute operand size, excluding the 3 float range scalars):
    per channel, k x (2-D int16 center + int8 radius + int8 count) — the
    paper's §3.2.2 accounting at the tensor field widths."""
    return channels * cluster_payload_bytes(k, bytes_center=4, bytes_radius=1,
                                            bits_count=8)


# --- byte-level framing: what the host's untrusted ingest actually parses --

_WIRE_MAGIC = 0x5EEC          # "SEEker Coreset"
_WIRE_VERSION = 1
_WIRE_HEADER = 20             # 5 x uint32: magic, version, B, C, k


def wire_payload_to_bytes(p: WirePayload) -> bytes:
    """Serialize a quantized coreset payload to one radio frame: a 20-B
    header (magic, version, B, C, k) followed by the little-endian code
    tensors and float ranges."""
    import numpy as np

    b, c, k, _ = p.c_codes.shape
    head = np.asarray([_WIRE_MAGIC, _WIRE_VERSION, b, c, k], "<u4")
    return b"".join([
        head.tobytes(),
        np.asarray(p.c_codes).astype("<i2").tobytes(),
        np.asarray(p.r_codes).astype("i1").tobytes(),
        np.asarray(p.n_codes).astype("i1").tobytes(),
        np.asarray(p.lo).astype("<f4").tobytes(),
        np.asarray(p.hi).astype("<f4").tobytes(),
        np.asarray(p.rhi).astype("<f4").tobytes(),
    ])


def wire_payload_from_bytes(buf: bytes) -> WirePayload:
    """Parse + validate one radio frame back into a :class:`WirePayload`.

    This is the host queue's trust boundary: buffer length, header fields,
    count codes and range floats are all checked, and any malformed frame
    raises ``ValueError`` with the reason — truncation, bad magic, counts
    outside the 4-bit field, or non-finite dequantization ranges.
    """
    import numpy as np

    buf = bytes(buf)
    _check(len(buf) >= _WIRE_HEADER,
           f"truncated wire frame: {len(buf)} B is shorter than the "
           f"{_WIRE_HEADER}-B header")
    magic, version, b, c, k = np.frombuffer(buf[:_WIRE_HEADER], "<u4")
    _check(magic == _WIRE_MAGIC,
           f"not a Seeker coreset frame (magic 0x{int(magic):X}, "
           f"want 0x{_WIRE_MAGIC:X})")
    _check(version == _WIRE_VERSION,
           f"unsupported wire version {int(version)} (want {_WIRE_VERSION})")
    b, c, k = int(b), int(c), int(k)
    _check(b > 0 and c > 0 and k > 0,
           f"degenerate wire dims B={b}, C={c}, k={k}")
    want = _WIRE_HEADER + 6 * b * c * k + 12 * b
    _check(len(buf) == want,
           f"truncated/oversized wire frame: {len(buf)} B, B={b} C={c} "
           f"k={k} needs {want} B")

    off = _WIRE_HEADER
    def take(count, dtype, shape):
        nonlocal off
        n = count * np.dtype(dtype).itemsize
        arr = np.frombuffer(buf[off:off + n], dtype).reshape(shape)
        off += n
        return arr

    c_codes = take(b * c * k * 2, "<i2", (b, c, k, 2))
    r_codes = take(b * c * k, "i1", (b, c, k))
    n_codes = take(b * c * k, "i1", (b, c, k))
    lo = take(b, "<f4", (b, 1, 1, 1))
    hi = take(b, "<f4", (b, 1, 1, 1))
    rhi = take(b, "<f4", (b, 1, 1))
    _check(bool((n_codes >= 0).all() and (n_codes <= 15).all()),
           f"wire frame counts outside the 4-bit field [0, 15]: "
           f"min {n_codes.min()}, max {n_codes.max()}")
    _check(bool(np.isfinite(lo).all() and np.isfinite(hi).all()
                and np.isfinite(rhi).all()),
           "wire frame dequantization ranges are not finite")
    _check(bool((hi >= lo).all()),
           "wire frame center range has hi < lo")
    return WirePayload(jnp.asarray(c_codes), jnp.asarray(r_codes),
                       jnp.asarray(n_codes), jnp.asarray(lo),
                       jnp.asarray(hi), jnp.asarray(rhi))


# ---------------------------------------------------------------------------
# Sampling-coreset wire format (the D4 payload: samples + GAN conditioning)
# ---------------------------------------------------------------------------

class WireSamplePayload(NamedTuple):
    """Quantized importance-sampling payload on the wire: int8 time indices
    (1 B, paper §3.2.2), int16 value codes (2 B per channel) with the
    per-window dequantization range, and the first/second moments that
    condition the recovery GAN (paper A.1) — carried as floats like the
    cluster format's range scalars, accounted at the paper's 2-B width."""

    idx: jnp.ndarray        # (B, m) int8 — selected time indices
    v_codes: jnp.ndarray    # (B, m, C) int16 — quantized sample values
    lo: jnp.ndarray         # (B, 1, 1) value range low
    hi: jnp.ndarray         # (B, 1, 1) value range high
    mean: jnp.ndarray       # (B, C) window mean (GAN conditioning)
    var: jnp.ndarray        # (B, C) window variance (GAN conditioning)


def encode_wire_samples(indices: jnp.ndarray, values: jnp.ndarray,
                        mean: jnp.ndarray, var: jnp.ndarray
                        ) -> WireSamplePayload:
    """Quantize batched sampling coresets for transmission.

    indices (B, m) int, values (B, m, C), mean/var (B, C) — the batched
    fields of :class:`repro.core.coreset.SamplingCoreset`.  Indices must fit
    the int8 wire field (window length < 128 — the paper's windows are 60).
    """
    if _is_concrete(indices):
        import numpy as np
        ix = np.asarray(indices)
        _check(bool((ix >= 0).all() and (ix <= 127).all()),
               f"sample indices outside the int8 wire field [0, 127]: "
               f"min {ix.min()}, max {ix.max()}")
    lo = jnp.min(values, axis=(1, 2), keepdims=True)
    hi = jnp.max(values, axis=(1, 2), keepdims=True)
    v_codes = jnp.round((values - lo) / jnp.maximum(hi - lo, 1e-9)
                        * 65535.0 - 32768.0).astype(jnp.int16)
    return WireSamplePayload(indices.astype(jnp.int8), v_codes, lo, hi,
                             mean.astype(jnp.float32),
                             var.astype(jnp.float32))


def decode_wire_samples(p: WireSamplePayload):
    """Host-side dequantization; returns (indices int32, values, mean, var).
    Defensive like :func:`decode_wire_coresets`: dtype/shape always checked,
    index-range checks when the payload is concrete."""
    idx, v_codes = jnp.asarray(p.idx), jnp.asarray(p.v_codes)
    _check(idx.dtype == jnp.int8,
           f"sample payload idx must be int8, got {idx.dtype}")
    _check(v_codes.dtype == jnp.int16,
           f"sample payload v_codes must be int16, got {v_codes.dtype}")
    _check(v_codes.ndim >= 1 and idx.shape == v_codes.shape[:-1],
           f"sample payload idx shape {idx.shape} does not match v_codes "
           f"{v_codes.shape}")
    mean, var = jnp.asarray(p.mean), jnp.asarray(p.var)
    _check(mean.shape[-1] == v_codes.shape[-1]
           and var.shape[-1] == v_codes.shape[-1],
           f"sample payload moments {mean.shape}/{var.shape} do not match "
           f"channel dim of v_codes {v_codes.shape}")
    if _is_concrete(idx):
        import numpy as np
        ix = np.asarray(idx)
        _check(bool((ix >= 0).all()),
               f"sample payload has negative time indices (min {ix.min()})")
    values = ((v_codes.astype(jnp.float32) + 32768.0) / 65535.0
              * (p.hi - p.lo) + p.lo)
    return idx.astype(jnp.int32), values, mean, var


def wire_sample_nbytes(m: int, channels: int) -> int:
    """Bytes a sampling payload puts on the wire per window: m x (1-B index
    + 2-B value per channel) + the 2-B mean/var moments per channel (paper
    §3.2.2 / A.1 accounting)."""
    return sampling_payload_bytes(m, channels=channels)


# ---------------------------------------------------------------------------
# Distributed edge-host step (pod-axis disaggregation, for the dry-run)
# ---------------------------------------------------------------------------

def _edge_encode_coresets(win: jnp.ndarray, k: int) -> WirePayload:
    """Edge half of a serving tier: per-channel cluster coresets for the
    LOCAL window batch (B, T, C), quantized to the wire format — the only
    tensors that ever cross the mesh."""
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=k, iters=4))(win)
    return encode_wire_coresets(centers, radii, counts)


def edge_host_serve_step(windows: jnp.ndarray, *, signatures, qdnn_params,
                         host_params, gen_params, har_cfg: HARConfig,
                         mesh, k: int = 12, quant_bits: int = 16,
                         key: jax.Array | None = None):
    """Paired-tier serving across the "pod" mesh axis.

    Each pod is the *edge* for its own sensor batch (memoization + quantized
    DNN + cluster-coreset construction) and the *host* for its peer pod: the
    quantized coreset payload crosses pods via ``collective_permute`` —
    coreset bytes on the wire instead of raw windows (8.9x fewer, paper C3).

    windows: (B, T, C) globally, sharded over ("pod", "data") on B.
    Returns (B, n_classes) host logits for the *peer's* windows, in the peer
    pod's shards.

    The host half (decode -> batched recovery -> DNN) is the host-tier
    subsystem's :func:`repro.host.server.recover_infer_batch` — this
    function only models the *edge* side and the collective.
    """
    from jax.sharding import PartitionSpec as P

    from ..host.server import recover_infer_batch

    key = key if key is not None else jax.random.PRNGKey(0)
    t = windows.shape[1]

    def tier(win):
        # --- edge side: local sensors, quantized wire format (2B centers /
        # 1B radii / 4b counts modelled as int16/int8/int8 tensors: what
        # collective_permute actually moves) ---------------------------------
        payload = _edge_encode_coresets(win, k)

        # --- cross-pod transfer: coreset payload only ----------------------
        npods = jax.lax.psum(1, "pod")
        perm = [(i, (i + 1) % npods) for i in range(npods)]
        payload = WirePayload(*(jax.lax.ppermute(f, "pod", perm)
                                for f in payload))

        # --- host tier: recover the peer's coresets and infer ---------------
        return recover_infer_batch(
            payload, host_params,
            jax.random.split(key, payload.c_codes.shape[0]), t)

    from ..sharding import shard_map_compat
    fn = shard_map_compat(
        tier, mesh,
        in_specs=(P(("pod", "data")) if "pod" in mesh.shape else P("data"),),
        out_specs=P(("pod", "data")) if "pod" in mesh.shape else P("data"),
        axis_names=frozenset(a for a in ("pod", "data") if a in mesh.shape))
    return fn(windows)


def fleet_serve_step(windows: jnp.ndarray, *, host_params,
                     har_cfg: HARConfig, mesh, k: int = 12,
                     key: jax.Array | None = None,
                     host_state=None, serve_cfg=None, gen_params=None,
                     alive: jnp.ndarray | None = None,
                     engine_alive: jnp.ndarray | None = None,
                     per_shard_host: bool = False):
    """Sharded-fleet edge→host tier: gather ONLY coreset payloads to the host.

    The companion to :func:`repro.serving.fleet.seeker_fleet_simulate_sharded`
    for the offload decisions (D3): each shard builds per-channel cluster
    coresets for its *local* node tile and quantizes them to the compact wire
    format; the int16/int8 code tensors are then ``all_gather``-ed over the
    fleet's node axes (minor axis first, so global node order is preserved)
    to the host tier.  Raw windows and node state never leave their shard —
    only coreset bytes cross the mesh, reproducing the paper's edge-host
    communication asymmetry at the collective level.

    The host work is delegated to the host-tier subsystem (:mod:`repro.host`)
    in one of three modes:

    * default — the gathered batch runs straight through
      :func:`repro.host.server.recover_infer_batch` (decode -> batched
      recovery -> DNN), replicated, returning per-node logits;
    * ``host_state``/``serve_cfg`` given — the gathered payloads are
      *enqueued* into the host server (QoS deadline stamping, EDF microbatch
      assembly, recovery cache) and served at ``serve_cfg.batch_size``;
      returns the evolved ``host_state`` and the round's
      :class:`repro.host.server.SlotOutput` instead of raw logits, so a
      serving loop carries queue backlog / cache / ensemble across rounds.
      ``alive`` (the round's churn mask) keeps dead nodes' payloads out of
      the queue — a browned-out node produces no radio frame;
    * ``per_shard_host=True`` (with ``host_state``/``serve_cfg``) — the
      ROADMAP multi-host shape: NO gather at all.  Each shard runs its own
      host server (queue/EDF/cache) over the payloads of its local node
      tile; ``host_state`` must be the stacked per-shard carry from
      :func:`repro.host.server.host_server_init_stacked` (one server per
      shard, leading axis = the mesh quantum).  Only the QoS counters cross
      shards, psum'd into the returned ``qos`` dict — exactly how fleet
      aggregates cross shards in the simulator.

    Args:
        windows: (N, T, C) fleet sensor windows, one per node.  N that does
            not divide the mesh quantum is padded with zero windows and the
            padding is sliced off before the host tier sees it.
        mesh: mesh whose FLEET_RULES node axes carry the fleet.
        host_state: optional :class:`repro.host.server.HostServerState` to
            feed (requires ``serve_cfg`` and ``gen_params``); stacked
            per-shard when ``per_shard_host``.
        alive: optional (N,) bool — this round's *caller* churn mask (queue
            modes only): dead nodes' payloads never enqueue and transmit no
            wire bytes.
        engine_alive: optional (N,) bool — one slot of the fleet engine's
            EMITTED alive trace (``res["alive"][t]``), which already folds
            endogenous brown-outs into the exogenous trace.  Composes with
            ``alive`` by AND, so the host's per-round mask comes from the
            simulated physics, not just the caller: a node the engine
            browned out produces no radio frame either.  Queue modes only,
            like ``alive``.

    Returns dict: ``wire_bytes`` — total quantized payload bytes the alive
    fleet put on the wire, ``raw_bytes`` — the raw-window equivalent (the
    communication avoided), plus ``host_logits`` (N, L) (default mode) or
    ``host_state``/``slot_output`` (queue modes; per-shard mode adds the
    psum'd ``qos`` counter dict).
    """
    from ..host.server import recover_infer_batch, serve_fleet_payloads
    from ..sharding import node_mesh_axes, shard_map_compat

    key = key if key is not None else jax.random.PRNGKey(0)
    n, t, c = windows.shape
    axis_names, quantum = node_mesh_axes(mesh)
    if not axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has none of the FLEET_RULES node axes")
    pad = (-n) % quantum
    if pad:
        windows = jnp.pad(windows, ((0, pad), (0, 0), (0, 0)))
    if engine_alive is not None:
        engine_alive = jnp.asarray(engine_alive, bool)
        if engine_alive.shape != (n,):
            raise ValueError(f"engine_alive must be (N,)=({n},), got "
                             f"{engine_alive.shape}")
        alive = engine_alive if alive is None else \
            jnp.asarray(alive, bool) & engine_alive
    if alive is not None:
        alive = jnp.asarray(alive, bool)
        if alive.shape != (n,):
            raise ValueError(f"alive must be (N,)=({n},), got {alive.shape}")
        if host_state is None:
            raise ValueError("alive/engine_alive is a queue-mode argument: "
                             "without a host_state there is no queue to "
                             "keep dead nodes out of")

    if per_shard_host:
        return _fleet_serve_per_shard(
            windows, n=n, t=t, c=c, k=k, mesh=mesh, axis_names=axis_names,
            quantum=quantum, host_params=host_params,
            host_state=host_state, serve_cfg=serve_cfg,
            gen_params=gen_params, alive=alive, key=key)

    def tier(win, kk):
        # --- edge side: coresets + wire quantization for LOCAL nodes only --
        payload = _edge_encode_coresets(win, k)

        # --- node axis -> host tier: the quantized codes are ALL that moves.
        # Gather the minor mesh axis first so the concatenated node order
        # matches the global (pod-major) layout of the padded fleet.
        for ax in reversed(axis_names):
            payload = WirePayload(*(jax.lax.all_gather(f, ax, axis=0,
                                                       tiled=True)
                                    for f in payload))

        if host_state is None:
            # --- host tier, direct mode: decode, recover, infer ------------
            return recover_infer_batch(
                payload, host_params,
                jax.random.split(kk, payload.c_codes.shape[0]), t)
            # -> (N+pad, L) replicated
        return payload               # -> gathered wire payload, replicated

    from jax.sharding import PartitionSpec as P
    out_specs = P() if host_state is None else WirePayload(*([P()] * 6))
    fn = shard_map_compat(tier, mesh, in_specs=(P(axis_names), P()),
                          out_specs=out_specs,
                          axis_names=frozenset(axis_names))
    n_tx = n if alive is None else int(jnp.sum(alive))   # frames transmitted
    out = {
        "wire_bytes": n_tx * wire_payload_nbytes(k, c),
        "raw_bytes": n * raw_payload_bytes(t) * c,
    }
    if host_state is None:
        out["host_logits"] = fn(windows, key)[:n]
        return out

    # --- queue mode: the gathered payloads FEED the host subsystem ---------
    if serve_cfg is None or gen_params is None:
        raise ValueError("fleet_serve_step host_state mode needs serve_cfg "
                         "and gen_params")
    payload = fn(windows, key)
    payload = WirePayload(*(f[:n] for f in payload))   # drop inert pad nodes
    state, slot_out = serve_fleet_payloads(
        host_state, payload, jnp.arange(n, dtype=jnp.int32), cfg=serve_cfg,
        host_params=host_params, gen_params=gen_params, base_key=key,
        mask=alive)
    out["host_state"] = state
    out["slot_output"] = slot_out
    return out


def _fleet_serve_per_shard(windows, *, n, t, c, k, mesh, axis_names,
                           quantum, host_params, host_state, serve_cfg,
                           gen_params, alive, key):
    """``fleet_serve_step``'s per-shard host mode (flag-gated).

    Each shard is its own host: local coreset encode feeds the shard's OWN
    queue/EDF/cache server — no payload gather, no replicated host work.
    The payload path is shard-local end to end; only the scalar QoS
    counters are psum'd (the multi-host QoS aggregation the ROADMAP names),
    so the collective footprint of a serve round drops from
    O(N · payload bytes) to O(1).
    """
    import dataclasses as _dc

    from jax.sharding import PartitionSpec as P

    from ..host.server import (SlotOutput, _slot_body, cluster_entries,
                               host_telemetry_spec)
    from ..obs import metrics_psum
    from ..sharding import shard_map_compat

    if serve_cfg is None or gen_params is None or host_state is None:
        raise ValueError("fleet_serve_step per_shard_host mode needs "
                         "host_state (stacked: host_server_init_stacked), "
                         "serve_cfg and gen_params")
    lead = jax.tree_util.tree_leaves(host_state)[0].shape[0]
    if lead != quantum:
        raise ValueError(
            f"per_shard_host needs one host server per shard: host_state "
            f"is stacked for {lead} hosts, mesh quantum is {quantum} "
            f"(use host_server_init_stacked(cfg, {quantum}))")
    n_pad = windows.shape[0]
    n_local = n_pad // quantum
    if n_local > serve_cfg.queue_capacity:
        raise ValueError(
            f"per-shard ingest lane of {n_local} nodes exceeds "
            f"queue_capacity={serve_cfg.queue_capacity}; raise "
            f"HostServeConfig.queue_capacity")
    # service rate: enough EDF microbatches to cover the LOCAL tile
    cfg = _dc.replace(serve_cfg,
                      batches_per_slot=-(-n_local // serve_cfg.batch_size))
    # pad nodes (global index >= n) and dead nodes never enqueue
    mask_full = jnp.arange(n_pad) < n
    if alive is not None:
        mask_full = mask_full & jnp.pad(alive, (0, n_pad - n))
    node_ids = jnp.arange(n_pad, dtype=jnp.int32)

    def tier(win, st_tile, nids, m, kk):
        # local edge encode -> the shard's own host server; nothing but the
        # psum'd QoS counters ever leaves the shard
        payload = _edge_encode_coresets(win, k)
        entries = cluster_entries(payload, cfg.m)
        state = jax.tree_util.tree_map(lambda a: a[0], st_tile)
        new_state, slot_out = _slot_body(
            cfg, state, entries, nids, m, host_params, gen_params, kk)
        qos = {
            name: jax.lax.psum(getattr(new_state, name), axis_names)
            for name in ("served", "deadline_misses")
        }
        qos["drops_overflow"] = jax.lax.psum(
            new_state.queue.drops_overflow, axis_names)
        if cfg.telemetry:
            # fleet-wide registry lanes: per-shard host lanes psum'd
            # component-wise, exactly like the fleet engine's — the
            # multi-host QoS-percentile substrate (histograms stay exact
            # int32 across any shard layout)
            qos["telemetry"] = metrics_psum(
                host_telemetry_spec(cfg), new_state.metrics, axis_names)
        return (jax.tree_util.tree_map(lambda a: a[None], new_state),
                slot_out, qos)

    nodes = P(axis_names)
    state_specs = jax.tree_util.tree_map(lambda _: nodes, host_state)
    qos_specs = {"served": P(), "deadline_misses": P(),
                 "drops_overflow": P()}
    if serve_cfg.telemetry:
        qos_specs["telemetry"] = {
            name: P() for name in host_telemetry_spec(serve_cfg).names()}
    fn = shard_map_compat(
        tier, mesh,
        in_specs=(nodes, state_specs, nodes, nodes, P()),
        out_specs=(state_specs,
                   SlotOutput(*([nodes] * len(SlotOutput._fields))),
                   qos_specs),
        axis_names=frozenset(axis_names))
    new_state, slot_out, qos = fn(windows, host_state, node_ids, mask_full,
                                  key)
    telemetry = qos.pop("telemetry", None)
    n_tx = n if alive is None else int(jnp.sum(alive))
    out = {
        "wire_bytes": n_tx * wire_payload_nbytes(k, c),
        "raw_bytes": n * raw_payload_bytes(t) * c,
        "host_state": new_state,
        "slot_output": slot_out,
        "qos": {k_: int(v) for k_, v in qos.items()},
    }
    if telemetry is not None:
        out["telemetry"] = telemetry
    return out
