"""Fleet-scale batched Seeker simulator, single-device and sharded.

The single-node simulation (:func:`repro.serving.edge_host.seeker_simulate`)
models one EH-WSN; production serving means *fleets* — thousands of
independent sensor nodes (n_sensors x n_devices), each with its own
supercapacitor charge, harvest modality, predictor history, and memoization
phase.  :func:`seeker_fleet_simulate` runs all of them in ONE jitted
``lax.scan`` over time:

* the carry is a *stacked* ``SeekerNodeState`` (leading node axis N) plus a
  per-node PRNG key array — node ``i``'s stream is ``fold_in(key, i)``, so a
  fleet of N nodes is bit-compatible with N independent single-node runs;
* inside the step, the memoization hot path runs once for the whole fleet
  through the batched :func:`repro.kernels.signature_corr_op`
  ((N, T, C) x (L, T, C) -> (N, L); Pallas MXU kernel on TPU, the validated
  jnp oracle elsewhere), and the rest of the paper's Fig.-8 flow is
  ``jax.vmap`` of the per-node step — no Python loop over nodes anywhere;
* the scan carry is donated to the jitted run, so the stacked node state is
  updated in place across time steps instead of being reallocated.

:func:`seeker_fleet_simulate_sharded` scales the node axis past one device:
the stacked state, per-node keys, harvest traces and (N, S, T, C) window
streams are split over the mesh axes the ``"nodes"`` logical axis resolves
to (:data:`repro.sharding.FLEET_RULES`: ("pod", "data")) via
``shard_map_compat``, and the *entire* scan runs inside the manual region —
node state never leaves its shard.  Only fleet-level aggregates (bytes on
wire, the decision histogram, accuracy counts) cross shards, as ``psum``
scalars.  Fleets that don't divide the mesh quantum are padded with *inert*
nodes (zero harvest, masked out of every aggregate) and the padding is
sliced off the returned traces, so sharded results are bit-identical to the
single-device engine for any N.

Harvest traces are per-node (shape (N, S)): heterogeneous energy income is
the point of fleet simulation — per-node energy dynamics diverge (Gobieski et
al., arXiv:1810.07751), and the Seeker companion evaluation (arXiv:2204.13106)
runs exactly such heterogeneous wearable fleets.

**Churn** (node dropout/rejoin): harvested fleets are intermittent — nodes
brown out and rejoin mid-deployment.  Both engines accept an ``alive``
(N, S) bool trace (:func:`repro.core.energy.fleet_alive_traces`): in a dead
slot a node harvests nothing, holds its state *frozen* (supercapacitor
charge, predictor history, AAC continuity AND its PRNG stream), and emits
DEFER with a zero payload; on rejoin it continues exactly where it stopped —
no re-padding, no re-tracing, no shape change.  Every fleet aggregate
(bytes on wire, decision histogram, completion, accuracy) respects the
time-varying alive mask, not just the static padding mask.  An all-True
``alive`` is bitwise-identical to not passing one.

**Endogenous brown-out** (``brownout=BrownoutConfig(...)``): churn driven by
the simulated physics instead of an input array.  The decision ladder
switches to strict store-and-execute accounting — a decision must be
payable from ``stored + harvested_this_slot`` alone (the forecast still
ranks AAC's k but can no longer mint energy, and
:func:`repro.core.energy.supercap_step_direct` never clip-forgives debt) —
and the per-slot alive lane becomes ``exogenous_trace ∧ ¬browned_out``,
where ``browned_out`` lives in the scan carry and flips via supercap
hysteresis: below ``off_uj`` the node powers down (browned-out slots reuse
the dead-slot lane above, except the harvester keeps trickle-charging the
supercap), and at ``restart_uj`` it reboots into its frozen state.  The
engines emit the resulting ``alive``/``brownout`` (S, N) lanes plus
``brownout_slots``/``brownout_events`` counters (psum'd in the sharded
engine; padding nodes are exogenously dead and never brown "in").
``brownout=None`` keeps today's engines bitwise.

**Streaming** (:func:`seeker_fleet_simulate_streamed`): window streams are
fed to the scan in ``(chunk,)``-slot segments through the ``state0`` /
``node_keys`` resume contract (documented in docs/RESUME_CONTRACT.md), so
peak window memory is O(N·chunk·T·C) instead of O(N·S·T·C) while traces
stay bitwise-equal to one long run.

**Telemetry** (``telemetry=True`` or a :class:`repro.obs.MetricsSpec`): the
observability lane.  A metrics pytree (:func:`fleet_telemetry_spec`:
exact-int counters as normalized (2,) int32 ``[hi, lo]`` pairs, the
categorical decision histogram, a stored-energy gauge) rides the scan carry
of all three engines, updated per slot from the same masked quantities the
post-scan aggregates use; the sharded engine ``psum``-s the lanes
component-wise (int adds are associative, so lanes are *bitwise-equal*
across single-device, sharded and streamed runs), and the streamed driver
chains segments through ``telemetry_state0`` /
``res["telemetry"]`` (:func:`repro.obs.metrics_merge`) exactly like the
rest of the resume contract.  ``telemetry=None`` (default) keeps every
engine bitwise-identical to the untelemetered path — observation never
perturbs simulation.

**Intermittent inference** (``intermittent=IntermittentConfig(...)``): the
partial-inference lane.  Slots the strict ladder would DEFER instead run as
many energy-quantized stages of the on-node quantized DNN as ``stored +
harvested`` affords (:meth:`repro.core.energy.EnergyCosts.stage_costs`),
suspending the staged activations *in the scan carry*
(:class:`repro.serving.edge_host.IntermittentState` — a fourth carry lane
riding the ``state0``/``node_keys`` resume contract bitwise through brown-
outs and streamed segment boundaries).  An in-flight inference resumes
before new work starts; completion transmits at full depth (D8), and when
the remaining stages are unaffordable a confidence-tagged early-exit result
from the auxiliary head (D7) replaces the freeze-and-lose DEFER.  The lane
requires ``aux_params`` (:func:`repro.models.har.har_aux_init`) and
switches the ladder to strict store-and-execute accounting like
``brownout`` does.  ``intermittent=None`` keeps all three engines bitwise.

**One scan body, registered lanes** (:data:`repro.serving.FLEET_LANES`):
everything above rides a single typed carry
(:class:`repro.serving.FleetCarry`) whose fields are owned by lane
registrations in ``fleet_lanes.py`` — each lane declares its init, freeze
kind, resume keys, trace/counter/aggregate outputs and telemetry in ONE
place, and all three drivers are thin shells over the same registered scan
body (``_build_fleet_run``).  A disabled lane contributes an *empty*
pytree to the carry, so ``lane=None`` is the lane-less engine by
construction; the streamed driver derives the keys it concatenates/sums
from the registry rather than hand-listing them.  The contract is stated
in docs/RESUME_CONTRACT.md and enforced by ``tests/test_lane_conformance``
+ ``tests/test_resume_contract``.

**Heterogeneous task fleets** (``task=TaskLaneConfig(...)`` or an explicit
``tasks`` (N,) id array): the first lane shipped *through* the registry.
One fleet mixes workloads — HAR wearables and bearing-vibration monitors —
with static per-node task ids that scale the ladder's per-stage energy
costs, select stacked per-task host weights
(:func:`repro.serving.fleet_lanes.stack_task_params`, gathered per node),
and split the psum'd aggregates into ``completed_by_task`` /
``deadline_miss_by_task`` / ``correct_by_task`` (+ ``accuracy_by_task``
when labels are given).  Task ids are static per node, so XLA
constant-folds the switches; ``task=None`` keeps all three engines
bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.aac import AACTable
from ..core.coreset import raw_payload_bytes
from ..core.decision import (D4_SAMPLING, D6_PARTIAL, DEFER,
                             N_INTERMITTENT_DECISIONS, IntermittentConfig)
from ..core.energy import (BrownoutConfig, EnergyCosts, predictor_init,
                           supercap_step)
from ..kernels.ops import signature_corr_op
from ..models.har import HARConfig, quantize_params
from ..obs import (MetricsSpec, categorical_counts, compile_event,
                   int_pair_sum, int_pair_total, metrics_init,
                   metrics_merge, metrics_psum, spec_union)
from ..obs import trace as obs_trace
from ..sharding import make_mesh_compat, node_mesh_axes, shard_map_compat
from .edge_host import (IntermittentState, SeekerNodeState,
                        intermittent_fleet_init, intermittent_lane_step,
                        seeker_host_step, seeker_sensor_step_given_corr)
from .fleet_lanes import (FLEET_LANES, FleetCarry, TaskLaneConfig,
                          fleet_counter_keys, fleet_task_assignment,
                          fleet_telemetry_lanes, fleet_trace_keys,
                          stack_task_params)

__all__ = ["fleet_node_init", "fleet_node_keys", "fleet_telemetry_spec",
           "seeker_fleet_simulate", "seeker_fleet_simulate_sharded",
           "seeker_fleet_simulate_streamed", "wire_bytes_exact"]

N_DECISIONS = DEFER + 1   # D0..D4 + DEFER: bins of the fleet histogram


def _active_lanes(intermittent: IntermittentConfig | None = None,
                  task: TaskLaneConfig | None = None,
                  brownout: BrownoutConfig | None = None) -> frozenset:
    """The engine build's active-lane tag set, from its lane configs.  The
    ``task:K`` tag carries the task count so pure functions of the set (the
    telemetry spec) can size per-task lanes."""
    active = set()
    if brownout is not None:
        active.add("brownout")
    if intermittent is not None:
        active.add("intermittent")
    if task is not None:
        active.update({"task", f"task:{task.n_tasks}"})
    return frozenset(active)


def fleet_telemetry_spec(intermittent: bool = False,
                         n_tasks: int = 0) -> MetricsSpec:
    """The fleet engines' registry lanes (:mod:`repro.obs.registry`),
    DERIVED from the lane registry: each :class:`~repro.serving.fleet_lanes.
    FleetLane` declares the telemetry lanes it owns (node state owns
    ``fleet.wire_bytes``/``fleet.completed``/``fleet.alive_slots``/
    ``fleet.stored_uj``/``fleet.decisions``, brown-out owns
    ``fleet.brownout_*``, the intermittent lane ``fleet.it_*``, the task
    lane ``fleet.task_completed``), and this spec is their
    :func:`repro.obs.spec_union` — spec and carry cannot drift apart.

    Shared by all three engines, so a lane name means the same masked
    quantity everywhere; all lanes are int32 — counter pairs and categorical
    histograms are associative, which is what makes them *bitwise-equal*
    across single-device, sharded and streamed runs (float sums are not
    order-independent and stay out of the parity set)."""
    active = set()
    if intermittent:
        active.add("intermittent")
    if n_tasks:
        active.update({"task", f"task:{n_tasks}"})
    return _fleet_telemetry_spec_cached(frozenset(active))


@functools.lru_cache(maxsize=8)
def _fleet_telemetry_spec_cached(active: frozenset) -> MetricsSpec:
    # memoized on the NORMALIZED lane set, so fleet_telemetry_spec(False)
    # and fleet_telemetry_spec(False, 0) return the identical object — the
    # engines' result["telemetry_spec"] is comparable by `is`
    return spec_union(fleet_telemetry_lanes(active))


def _resolve_telemetry(telemetry,
                       intermittent: IntermittentConfig | None,
                       task: TaskLaneConfig | None = None
                       ) -> MetricsSpec | None:
    """``True`` -> the registry-derived lane set for this build's active
    lanes; a :class:`MetricsSpec` passes through (it must declare the fleet
    lanes); ``None`` stays off."""
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True:
        return fleet_telemetry_spec(intermittent is not None,
                                    task.n_tasks if task is not None else 0)
    if not isinstance(telemetry, MetricsSpec):
        raise TypeError(f"telemetry must be None/True/MetricsSpec, "
                        f"got {type(telemetry).__name__}")
    return telemetry


def _update_fleet_lanes(spec: MetricsSpec, metrics: dict, out_trace: dict,
                        exo_alive_t: jnp.ndarray, active: frozenset,
                        tasks: jnp.ndarray | None = None) -> dict:
    """Advance every registry lane by one slot by folding each registered
    lane's ``telemetry_update`` over the metrics pytree, from the engine's
    MASKED ``out_trace`` quantities — the same post-mask values the
    post-scan aggregates reduce, so carry lanes and aggregates cannot drift
    apart.  Lane updates touch disjoint name-keyed entries, so registration
    order never changes values.  Padding nodes are exogenously dead
    (``alive`` False, ``brownout`` flag frozen False), so they contribute
    to no lane without any extra mask."""
    m = metrics
    for ln in FLEET_LANES:
        if ln.telemetry_update is not None and ln.active(active):
            m = ln.telemetry_update(spec, m, out_trace,
                                    exo_alive_t=exo_alive_t, active=active,
                                    tasks=tasks)
    return m


def fleet_node_init(n_nodes: int, predictor_window: int = 8,
                    initial_uj: float = 50.0) -> SeekerNodeState:
    """Stacked state for ``n_nodes`` nodes (leading node axis on every leaf)."""
    return SeekerNodeState(
        stored_uj=jnp.full((n_nodes,), initial_uj, jnp.float32),
        predictor=predictor_init(predictor_window, batch=n_nodes),
        prev_label=jnp.zeros((n_nodes,), jnp.int32))


def fleet_node_keys(key: jax.Array, n_nodes: int) -> jnp.ndarray:
    """The PRNG lane's init: node ``i``'s stream is ``fold_in(key, i)``, so
    a fleet of N nodes is bit-compatible with N independent single-node
    runs (and with any shard layout of the same fleet)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n_nodes))


def _make_fleet_step(har_cfg: HARConfig, costs: EnergyCosts, quant_bits: int,
                     k_max: int, m_samples: int, corr_threshold: float,
                     shared_stream: bool, t: int, node_block: int | None,
                     brownout: BrownoutConfig | None,
                     intermittent: IntermittentConfig | None = None,
                     telemetry: MetricsSpec | None = None,
                     task: TaskLaneConfig | None = None):
    """One fleet time slot, shared VERBATIM by the single-device scan and the
    per-shard scan inside ``shard_map`` — the sharded engine sees exactly this
    computation on its local node tile.

    ``node_block``: XLA lowers matmuls/convs differently for different batch
    shapes, so a node's float results can drift ~1e-7 between a (N,) batch
    and a (N/d,) shard tile.  With ``node_block`` set, the per-slot fleet
    math runs as a ``lax.map`` over fixed-(node_block,) microbatches — the
    mapped body is compiled ONCE at a batch shape independent of fleet size
    or shard layout, so sharded and unsharded runs are bit-identical.
    ``None`` keeps the one-shot full-batch vmap (fastest; bitwise only for
    integer/energy traces across layouts).

    ``intermittent``: the partial-inference lane.  When set, the scan carry
    gains a stacked :class:`repro.serving.edge_host.IntermittentState` and
    the per-slot inputs gain the global slot index; the lane runs INSIDE
    ``block_body`` so its conv/matmul stages see the same microbatch shapes
    as the ladder (bitwise across shard layouts under a common
    ``node_block``).  The lane's state obeys the same ``keep()`` freeze as
    the rest of the carry — a browned-out or dead node's suspended
    activations survive untouched until it rejoins, which is exactly the
    suspend-across-brown-out semantics."""

    strict = brownout is not None or intermittent is not None

    def block_body(state, keys, it, tasks_b, win_t, harv_t, slot, signatures,
                   qdnn_params, host_params, gen_params, aac_table,
                   aux_params):
        # same split discipline as the single-node scan:
        # carry, sensor, host
        ks = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)  # (B,3,2)

        # memoization hot path: one batched signature-bank correlation for
        # the whole (local) fleet — under shard_map this is the (N/d, L)
        # tile, so the Pallas/ref kernel runs per-shard with no collectives
        corr = signature_corr_op(win_t, signatures)       # (B, L)

        if task is None:
            out = jax.vmap(
                lambda w, st, h, co, kk: seeker_sensor_step_given_corr(
                    w, st, h, co, qdnn_params=qdnn_params, har_cfg=har_cfg,
                    aac_table=aac_table, costs=costs, key=kk, k_max=k_max,
                    m_samples=m_samples, quant_bits=quant_bits,
                    corr_threshold=corr_threshold, strict_energy=strict)
            )(win_t, state, harv_t, corr, ks[:, 1])
        else:
            # task lane: each node's WHOLE cost ladder scales by its task's
            # declared factor — a separate vmap variant so ``task=None``
            # keeps the exact pre-lane jaxpr
            scale = jnp.asarray(task.cost_scale, jnp.float32)[tasks_b]
            out = jax.vmap(
                lambda w, st, h, co, kk, cs: seeker_sensor_step_given_corr(
                    w, st, h, co, qdnn_params=qdnn_params, har_cfg=har_cfg,
                    aac_table=aac_table, costs=costs, key=kk, k_max=k_max,
                    m_samples=m_samples, quant_bits=quant_bits,
                    corr_threshold=corr_threshold, strict_energy=strict,
                    cost_scale=cs)
            )(win_t, state, harv_t, corr, ks[:, 1], scale)
        if intermittent is not None:
            # the lane overrides engaged slots AFTER the ladder: in-flight
            # inferences resume before new work, DEFER slots become staged
            # progress / early exits.  Quantize the backbone once per slot.
            qp = quantize_params(qdnn_params, quant_bits)
            reserve = brownout.off_uj if brownout is not None else 0.0
            if task is None:
                lane = jax.vmap(
                    lambda w, st, h, dec, itn: intermittent_lane_step(
                        w, st, h, dec, itn, slot, qp=qp,
                        aux_params=aux_params, har_cfg=har_cfg, costs=costs,
                        quant_bits=quant_bits, cfg=intermittent,
                        reserve_uj=reserve)
                )(win_t, state, harv_t, out.decision, it)
            else:
                lane = jax.vmap(
                    lambda w, st, h, dec, itn, cs: intermittent_lane_step(
                        w, st, h, dec, itn, slot, qp=qp,
                        aux_params=aux_params, har_cfg=har_cfg, costs=costs,
                        quant_bits=quant_bits, cfg=intermittent,
                        reserve_uj=reserve, cost_scale=cs)
                )(win_t, state, harv_t, out.decision, it, scale)
            eng = lane.engaged
            lane_state = SeekerNodeState(
                stored_uj=jnp.where(eng, lane.stored_uj,
                                    out.state.stored_uj),
                predictor=out.state.predictor,
                prev_label=jnp.where(eng, lane.prev_label,
                                     out.state.prev_label))
            # label_or_neg = -1 on engaged slots: the host's one_hot(-1)
            # contributes zeros, so D6/D7/D8 slots put nothing into the
            # slot-aligned ensemble (the emitted result belongs to the
            # SOURCE slot; it is scored through the it_* traces instead)
            out = out._replace(
                decision=jnp.where(eng, lane.decision, out.decision),
                payload_bytes=jnp.where(eng, lane.payload_bytes,
                                        out.payload_bytes),
                label_or_neg=jnp.where(eng, -1, out.label_or_neg),
                state=lane_state)
            new_it = lane.state
        else:
            new_it = None
        if task is not None and task.per_task_host:
            # kind-switched host recovery/DNN: host_params arrives STACKED
            # on a leading task axis (stack_task_params); each node's host
            # step gathers its task's tree inside the vmap, so the compiled
            # shapes stay task-independent
            host_logits = jax.vmap(
                lambda o, kk, tid: seeker_host_step(
                    o, host_params=jax.tree_util.tree_map(
                        lambda p: p[tid], host_params),
                    gen_params=gen_params, har_cfg=har_cfg, key=kk, t=t)
            )(out, ks[:, 2], tasks_b)
        else:
            host_logits = jax.vmap(
                lambda o, kk: seeker_host_step(
                    o, host_params=host_params, gen_params=gen_params,
                    har_cfg=har_cfg, key=kk, t=t)
            )(out, ks[:, 2])
        trace = {"decision": out.decision, "payload": out.payload_bytes,
                 "stored": out.state.stored_uj, "k": out.coreset_k,
                 "logits": host_logits}
        if intermittent is not None:
            trace.update({"it_emit": lane.emit, "it_label": lane.emit_label,
                          "it_conf": lane.emit_conf, "it_src": lane.emit_src,
                          "it_stage": lane.emit_stage})
        return out.state, ks[:, 0], new_it, trace

    active = _active_lanes(intermittent, task, brownout)

    def step(carry, inp, tasks, signatures, qdnn_params, host_params,
             gen_params, aac_table, aux_params=None):
        # the typed carry: one field per registered lane, None for absent
        # lanes (an empty pytree — no scan slots, no ops), which is what
        # keeps ``lane=None`` engines bitwise-identical to engines built
        # before the lane existed.  The telemetry field is the fleet-level
        # accumulator lane — never passed through keep(): it holds masked
        # counts, not per-node state.
        state, keys, browned, it, metrics = carry
        win_t, harv_t, alive_t, slot = inp
        n = keys.shape[0]
        # the per-slot alive lane: the exogenous trace composed with the
        # endogenous brown-out flag carried through the scan — a node runs
        # only when its trace says so AND its supercap hysteresis allows
        alive_eff = (alive_t & ~browned) if brownout is not None else alive_t
        if shared_stream:
            win_t = jnp.broadcast_to(win_t[None], (n,) + win_t.shape)

        if node_block is None or node_block == n:
            new_state, new_keys, new_it, trace = block_body(
                state, keys, it, tasks, win_t, harv_t, slot, signatures,
                qdnn_params, host_params, gen_params, aac_table, aux_params)
        else:
            # fixed-shape microbatches: pad the node axis to the block
            # quantum (rows are independent, padding is sliced off) and map
            # the identical compiled body over groups — a shard tile SMALLER
            # than the block pads up to it, so every layout runs batch-
            # (node_block,) bodies
            pad = (-n) % node_block
            grp = (n + pad) // node_block

            def regroup(x):
                x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
                return x.reshape((grp, node_block) + x.shape[1:])

            def ungroup(x):
                return x.reshape((grp * node_block,) + x.shape[2:])[:n]

            st_g, ks_g, it_g, tk_g, w_g, h_g = jax.tree_util.tree_map(
                regroup, (state, keys, it, tasks, win_t, harv_t))
            new_state, new_keys, new_it, trace = jax.tree_util.tree_map(
                ungroup,
                jax.lax.map(
                    lambda a: block_body(a[0], a[1], a[2], a[3], a[4], a[5],
                                         slot, signatures, qdnn_params,
                                         host_params, gen_params, aac_table,
                                         aux_params),
                    (st_g, ks_g, it_g, tk_g, w_g, h_g)))

        # --- churn lane: a dead node harvests nothing, freezes its whole
        # carry (charge, predictor, AAC continuity AND its PRNG stream — on
        # rejoin it continues exactly where it browned out), and emits DEFER
        # with zero payload.  With an all-True trace every select picks the
        # freshly-computed value, so the churn-free run is bitwise unchanged.
        def keep(new, old):
            a = alive_eff.reshape((n,) + (1,) * (new.ndim - 1))
            return jnp.where(a, new, old)

        new_state = jax.tree_util.tree_map(keep, new_state, state)
        new_keys = keep(new_keys, keys)
        if intermittent is not None:
            # suspended staged activations freeze through dead AND browned-
            # out slots like every other carry lane — suspend-across-
            # brown-out falls out of the same select
            new_it = jax.tree_util.tree_map(keep, new_it, it)
        if brownout is not None:
            # --- endogenous brown-out: the MCU is down but the harvester
            # keeps trickle-charging the supercap, so a browned-out (yet
            # exogenously-present) node's charge still integrates income;
            # an exogenously-dead node stays fully frozen (PR-4 lane).
            trickle = supercap_step(state.stored_uj, harv_t, 0.0)
            stored = jnp.where(alive_eff, new_state.stored_uj,
                               jnp.where(alive_t, trickle, state.stored_uj))
            new_state = new_state._replace(stored_uj=stored)
            # hysteresis on the POST-slot charge: running nodes brown out
            # below off_uj, browned-out nodes rejoin at restart_uj; the flag
            # freezes (like everything else) through exogenously-dead slots
            next_browned = jnp.where(browned, stored < brownout.restart_uj,
                                     stored < brownout.off_uj)
            next_browned = jnp.where(alive_t, next_browned, browned)
        else:
            next_browned = browned
        out_trace = {
            "decision": jnp.where(alive_eff, trace["decision"], DEFER),
            "payload": jnp.where(alive_eff, trace["payload"], 0.0),
            "stored": new_state.stored_uj,
            "k": jnp.where(alive_eff, trace["k"], 0),
            "logits": jnp.where(alive_eff[:, None], trace["logits"], 0.0),
            "alive": alive_eff,          # exogenous ∧ ¬browned_out
            "brownout": browned,         # the flag the slot was entered with
            "bo_event": next_browned & ~browned,   # brown-out onsets
        }
        if intermittent is not None:
            # a dead/browned-out node ran no lane this slot: its emission
            # lane is masked like the decision lane (the label/conf/src
            # fields are only meaningful where it_emit > 0)
            out_trace.update({
                "it_emit": jnp.where(alive_eff, trace["it_emit"], 0),
                "it_label": trace["it_label"],
                "it_conf": trace["it_conf"],
                "it_src": trace["it_src"],
                "it_stage": trace["it_stage"],
            })
        new_metrics = (None if telemetry is None else _update_fleet_lanes(
            telemetry, metrics, out_trace, alive_t, active, tasks))
        return FleetCarry(node=new_state, keys=new_keys,
                          brownout=next_browned, intermittent=new_it,
                          telemetry=new_metrics), out_trace

    return step


@functools.lru_cache(maxsize=32)
def _build_fleet_run(har_cfg: HARConfig, costs: EnergyCosts, quant_bits: int,
                     k_max: int, m_samples: int, corr_threshold: float,
                     shared_stream: bool, node_block: int | None,
                     brownout: BrownoutConfig | None, donate: bool,
                     intermittent: IntermittentConfig | None = None,
                     telemetry: MetricsSpec | None = None,
                     task: TaskLaneConfig | None = None):
    """Compile-cached fleet scan, keyed on the static configuration.

    All arrays (params, signatures, windows, state) are jit *arguments*, so
    repeated simulations with the same config — the benchmark's timed
    iterations, a serving loop — reuse the compiled executable instead of
    re-tracing a fresh closure each call.

    ONE signature for every lane combination: absent lanes pass ``None``
    (an empty pytree contributing no jit inputs and no scan slots), so
    ``lane=None`` stays bitwise-off without per-combination run variants —
    the scan body is the same registered :class:`FleetCarry` step for every
    driver.  ``xs_slots`` is always an input (the intermittent lane's
    global slot indices; unused — and dead-code-eliminated — without the
    lane).  With ``telemetry`` the carry's telemetry field starts from
    ZERO — the run computes a telemetry *delta*, merged with any resumed
    ``telemetry_state0`` host-side, which is what keeps the sharded engine
    from double-counting a replicated carry-in on psum.
    """

    def run(state0, keys0, browned0, it0, tasks, xs_w, xs_h, xs_alive,
            xs_slots, signatures, qdnn_params, host_params, gen_params,
            aac_table, aux_params):
        compile_event("fleet.run")
        obs_trace.instant("compile:fleet.run")
        t = xs_w.shape[-2]
        step = _make_fleet_step(har_cfg, costs, quant_bits, k_max,
                                m_samples, corr_threshold, shared_stream,
                                t, node_block, brownout, intermittent,
                                telemetry=telemetry, task=task)
        carry0 = FleetCarry(
            node=state0, keys=keys0, brownout=browned0, intermittent=it0,
            telemetry=None if telemetry is None else metrics_init(telemetry))
        final, traces = jax.lax.scan(
            lambda c, i: step(c, i, tasks, signatures, qdnn_params,
                              host_params, gen_params, aac_table,
                              aux_params),
            carry0, (xs_w, xs_h, xs_alive, xs_slots))
        # the final carry IS the resume contract: a resumed run
        # (state0=final_state, node_keys=final_keys,
        # brownout_state0=final_brownout,
        # intermittent_state0=final_intermittent, slot0=slots run so far,
        # telemetry_state0=res["telemetry"]) continues each lane exactly
        # where it stopped instead of replaying segment 1
        return traces, final

    # donate the stacked node state (it is returned, so XLA can alias it)
    return jax.jit(run, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=32)
def _build_fleet_run_sharded(mesh, axis_names: tuple[str, ...],
                             har_cfg: HARConfig, costs: EnergyCosts,
                             quant_bits: int, k_max: int, m_samples: int,
                             corr_threshold: float, shared_stream: bool,
                             per_node_labels: bool,
                             node_block: int | None,
                             brownout: BrownoutConfig | None, donate: bool,
                             intermittent: IntermittentConfig | None = None,
                             telemetry: MetricsSpec | None = None,
                             task: TaskLaneConfig | None = None):
    """Compile-cached SHARDED fleet scan: the whole time scan runs inside the
    ``shard_map`` manual region, each shard scanning its local node tile
    with the SAME registered :class:`FleetCarry` step as the single-device
    driver; only the masked fleet aggregates (and, with ``telemetry``, the
    registry lanes via :func:`repro.obs.metrics_psum`) are ``psum``-ed over
    ``axis_names``.

    Like :func:`_build_fleet_run`, ONE signature covers every lane
    combination — absent lanes pass ``None``, whose shard specs broadcast
    over zero leaves.  ``per_node_labels`` switches the accuracy aggregate
    between one shared (S,) label track (replicated) and per-node (S, N)
    tracks (sharded over the node axes like every other per-node array);
    the task lane's (N,) ids shard over the node axes and its per-task
    splits join the psum'd aggregate set."""
    nodes = P(axis_names)                    # leading node dim over the mesh
    time_nodes = P(None, axis_names)         # (S, N, ...) time-major traces
    repl = P()                               # replicated (params, bank, mask)

    def shard_body(state0, keys0, browned0, it0, tasks, xs_w, xs_h,
                   xs_alive, xs_slots, mask, labels, signatures,
                   qdnn_params, host_params, gen_params, aac_table,
                   aux_params):
        compile_event("fleet.run_sharded")
        obs_trace.instant("compile:fleet.run_sharded")
        t = xs_w.shape[-2]
        step = _make_fleet_step(har_cfg, costs, quant_bits, k_max,
                                m_samples, corr_threshold, shared_stream,
                                t, node_block, brownout, intermittent,
                                telemetry=telemetry, task=task)
        carry0 = FleetCarry(
            node=state0, keys=keys0, brownout=browned0, intermittent=it0,
            telemetry=None if telemetry is None else metrics_init(telemetry))
        final, traces = jax.lax.scan(
            lambda c, i: step(c, i, tasks, signatures, qdnn_params,
                              host_params, gen_params, aac_table,
                              aux_params),
            carry0, (xs_w, xs_h, xs_alive, xs_slots))
        aggs = _fleet_aggregates(
            traces, xs_alive, labels, per_node_labels, intermittent,
            xs_slots[0] if intermittent is not None else 0,
            tasks=tasks, task=task, mask=mask,
            reduce=lambda x: jax.lax.psum(x, axis_names))
        # registry lanes are summed per shard then psum'd component-wise;
        # the psum'd delta is replicated (out-spec P() per lane)
        final = final._replace(
            telemetry=None if telemetry is None else metrics_psum(
                telemetry, final.telemetry, axis_names))
        return traces, final, aggs

    in_specs = (nodes, nodes, nodes,   # state0 (pytree), keys0, browned0
                nodes,                            # it0 (lane state | None)
                nodes,                            # tasks (N,) | None
                repl if shared_stream else time_nodes,   # xs_w
                time_nodes,                       # xs_h (S, N)
                time_nodes,                       # xs_alive (S, N)
                repl,                             # xs_slots (S,)
                nodes,                            # mask (N,)
                time_nodes if per_node_labels else repl,  # labels
                repl, repl, repl, repl, repl, repl)
    out_specs = (time_nodes,                      # traces
                 FleetCarry(node=nodes, keys=nodes, brownout=nodes,
                            intermittent=nodes, telemetry=repl),
                 repl)                            # psum'd aggregates

    fn = shard_map_compat(
        shard_body, mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=frozenset(axis_names))
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def _stack_pad_state(state0: SeekerNodeState | None, n: int, pad: int,
                     predictor_window: int, initial_uj: float
                     ) -> SeekerNodeState:
    """Resolve the fleet's initial state: a caller-provided stacked state
    (serving loops resuming a fleet keep their supercapacitor charge) or a
    fresh init, extended with ``pad`` inert default-init rows."""
    if state0 is None:
        return fleet_node_init(n + pad, predictor_window, initial_uj)
    lead = jax.tree_util.tree_leaves(state0)[0].shape[0]
    if lead != n:
        raise ValueError(f"state0 is stacked for {lead} nodes, fleet has {n}")
    if pad == 0:
        return state0
    filler = fleet_node_init(pad, predictor_window, initial_uj)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), state0, filler)


def _resolve_labels(labels, s: int, n: int, shared_stream: bool
                    ) -> tuple[jnp.ndarray | None, bool]:
    """Validate the ``labels`` argument against the stream layout.

    Returns ``(labels, per_node)``: a shared (S,) track (only meaningful
    when every node sees the same stream) or per-node (S, N) tracks.  A
    shared track with per-node streams is REJECTED — scoring N different
    window streams against one label track is exactly the silent accuracy
    bug this check exists to stop.
    """
    if labels is None:
        return None, False
    labels = jnp.asarray(labels)
    accepted = (f"accepted forms: (S,)=({s},) shared-stream track, or "
                f"(S, N)=({s}, {n}) per-node tracks (padded/sharded like "
                f"harvest; mixed-task fleets score each node's track "
                f"against its own task)")
    if labels.shape == (s, n):
        return labels.astype(jnp.int32), True
    if labels.shape == (s,):
        if not shared_stream and n != 1:
            raise ValueError(
                f"labels shape {labels.shape} is ambiguous with per-node "
                f"(N, S, T, C) window streams: each of the {n} nodes plays "
                f"its own stream, so accuracy against one shared "
                f"(S,)=({s},) label track is meaningless.  Pass per-node "
                f"(S, N)=({s}, {n}) labels or a shared (S, T, C) window "
                f"stream; {accepted}.")
        return labels.astype(jnp.int32), False
    raise ValueError(
        f"labels must be one of the accepted forms, got shape "
        f"{labels.shape}; {accepted}.")


def _resolve_alive(alive, n: int, s: int) -> jnp.ndarray:
    """(N, S) bool churn trace; ``None`` = the always-registered fleet."""
    if alive is None:
        return jnp.ones((n, s), bool)
    alive = jnp.asarray(alive)
    if alive.shape != (n, s):
        raise ValueError(f"alive must be (N, S)=({n}, {s}) bool, "
                         f"got {alive.shape}")
    return alive.astype(bool)


def _wire_byte_pair(payload: jnp.ndarray, act: jnp.ndarray) -> jnp.ndarray:
    """Exact integer bytes-on-wire as a (2,) int32 ``[hi, lo]`` pair with
    value ``hi * 2**16 + lo``.

    Payloads are integral whole-byte counts (``aac_bytes``,
    ``sampling_payload_bytes``), but the legacy ``bytes_on_wire`` float32
    sum loses exactness past 2**24 at fleet scale.  int64 is unavailable
    with jax's default x64-off config, so the reduction goes hierarchical:
    per-node slot totals stay exact in int32 (payload < 2**16 B per slot,
    so any S < 2**31 / 2**16 ≈ 32k-slot-of-max-payload per node — in
    practice S < ~3M slots at the 720-B raw bound), then the node reduction
    splits each total into base-2**16 digits whose int32 sums (and psums)
    stay exact to N < 32768 nodes.  The pair is not normalized (``lo`` may
    exceed 2**16); combine with :func:`wire_bytes_exact`.
    """
    p = jnp.where(act, jnp.round(payload).astype(jnp.int32), 0)
    # hierarchical: per-node totals stay exact in int32, then the digit
    # split + reduction is the registry's shared primitive
    return int_pair_sum(jnp.sum(p, axis=0))               # (N,) -> (2,)


def wire_bytes_exact(res: dict) -> int:
    """Combine an engine result's ``bytes_on_wire_i32`` pair into the exact
    total bytes the fleet put on the wire, as an arbitrary-precision Python
    int (the float32 ``bytes_on_wire`` is kept for compatibility but is
    only approximate past 2**24)."""
    return int_pair_total(res["bytes_on_wire_i32"])


def _resolve_brownout0(brownout_state0, state0: SeekerNodeState,
                       brownout: BrownoutConfig | None, n: int
                       ) -> jnp.ndarray:
    """(N,) bool brown-out flag entering slot 0: an explicitly resumed flag
    (a previous run's ``final_brownout``), else boot-time hysteresis (a node
    whose initial charge is already under ``off_uj`` boots browned out),
    else the inert all-False lane when brown-out is disabled."""
    if brownout_state0 is not None:
        browned0 = jnp.asarray(brownout_state0)
        if browned0.shape != (n,):
            raise ValueError(f"brownout_state0 must be (N,)=({n},) bool, "
                             f"got {browned0.shape}")
        return browned0.astype(bool)
    if brownout is not None:
        return state0.stored_uj[:n] < brownout.off_uj
    return jnp.zeros((n,), bool)


def _validate_intermittent_args(intermittent, intermittent_state0,
                                aux_params, n: int) -> None:
    """Reject half-configured intermittent runs before tracing: the lane
    needs its auxiliary heads, and a resumed lane state without the lane
    enabled would silently be ignored."""
    if intermittent is None:
        if intermittent_state0 is not None:
            raise ValueError(
                "intermittent_state0 was passed but intermittent is None — "
                "a resumed lane state without the lane enabled would be "
                "silently dropped; pass the IntermittentConfig too")
        return
    if aux_params is None:
        raise ValueError(
            "intermittent inference needs the early-exit auxiliary heads: "
            "pass aux_params=har_aux_init(key, har_cfg)")
    if intermittent_state0 is not None:
        lead = intermittent_state0.stage.shape[0]
        if lead != n:
            raise ValueError(
                f"intermittent_state0 is stacked for {lead} nodes, "
                f"fleet has {n}")


def _resolve_tasks(tasks, task: TaskLaneConfig | None, n: int
                   ) -> tuple[jnp.ndarray | None, TaskLaneConfig | None]:
    """Resolve the heterogeneous-task lane's per-node ids + config.

    ``task`` alone defaults to the round-robin
    :func:`repro.serving.fleet_lanes.fleet_task_assignment`; ``tasks``
    alone gets the default two-task :class:`TaskLaneConfig`.  Ids are
    validated against the config's task count host-side (they are static
    per-node run arguments, not traced)."""
    if tasks is None and task is None:
        return None, None
    if task is None:
        task = TaskLaneConfig()
    if tasks is None:
        tasks = fleet_task_assignment(n, task.n_tasks)
    tasks = jnp.asarray(tasks, jnp.int32)
    if tasks.shape != (n,):
        raise ValueError(
            f"tasks must be (N,)=({n},) per-node task ids, "
            f"got {tasks.shape}")
    lo, hi = int(jnp.min(tasks)), int(jnp.max(tasks))
    if lo < 0 or hi >= task.n_tasks:
        raise ValueError(
            f"tasks ids span [{lo}, {hi}] but the TaskLaneConfig declares "
            f"{task.n_tasks} tasks {task.names}")
    return tasks, task


def _resolve_task_host(task: TaskLaneConfig | None, host_params):
    """With ``per_task_host``, ``host_params`` must arrive as one tree per
    task; stack them leaf-wise so each node's host step can gather its
    task's tree at fixed shapes (:func:`stack_task_params`)."""
    if task is None or not task.per_task_host:
        return host_params
    if not isinstance(host_params, (tuple, list)):
        raise ValueError(
            f"per_task_host=True needs host_params as a sequence of "
            f"{task.n_tasks} per-task param trees "
            f"(one per {task.names}), got {type(host_params).__name__}")
    if len(host_params) != task.n_tasks:
        raise ValueError(
            f"per_task_host=True needs {task.n_tasks} host param trees "
            f"for tasks {task.names}, got {len(host_params)}")
    return stack_task_params(host_params)


def _fleet_aggregates(traces: dict, exo_alive: jnp.ndarray,
                      labels: jnp.ndarray | None, per_node: bool,
                      intermittent: IntermittentConfig | None = None,
                      slot0=0, tasks: jnp.ndarray | None = None,
                      task: TaskLaneConfig | None = None,
                      mask: jnp.ndarray | None = None,
                      reduce=None) -> dict:
    """Masked fleet aggregates from (S, N) traces — ONE function for both
    engines: the single-device driver calls it host-side after the run
    (``mask=None``, identity ``reduce``); the sharded engine calls it
    inside the shard_map region on its local tile, with the static padding
    ``mask`` composed into the activity mask and ``reduce`` wrapping every
    aggregate in a ``psum`` — int counters are exactly equal across engines
    because every reduction here is an associative integer sum (tests
    cross-check them).  The activity mask is the engine's EMITTED alive
    lane (exogenous ∧ ¬browned_out); ``exo_alive`` is the exogenous trace
    alone, needed to count the slots the brown-out hysteresis suppressed.

    With ``intermittent`` the completion aggregate excludes D6 (a suspended
    inference put nothing on the wire), the histogram grows to the 9-code
    ladder, and emission counters + source-slot-scored accuracy splits are
    added; ``slot0`` is the absolute slot index of this run's first slot —
    emissions whose ``it_src`` predates it (a resumed segment finishing an
    earlier segment's inference) are masked out of the accuracy counters
    here and rescored by the streamed driver over the concatenated traces.

    With the task lane (``tasks``/``task``) every completion/miss/accuracy
    count additionally splits per task id via
    :func:`repro.obs.categorical_counts` — integer histograms over the
    broadcast (S, N) task ids, so the splits psum exactly like the totals:
    ``completed_by_task``, ``deadline_miss_by_task`` (an alive slot that
    put no result on the wire missed its slot deadline) and, with labels,
    ``correct_by_task``."""
    red = reduce if reduce is not None else (lambda x: x)
    act = traces["alive"]
    if mask is not None:
        act = act & mask[None, :]
    if intermittent is None:
        sent = (traces["decision"] != DEFER) & act
        n_bins = N_DECISIONS
    else:
        # D6 suspends with nothing on the wire; D7/D8 are completions
        sent = ((traces["decision"] != DEFER)
                & (traces["decision"] != D6_PARTIAL) & act)
        n_bins = N_INTERMITTENT_DECISIONS
    bo = traces["brownout"] & exo_alive
    bo_event = traces["bo_event"]
    if mask is not None:
        # padding nodes are exogenously dead: they never brown "in" and
        # contribute to neither brown-out count
        bo = bo & mask[None, :]
        bo_event = bo_event & mask[None, :]
    aggs = {
        "bytes_on_wire": red(
            jnp.sum(jnp.where(act, traces["payload"], 0.0))),
        "bytes_on_wire_i32": red(_wire_byte_pair(traces["payload"], act)),
        "decision_histogram": red(categorical_counts(
            traces["decision"], n_bins, act)),
        "completed": red(jnp.sum(sent.astype(jnp.int32))),
        "alive_slots": red(jnp.sum(act.astype(jnp.int32))),
        "brownout_slots": red(jnp.sum(bo.astype(jnp.int32))),
        "brownout_events": red(jnp.sum(bo_event.astype(jnp.int32))),
    }
    if intermittent is not None:
        emit = traces["it_emit"]
        aggs["it_full"] = red(
            jnp.sum(((emit == 2) & act).astype(jnp.int32)))
        aggs["it_early"] = red(
            jnp.sum(((emit == 1) & act).astype(jnp.int32)))
    tasks_b = (None if tasks is None else
               jnp.broadcast_to(tasks[None, :], act.shape))
    if task is not None:
        aggs["completed_by_task"] = red(
            categorical_counts(tasks_b, task.n_tasks, sent))
        aggs["deadline_miss_by_task"] = red(
            categorical_counts(tasks_b, task.n_tasks, act & ~sent))
    if labels is None:
        return aggs
    preds = jnp.argmax(traces["logits"], axis=-1)
    # per-node labels arrive as (S, N) tracks (under shard_map: the shard's
    # own tile); a shared track broadcasts over the node axis
    ok = (preds == labels) if per_node else (preds == labels[:, None])
    if intermittent is None:
        aggs["correct"] = red(jnp.sum((ok & sent).astype(jnp.int32)))
        if task is not None:
            aggs["correct_by_task"] = red(
                categorical_counts(tasks_b, task.n_tasks, ok & sent))
        return aggs
    # ladder accuracy scores the slot-aligned host logits; lane emissions
    # score against the label of their SOURCE slot (the staged window's
    # capture slot, gathered through it_src)
    ladder_sent = sent & (traces["decision"] <= D4_SAMPLING)
    s = traces["decision"].shape[0]
    rel = traces["it_src"] - slot0
    valid = (traces["it_emit"] > 0) & act & (rel >= 0)
    rel_c = jnp.clip(rel, 0, s - 1)
    lab = (jnp.take_along_axis(labels, rel_c, axis=0) if per_node
           else labels[rel_c])
    it_ok = (traces["it_label"] == lab) & valid
    aggs["correct_ladder"] = red(
        jnp.sum((ok & ladder_sent).astype(jnp.int32)))
    aggs["it_correct_full"] = red(
        jnp.sum((it_ok & (traces["it_emit"] == 2)).astype(jnp.int32)))
    aggs["it_correct_early"] = red(
        jnp.sum((it_ok & (traces["it_emit"] == 1)).astype(jnp.int32)))
    aggs["correct"] = (aggs["correct_ladder"] + aggs["it_correct_full"]
                       + aggs["it_correct_early"])
    if task is not None:
        aggs["correct_by_task"] = red(
            categorical_counts(tasks_b, task.n_tasks, ok & ladder_sent)
            + categorical_counts(tasks_b, task.n_tasks,
                                 it_ok & (traces["it_emit"] == 2))
            + categorical_counts(tasks_b, task.n_tasks,
                                 it_ok & (traces["it_emit"] == 1)))
    return aggs


def seeker_fleet_simulate(windows: jnp.ndarray, harvest: jnp.ndarray, *,
                          signatures, qdnn_params, host_params, gen_params,
                          har_cfg: HARConfig,
                          aac_table: AACTable | None = None,
                          costs: EnergyCosts | None = None,
                          key: jax.Array | None = None, quant_bits: int = 16,
                          k_max: int = 12, m_samples: int = 20,
                          corr_threshold: float = 0.95,
                          predictor_window: int = 8, initial_uj: float = 50.0,
                          state0: SeekerNodeState | None = None,
                          node_keys: jax.Array | None = None,
                          labels: jnp.ndarray | None = None,
                          alive: jnp.ndarray | None = None,
                          brownout: BrownoutConfig | None = None,
                          brownout_state0: jnp.ndarray | None = None,
                          node_block: int | None = None,
                          donate: bool = True,
                          intermittent: IntermittentConfig | None = None,
                          intermittent_state0: IntermittentState | None = None,
                          aux_params: dict | None = None,
                          slot0: int = 0,
                          telemetry=None,
                          telemetry_state0: dict | None = None,
                          tasks: jnp.ndarray | None = None,
                          task: TaskLaneConfig | None = None):
    """Simulate N independent Seeker nodes over S time slots in one scan.

    Args:
        windows: (S, T, C) — one stream shared by every node (the sensor-
            ensemble deployment), or (N, S, T, C) — a stream per node.
        harvest: (N, S) µJ harvested per node per slot (heterogeneous traces;
            see :func:`repro.core.energy.fleet_harvest_traces`).
        key: fleet PRNG; node ``i`` uses ``fold_in(key, i)`` and then splits
            exactly like the single-node simulator, so an N=1 fleet
            reproduces a single-node run.
        state0: optional stacked ``SeekerNodeState`` to resume from (e.g. the
            ``final_state`` of a previous run) — supercapacitor charge,
            predictor history and AAC continuity carry over instead of being
            silently reset to ``initial_uj``.  NOTE: with ``donate=True`` the
            passed state's buffers are donated to the run.
        node_keys: optional (N, 2) per-node PRNG keys to resume from (a
            previous run's ``final_keys``) — without them a resumed segment
            re-derives ``fold_in(key, i)`` and replays segment 1's random
            draws.  ``state0 + node_keys`` makes a chain of runs bitwise
            equal to one long run.
        labels: optional ground truth for the ``fleet_accuracy`` aggregate:
            (S,) for a shared stream, or per-node (S, N) tracks.  A shared
            (S,) track with per-node window streams raises — see
            :func:`_resolve_labels`.
        alive: optional (N, S) bool churn trace
            (:func:`repro.core.energy.fleet_alive_traces`) — dead slots
            freeze the node (state AND PRNG stream), emit DEFER with zero
            payload, and drop out of every aggregate.  An all-True trace is
            bitwise-identical to ``None``.
        brownout: optional :class:`repro.core.energy.BrownoutConfig` —
            ENDOGENOUS churn: the decision ladder switches to strict
            store-and-execute affordability (spend ≤ stored + harvested this
            slot; the forecast ranks but no longer mints energy), and the
            per-slot alive lane becomes ``alive_trace ∧ ¬browned_out`` with
            ``browned_out`` carried through the scan and flipped by the
            supercap hysteresis (below ``off_uj`` the node powers down and
            trickle-charges; at ``restart_uj`` it reboots into its frozen
            state).  ``None`` keeps today's engine bitwise.
        brownout_state0: optional (N,) bool — resume the brown-out flag from
            a previous run's ``final_brownout`` (the streamed driver does);
            default is boot-time hysteresis on the initial charge.
        node_block: run per-slot fleet math in fixed-size node microbatches
            (see :func:`_make_fleet_step`) — results become bit-identical
            across fleet sizes and shard layouts that use the same block.
            ``None`` (default) is the fastest full-batch path.
        donate: donate the stacked node state to the jitted run so XLA can
            alias its buffers into the returned final state (the key array
            has no matching output and is never donated).
        intermittent: optional :class:`repro.core.decision.IntermittentConfig`
            — enables the staged intermittent-inference lane: slots the
            ladder would DEFER instead advance a staged quantized inference
            as far as this slot's strict ``stored + harvested`` budget
            affords, suspending the activations in the scan carry across
            slots and brown-outs (see docs/ENERGY_MODEL.md).  Requires
            ``aux_params`` (:func:`repro.models.har.har_aux_init`).  ``None``
            keeps the engine bitwise-identical to the legacy path.
        intermittent_state0: optional stacked
            :class:`repro.serving.edge_host.IntermittentState` to resume a
            suspended fleet from (a previous run's ``final_intermittent``).
        aux_params: early-exit auxiliary head params (required with
            ``intermittent``).
        slot0: absolute slot index of this run's first slot — the streamed
            driver passes its segment offset so ``it_src`` emission sources
            stay globally indexed and segment chains stay bitwise equal to
            one long run.
        telemetry: ``True`` (the default :func:`fleet_telemetry_spec` lane
            set) or a :class:`repro.obs.MetricsSpec` — registry lanes ride
            the scan carry and come back under ``res["telemetry"]`` (all
            int32; bitwise-equal across the three engines).  ``None``
            (default) keeps the engine bitwise-identical to the
            untelemetered path.
        telemetry_state0: a previous run's ``res["telemetry"]`` to resume
            from — merged host-side (:func:`repro.obs.metrics_merge`) after
            the run, so counters/histograms accumulate exactly across
            segments and gauges keep the latest level.
        tasks: optional (N,) int32 per-node task ids — the heterogeneous-
            task lane (HAR wearables + bearing monitors sharing one fleet).
            Defaults to :func:`repro.serving.fleet_lanes.
            fleet_task_assignment` when only ``task`` is given.
        task: optional :class:`repro.serving.fleet_lanes.TaskLaneConfig` —
            names, per-task cost scales (the WHOLE decision ladder and the
            intermittent lane's stage costs scale per node), and the
            ``per_task_host`` switch (``host_params`` then arrives as one
            tree per task and each node infers through its task's weights).
            Adds per-task splits ``completed_by_task``/
            ``deadline_miss_by_task`` (and ``correct_by_task``/
            ``accuracy_by_task`` with labels) to the aggregates.  ``None``
            (with ``tasks=None``) keeps the engine bitwise-identical to
            the homogeneous fleet.

    Returns a dict of per-node traces, time-major:
        ``decisions``/``payload_bytes``/``stored_uj``/``k_trace``: (S, N),
        ``logits``/``preds``: (S, N, L) / (S, N),
        ``alive``/``brownout``: (S, N) bool — the EMITTED per-slot alive
            lane (exogenous ∧ ¬browned_out) and the brown-out flag each
            slot was entered with,
        ``bytes_on_wire``: () total payload bytes the fleet transmitted
            (float32; ``bytes_on_wire_i32`` is the exact (2,) int32
            [hi, lo] pair — combine with :func:`wire_bytes_exact`),
        ``decision_histogram``: (N_DECISIONS,) int32 counts over alive slots,
        ``completed``/``alive_slots``: () int32, ``completed_frac``: (),
        ``brownout_slots``/``brownout_events``: () int32 — slots suppressed
            by the hysteresis and brown-out onsets,
        ``fleet_accuracy``/``correct``: () when ``labels`` is given,
        ``raw_bytes_per_window``: () the uncompressed (T, C) baseline per
            window (all channels, the benchmarks' raw-equivalent convention),
        ``final_state``: stacked ``SeekerNodeState``.

    With ``intermittent`` the dict additionally carries the lane traces
    ``it_emit`` (S, N) int32 (0 none / 1 early exit / 2 full depth),
    ``it_label``/``it_src``/``it_stage``/``it_conf`` (S, N), the counters
    ``it_full``/``it_early`` (and, with labels, ``correct_ladder``/
    ``it_correct_full``/``it_correct_early``), and ``final_intermittent``
    (stacked :class:`~repro.serving.edge_host.IntermittentState`) for
    resuming; ``correct`` then sums ladder + lane completions, each scored
    against its source slot's label.
    """
    costs = costs or EnergyCosts()
    key = key if key is not None else jax.random.PRNGKey(0)
    n, s = harvest.shape
    assert windows.ndim in (3, 4), f"windows must be (S,T,C) or (N,S,T,C), got {windows.shape}"
    shared_stream = windows.ndim == 3
    if shared_stream:
        assert windows.shape[0] == s, (windows.shape, s)
        xs_windows = windows                                  # (S, T, C)
    else:
        assert windows.shape[:2] == (n, s), (windows.shape, n, s)
        xs_windows = jnp.moveaxis(windows, 0, 1)              # (S, N, T, C)
    t = windows.shape[-2]
    labels, per_node_labels = _resolve_labels(labels, s, n, shared_stream)
    alive_t = _resolve_alive(alive, n, s).T                   # (S, N)

    state0 = _stack_pad_state(state0, n, 0, predictor_window, initial_uj)
    keys0 = node_keys if node_keys is not None else fleet_node_keys(key, n)
    browned0 = _resolve_brownout0(brownout_state0, state0, brownout, n)
    _validate_intermittent_args(intermittent, intermittent_state0,
                                aux_params, n)
    tasks, task = _resolve_tasks(tasks, task, n)
    host_params = _resolve_task_host(task, host_params)
    tel_spec = _resolve_telemetry(telemetry, intermittent, task)
    run_fn = _build_fleet_run(har_cfg, costs, quant_bits, k_max, m_samples,
                              corr_threshold, shared_stream, node_block,
                              brownout, donate, intermittent, tel_spec,
                              task)
    it0 = None
    if intermittent is not None:
        it0 = (intermittent_state0 if intermittent_state0 is not None
               else intermittent_fleet_init(n, har_cfg))
    xs_slots = jnp.arange(slot0, slot0 + s, dtype=jnp.int32)
    traces, final = run_fn(
        state0, keys0, browned0, it0, tasks, xs_windows, harvest.T,
        alive_t, xs_slots, signatures, qdnn_params, host_params, gen_params,
        aac_table, aux_params)
    final_state, final_keys = final.node, final.keys
    final_brownout, final_intermittent = final.brownout, final.intermittent
    tel_delta = final.telemetry

    aggs = _fleet_aggregates(traces, alive_t, labels, per_node_labels,
                             intermittent, slot0, tasks=tasks, task=task)
    out = {
        "decisions": traces["decision"],                      # (S, N)
        "payload_bytes": traces["payload"],                   # (S, N)
        "stored_uj": traces["stored"],                        # (S, N)
        "k_trace": traces["k"],                               # (S, N)
        "logits": traces["logits"],                           # (S, N, L)
        "preds": jnp.argmax(traces["logits"], axis=-1),       # (S, N)
        "alive": traces["alive"],                             # (S, N)
        "brownout": traces["brownout"],                       # (S, N)
        "bytes_on_wire": aggs["bytes_on_wire"],
        "bytes_on_wire_i32": aggs["bytes_on_wire_i32"],
        "decision_histogram": aggs["decision_histogram"],
        "completed": aggs["completed"],
        "alive_slots": aggs["alive_slots"],
        "brownout_slots": aggs["brownout_slots"],
        "brownout_events": aggs["brownout_events"],
        "completed_frac": aggs["completed"]
            / jnp.maximum(aggs["alive_slots"], 1),
        "raw_bytes_per_window": jnp.asarray(
            float(raw_payload_bytes(t)) * windows.shape[-1], jnp.float32),
        "final_state": final_state,
        "final_keys": final_keys,
        "final_brownout": final_brownout,
    }
    if tel_spec is not None:
        out["telemetry"] = metrics_merge(tel_spec, telemetry_state0,
                                         tel_delta)
        out["telemetry_spec"] = tel_spec
    if intermittent is not None:
        out.update({
            "it_emit": traces["it_emit"],                     # (S, N)
            "it_label": traces["it_label"],                   # (S, N)
            "it_conf": traces["it_conf"],                     # (S, N)
            "it_src": traces["it_src"],                       # (S, N)
            "it_stage": traces["it_stage"],                   # (S, N)
            "it_full": aggs["it_full"],
            "it_early": aggs["it_early"],
            "final_intermittent": final_intermittent,
        })
    if labels is not None:
        out["correct"] = aggs["correct"]
        out["fleet_accuracy"] = (aggs["correct"]
                                 / jnp.maximum(aggs["completed"], 1))
        if intermittent is not None:
            out["correct_ladder"] = aggs["correct_ladder"]
            out["it_correct_full"] = aggs["it_correct_full"]
            out["it_correct_early"] = aggs["it_correct_early"]
    if task is not None:
        out["task_names"] = task.names
        out["tasks"] = tasks
        out["completed_by_task"] = aggs["completed_by_task"]
        out["deadline_miss_by_task"] = aggs["deadline_miss_by_task"]
        if labels is not None:
            out["correct_by_task"] = aggs["correct_by_task"]
            out["accuracy_by_task"] = (
                aggs["correct_by_task"]
                / jnp.maximum(aggs["completed_by_task"], 1))
    return out


def seeker_fleet_simulate_sharded(
        windows: jnp.ndarray, harvest: jnp.ndarray, *,
        signatures, qdnn_params, host_params, gen_params,
        har_cfg: HARConfig, mesh=None,
        aac_table: AACTable | None = None,
        costs: EnergyCosts | None = None,
        key: jax.Array | None = None, quant_bits: int = 16,
        k_max: int = 12, m_samples: int = 20, corr_threshold: float = 0.95,
        predictor_window: int = 8, initial_uj: float = 50.0,
        state0: SeekerNodeState | None = None,
        node_keys: jax.Array | None = None,
        labels: jnp.ndarray | None = None,
        alive: jnp.ndarray | None = None,
        brownout: BrownoutConfig | None = None,
        brownout_state0: jnp.ndarray | None = None,
        node_block: int | None = None, donate: bool = True,
        intermittent: IntermittentConfig | None = None,
        intermittent_state0: IntermittentState | None = None,
        aux_params: dict | None = None,
        slot0: int = 0,
        telemetry=None,
        telemetry_state0: dict | None = None,
        tasks: jnp.ndarray | None = None,
        task: TaskLaneConfig | None = None):
    """:func:`seeker_fleet_simulate` with the node axis sharded over a mesh.

    The fleet's node dim is split over the mesh axes the ``"nodes"`` logical
    axis resolves to (:data:`repro.sharding.FLEET_RULES`: ("pod", "data"),
    axes absent from ``mesh`` dropped); the signature bank and all model
    params are replicated.  The whole time scan runs inside the shard_map
    manual region — per-node state never crosses shards; ``bytes_on_wire``,
    ``decision_histogram``, ``completed_frac`` (and ``fleet_accuracy`` when
    ``labels`` is given) are the only collectives, reduced with ``psum``.

    Fleets with N not divisible by the mesh quantum are padded with inert
    nodes — zero harvest, default state, masked out of every aggregate — and
    the padding is sliced off the returned traces.  Integer and energy traces
    (decisions, payload bytes, stored µJ, k) are bit-identical to the
    single-device engine for any N; host logits additionally need a common
    ``node_block`` in both engines to pin XLA's batch-shape-dependent matmul
    lowering (see :func:`_make_fleet_step`), otherwise they match to ~1e-6.

    Args (beyond :func:`seeker_fleet_simulate`):
        mesh: a ``jax.sharding.Mesh``; default is a 1-D ("data",) mesh over
            every visible device.
        labels: optional ground truth enabling the ``fleet_accuracy``
            aggregate: (S,) for a shared stream, or per-node (S, N) tracks
            (sharded over the node axes, padded like harvest).  A shared
            (S,) track with per-node window streams raises.
        alive: optional (N, S) bool churn trace — sharded over the node
            axes; padding nodes are permanently dead.
        brownout: optional :class:`repro.core.energy.BrownoutConfig` — the
            endogenous brown-out lane (see :func:`seeker_fleet_simulate`).
            The flag lives in each shard's local carry; ``brownout_slots``
            and ``brownout_events`` join the psum'd aggregate set.  Padding
            nodes are exogenously dead, so their flag stays frozen — they
            never brown "in" and never count.
        intermittent: optional staged-inference lane (see
            :func:`seeker_fleet_simulate`) — the lane state is sharded over
            the node axes like every other per-node carry; padding nodes
            start (and stay) inert.  Lane emission counters and the
            source-slot-scored accuracy splits join the psum'd set.  A
            common ``node_block`` in both engines makes lane traces
            bit-identical across shard layouts, same as the host logits.
        tasks/task: the heterogeneous-task lane (see
            :func:`seeker_fleet_simulate`).  Task ids are sharded over the
            node axes like harvest; padding nodes get task 0 but are masked
            out of every per-task count, so ``completed_by_task``/
            ``deadline_miss_by_task`` (and ``correct_by_task`` with labels)
            are psum-exact equals of the single-device engine's.

    Extra returns: ``decision_histogram`` (N_DECISIONS,) int32 fleet-wide
    decision counts over alive slots, ``completed``/``alive_slots`` () int32,
    ``brownout_slots``/``brownout_events`` () int32 (psum'd, exactly equal
    to the single-device engine's), ``bytes_on_wire_i32`` (2,) int32 exact
    byte pair, ``completed_frac`` (), ``fleet_accuracy``/``correct`` () when
    ``labels`` is given, ``padded_nodes`` (python int), ``node_axes``
    (python tuple of mesh axis names).
    """
    costs = costs or EnergyCosts()
    key = key if key is not None else jax.random.PRNGKey(0)
    if mesh is None:
        mesh = make_mesh_compat((jax.device_count(),), ("data",))
    axis_names, quantum = node_mesh_axes(mesh)
    if not axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has none of the FLEET_RULES node axes")

    n, s = harvest.shape
    assert windows.ndim in (3, 4), f"windows must be (S,T,C) or (N,S,T,C), got {windows.shape}"
    shared_stream = windows.ndim == 3
    pad = (-n) % quantum
    if shared_stream:
        assert windows.shape[0] == s, (windows.shape, s)
        xs_windows = windows                                  # (S, T, C)
    else:
        assert windows.shape[:2] == (n, s), (windows.shape, n, s)
        xs_windows = jnp.moveaxis(windows, 0, 1)              # (S, N, T, C)
        if pad:   # inert nodes see all-zero windows (corr 0, masked anyway)
            xs_windows = jnp.pad(xs_windows,
                                 ((0, 0), (0, pad)) + ((0, 0),) * 2)
    t = windows.shape[-2]
    labels, per_node_labels = _resolve_labels(labels, s, n, shared_stream)

    state_full = _stack_pad_state(state0, n, pad, predictor_window,
                                  initial_uj)
    keys0 = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n + pad))
    if node_keys is not None:        # resume: real nodes continue their
        keys0 = keys0.at[:n].set(node_keys)     # streams, pad keys inert
    harvest_t = jnp.pad(harvest, ((0, pad), (0, 0))).T        # (S, N+pad)
    # churn trace, padding nodes permanently dead (their ladder never runs)
    alive_t = jnp.pad(_resolve_alive(alive, n, s),
                      ((0, pad), (0, 0))).T                   # (S, N+pad)
    mask = jnp.arange(n + pad) < n
    if labels is None:
        labels_arr = jnp.zeros((s,), jnp.int32)
    elif per_node_labels:            # pad like harvest: inert nodes' track
        labels_arr = jnp.pad(labels, ((0, 0), (0, pad)))      # (S, N+pad)
    else:
        labels_arr = labels

    # brown-out flag, padding nodes forced awake: they are exogenously dead
    # (frozen flag), so they can never brown "in" nor trickle back out
    browned0 = jnp.pad(
        _resolve_brownout0(brownout_state0, state_full, brownout, n),
        (0, pad))
    _validate_intermittent_args(intermittent, intermittent_state0,
                                aux_params, n)
    tasks, task = _resolve_tasks(tasks, task, n)
    host_params = _resolve_task_host(task, host_params)
    if tasks is not None and pad:   # padding nodes run task 0, masked out
        tasks = jnp.pad(tasks, (0, pad))
    tel_spec = _resolve_telemetry(telemetry, intermittent, task)
    run_fn = _build_fleet_run_sharded(
        mesh, axis_names, har_cfg, costs, quant_bits, k_max, m_samples,
        corr_threshold, shared_stream, per_node_labels, node_block,
        brownout, donate, intermittent, tel_spec, task)
    it0 = None
    if intermittent is not None:
        it0 = (intermittent_state0 if intermittent_state0 is not None
               else intermittent_fleet_init(n, har_cfg))
        if pad:   # inert lane rows for padding nodes (never engage: dead)
            filler = intermittent_fleet_init(pad, har_cfg)
            it0 = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), it0, filler)
    xs_slots = jnp.arange(slot0, slot0 + s, dtype=jnp.int32)
    traces, final, aggs = run_fn(
        state_full, keys0, browned0, it0, tasks, xs_windows, harvest_t,
        alive_t, xs_slots, mask, labels_arr, signatures, qdnn_params,
        host_params, gen_params, aac_table, aux_params)
    final_state, final_keys = final.node, final.keys
    final_brownout, final_intermittent = final.brownout, final.intermittent
    tel_delta = final.telemetry

    out = {
        "decisions": traces["decision"][:, :n],               # (S, N)
        "payload_bytes": traces["payload"][:, :n],            # (S, N)
        "stored_uj": traces["stored"][:, :n],                 # (S, N)
        "k_trace": traces["k"][:, :n],                        # (S, N)
        "logits": traces["logits"][:, :n],                    # (S, N, L)
        "preds": jnp.argmax(traces["logits"][:, :n], axis=-1),
        "alive": traces["alive"][:, :n],                      # (S, N)
        "brownout": traces["brownout"][:, :n],                # (S, N)
        "bytes_on_wire": aggs["bytes_on_wire"],
        "bytes_on_wire_i32": aggs["bytes_on_wire_i32"],
        "decision_histogram": aggs["decision_histogram"],
        "completed": aggs["completed"],
        "alive_slots": aggs["alive_slots"],
        "brownout_slots": aggs["brownout_slots"],
        "brownout_events": aggs["brownout_events"],
        "completed_frac": aggs["completed"]
            / jnp.maximum(aggs["alive_slots"], 1),
        "raw_bytes_per_window": jnp.asarray(
            float(raw_payload_bytes(t)) * windows.shape[-1], jnp.float32),
        "final_state": jax.tree_util.tree_map(lambda a: a[:n], final_state),
        "final_keys": final_keys[:n],
        "final_brownout": final_brownout[:n],
        "padded_nodes": pad,
        "node_axes": axis_names,
    }
    if tel_spec is not None:
        out["telemetry"] = metrics_merge(tel_spec, telemetry_state0,
                                         tel_delta)
        out["telemetry_spec"] = tel_spec
    if intermittent is not None:
        out.update({
            "it_emit": traces["it_emit"][:, :n],              # (S, N)
            "it_label": traces["it_label"][:, :n],            # (S, N)
            "it_conf": traces["it_conf"][:, :n],              # (S, N)
            "it_src": traces["it_src"][:, :n],                # (S, N)
            "it_stage": traces["it_stage"][:, :n],            # (S, N)
            "it_full": aggs["it_full"],
            "it_early": aggs["it_early"],
            "final_intermittent": jax.tree_util.tree_map(
                lambda a: a[:n], final_intermittent),
        })
    if labels is not None:
        out["correct"] = aggs["correct"]
        out["fleet_accuracy"] = (aggs["correct"]
                                 / jnp.maximum(aggs["completed"], 1))
        if intermittent is not None:
            out["correct_ladder"] = aggs["correct_ladder"]
            out["it_correct_full"] = aggs["it_correct_full"]
            out["it_correct_early"] = aggs["it_correct_early"]
    if task is not None:
        out["task_names"] = task.names
        out["tasks"] = tasks[:n]
        out["completed_by_task"] = aggs["completed_by_task"]
        out["deadline_miss_by_task"] = aggs["deadline_miss_by_task"]
        if labels is not None:
            out["correct_by_task"] = aggs["correct_by_task"]
            out["accuracy_by_task"] = (
                aggs["correct_by_task"]
                / jnp.maximum(aggs["completed_by_task"], 1))
    return out


def seeker_fleet_simulate_streamed(
        windows, harvest: jnp.ndarray, *, chunk: int,
        signatures, qdnn_params, host_params, gen_params,
        har_cfg: HARConfig, mesh=None,
        aac_table: AACTable | None = None,
        costs: EnergyCosts | None = None,
        key: jax.Array | None = None, quant_bits: int = 16,
        k_max: int = 12, m_samples: int = 20, corr_threshold: float = 0.95,
        predictor_window: int = 8, initial_uj: float = 50.0,
        state0: SeekerNodeState | None = None,
        node_keys: jax.Array | None = None,
        labels: jnp.ndarray | None = None,
        alive: jnp.ndarray | None = None,
        brownout: BrownoutConfig | None = None,
        brownout_state0: jnp.ndarray | None = None,
        node_block: int | None = None, donate: bool = True,
        intermittent: IntermittentConfig | None = None,
        intermittent_state0: IntermittentState | None = None,
        aux_params: dict | None = None,
        telemetry=None,
        telemetry_state0: dict | None = None,
        tasks: jnp.ndarray | None = None,
        task: TaskLaneConfig | None = None):
    """Feed the fleet scan in ``chunk``-slot window segments instead of
    materializing the whole (N, S, T, C) stream up front.

    The driver around the resume contract: each segment runs through
    :func:`seeker_fleet_simulate` (or the sharded engine when ``mesh`` is
    given) with the previous segment's ``final_state``/``final_keys``, so
    the chain is *bitwise* one long run — decisions, payload bytes, stored
    µJ, logits and final keys are identical to a single S-slot call — while
    peak window memory is O(N·chunk·T·C) instead of O(N·S·T·C).  Every
    segment reuses the engines' compile cache (one compiled scan per
    distinct segment length: ``S % chunk`` adds at most one more shape).

    Args (beyond the engines'):
        windows: the stream *source* — either a full array ((S, T, C) shared
            or (N, S, T, C) per-node; the driver slices it) or a callable
            ``windows(start, stop) -> (stop-start, T, C) | (N, stop-start,
            T, C)`` producing each segment on demand.  The callable form is
            the point of streaming: only one chunk of windows ever exists.
        chunk: slots per segment (the last segment may be shorter).
        mesh: run segments through :func:`seeker_fleet_simulate_sharded`.
        brownout: endogenous brown-out config — the flag rides the
            ``state0``/``node_keys`` resume contract bitwise: each segment
            resumes from the previous segment's ``final_brownout``.
        intermittent: staged intermittent-inference lane — the suspended
            activations ride the resume contract too: each segment resumes
            from the previous segment's ``final_intermittent``, and each
            segment is launched at its absolute ``slot0`` offset so a staged
            inference suspended in one segment and emitted in the next keeps
            its globally indexed source slot.  Accuracy for lane emissions is
            rescored over the CONCATENATED traces (a segment cannot see the
            labels of windows captured before its first slot), so
            ``correct``/``fleet_accuracy`` again exactly match one long run.
        telemetry: registry lanes (see :func:`seeker_fleet_simulate`) — each
            segment resumes from the previous segment's ``res["telemetry"]``
            (the :func:`repro.obs.metrics_merge` chain), so the final lanes
            are bitwise-equal to one long telemetered run.
        tasks/task: the heterogeneous-task lane (see
            :func:`seeker_fleet_simulate`) — task ids are static per-node,
            so every segment reuses the same resolved assignment; per-task
            completion/miss counters sum exactly, and ``correct_by_task`` is
            rescored over the concatenated traces (like ``correct``) so
            cross-segment staged emissions land in the right task bucket.

    Returns the engine dict with traces concatenated over time, counter
    aggregates (``decision_histogram``, ``completed``, ``alive_slots``,
    ``brownout_slots``, ``brownout_events``, ``correct``, the
    ``bytes_on_wire_i32`` exact pair) summed exactly, float aggregates
    (``bytes_on_wire``) summed per segment, and
    ``completed_frac``/``fleet_accuracy`` recomputed from the summed
    counters; plus ``n_chunks``.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    n, s = harvest.shape
    if s < 1:
        raise ValueError(
            f"cannot stream an empty deployment: harvest is (N, S)="
            f"({n}, {s}) — S must be >= 1 slot")
    if callable(windows):
        window_fn = windows
    else:
        arr = jnp.asarray(windows)
        if arr.ndim == 3:
            window_fn = lambda a, b: arr[a:b]                 # noqa: E731
        else:
            window_fn = lambda a, b: arr[:, a:b]              # noqa: E731
    labels_full = None if labels is None else jnp.asarray(labels)
    alive_full = None if alive is None else _resolve_alive(alive, n, s)
    tasks, task = _resolve_tasks(tasks, task, n)

    kw = dict(signatures=signatures, qdnn_params=qdnn_params,
              host_params=host_params, gen_params=gen_params,
              har_cfg=har_cfg, aac_table=aac_table, costs=costs, key=key,
              quant_bits=quant_bits, k_max=k_max, m_samples=m_samples,
              corr_threshold=corr_threshold,
              predictor_window=predictor_window, initial_uj=initial_uj,
              brownout=brownout, node_block=node_block, donate=donate,
              intermittent=intermittent, aux_params=aux_params,
              telemetry=telemetry, tasks=tasks, task=task)
    if mesh is not None:
        kw["mesh"] = mesh
    engine = (seeker_fleet_simulate if mesh is None
              else seeker_fleet_simulate_sharded)

    # the segment keys to concatenate/sum come from the lane registry — a
    # new lane that declares trace_keys/counter_keys streams automatically
    active = _active_lanes(intermittent, task, brownout)
    trace_keys = list(fleet_trace_keys(active))
    counter_keys = list(fleet_counter_keys(active))

    tel_spec = _resolve_telemetry(telemetry, intermittent, task)
    state, keys, browned = state0, node_keys, brownout_state0
    it_state = intermittent_state0
    tel_state = telemetry_state0
    parts: list[dict] = []
    counters: dict = {}
    bytes_on_wire = jnp.zeros((), jnp.float32)
    res = None
    for start in range(0, s, chunk):
        stop = min(start + chunk, s)
        seg_kw = dict(kw)
        if labels_full is not None:
            seg_kw["labels"] = labels_full[start:stop]
        if alive_full is not None:
            seg_kw["alive"] = alive_full[:, start:stop]
        if intermittent is not None:
            seg_kw["intermittent_state0"] = it_state
            seg_kw["slot0"] = start
        if tel_spec is not None:
            seg_kw["telemetry_state0"] = tel_state
        with obs_trace.span("fleet.segment", cat="fleet",
                            args={"start": start, "stop": stop},
                            flush=lambda: res["decisions"]):
            res = engine(window_fn(start, stop), harvest[:, start:stop],
                         state0=state, node_keys=keys,
                         brownout_state0=browned, **seg_kw)
        state, keys = res["final_state"], res["final_keys"]
        browned = res["final_brownout"]
        if intermittent is not None:
            it_state = res["final_intermittent"]
        if tel_spec is not None:
            tel_state = res["telemetry"]
        parts.append({k: res[k] for k in trace_keys})
        for k in counter_keys:
            if k in res:
                counters[k] = counters.get(k, 0) + res[k]
        # the exact byte pair needs its carry propagated each segment: a
        # segment's lo digit is < N * 2**16, so adding it to an ALREADY
        # NORMALIZED lo (< 2**16) stays exact in int32 for N < 32768 — the
        # same node bound as the pair itself — while an un-normalized
        # running lo would overflow after ~2**15/N segments
        pair = counters.get("bytes_on_wire_i32",
                            jnp.zeros((2,), jnp.int32)) \
            + res["bytes_on_wire_i32"]
        counters["bytes_on_wire_i32"] = jnp.stack(
            [pair[0] + (pair[1] >> 16), pair[1] & 0xFFFF])
        bytes_on_wire = bytes_on_wire + res["bytes_on_wire"]

    out = {k: jnp.concatenate([p[k] for p in parts], axis=0)
           for k in parts[0]}
    out.update(counters)
    out.update({
        "bytes_on_wire": bytes_on_wire,
        "completed_frac": counters["completed"]
            / jnp.maximum(counters["alive_slots"], 1),
        "raw_bytes_per_window": res["raw_bytes_per_window"],
        "final_state": state,
        "final_keys": keys,
        "final_brownout": browned,
        "n_chunks": -(-s // chunk),
    })
    if tel_spec is not None:
        out["telemetry"] = tel_state
        out["telemetry_spec"] = tel_spec
    if intermittent is not None:
        out["final_intermittent"] = it_state
    if "correct" in counters:
        if intermittent is not None:
            # a segment cannot score an emission whose window was captured
            # in an EARLIER segment (its label is out of the segment's
            # view), so the per-segment it_correct counters undercount
            # exactly the cross-segment completions — rescore the lane over
            # the concatenated traces, where every source slot is visible
            rel = out["it_src"]                  # driver runs from slot 0
            valid = (out["it_emit"] > 0) & out["alive"] & (rel >= 0)
            rel_c = jnp.clip(rel, 0, s - 1)
            lab = (jnp.take_along_axis(labels_full.astype(jnp.int32), rel_c,
                                       axis=0)
                   if labels_full.ndim == 2
                   else labels_full.astype(jnp.int32)[rel_c])
            it_ok = (out["it_label"] == lab) & valid
            out["it_correct_full"] = jnp.sum(
                (it_ok & (out["it_emit"] == 2)).astype(jnp.int32))
            out["it_correct_early"] = jnp.sum(
                (it_ok & (out["it_emit"] == 1)).astype(jnp.int32))
            out["correct"] = (counters["correct_ladder"]
                              + out["it_correct_full"]
                              + out["it_correct_early"])
        out["fleet_accuracy"] = (out["correct"]
                                 / jnp.maximum(counters["completed"], 1))
    if task is not None:
        out["task_names"] = task.names
        out["tasks"] = tasks
        if labels_full is not None:
            # like ``correct``: per-segment correct_by_task counters cannot
            # see cross-segment staged emissions, so rescore the split once
            # over the concatenated traces (integer counts — exact)
            tasks_b = jnp.broadcast_to(tasks[None, :], out["alive"].shape)
            lab_t = labels_full.astype(jnp.int32)
            ok = out["preds"] == (lab_t if lab_t.ndim == 2
                                  else lab_t[:, None])
            if intermittent is None:
                sent = (out["decisions"] != DEFER) & out["alive"]
                out["correct_by_task"] = categorical_counts(
                    tasks_b, task.n_tasks, ok & sent)
            else:
                sent = ((out["decisions"] != DEFER)
                        & (out["decisions"] != D6_PARTIAL) & out["alive"])
                ladder_sent = sent & (out["decisions"] <= D4_SAMPLING)
                out["correct_by_task"] = (
                    categorical_counts(tasks_b, task.n_tasks,
                                       ok & ladder_sent)
                    + categorical_counts(tasks_b, task.n_tasks,
                                         it_ok & (out["it_emit"] == 2))
                    + categorical_counts(tasks_b, task.n_tasks,
                                         it_ok & (out["it_emit"] == 1)))
            out["accuracy_by_task"] = (
                out["correct_by_task"]
                / jnp.maximum(counters["completed_by_task"], 1))
    if mesh is not None:
        out["padded_nodes"] = res["padded_nodes"]
        out["node_axes"] = res["node_axes"]
    return out
