"""Fleet-scale batched Seeker simulator.

The single-node simulation (:func:`repro.serving.edge_host.seeker_simulate`)
models one EH-WSN; production serving means *fleets* — thousands of
independent sensor nodes (n_sensors x n_devices), each with its own
supercapacitor charge, harvest modality, predictor history, and memoization
phase.  :func:`seeker_fleet_simulate` runs all of them in ONE jitted
``lax.scan`` over time:

* the carry is a *stacked* ``SeekerNodeState`` (leading node axis N) plus a
  per-node PRNG key array — node ``i``'s stream is ``fold_in(key, i)``, so a
  fleet of N nodes is bit-compatible with N independent single-node runs;
* inside the step, the memoization hot path runs once for the whole fleet
  through the batched :func:`repro.kernels.signature_corr_op`
  ((N, T, C) x (L, T, C) -> (N, L); Pallas MXU kernel on TPU, the validated
  jnp oracle elsewhere), and the rest of the paper's Fig.-8 flow is
  ``jax.vmap`` of the per-node step — no Python loop over nodes anywhere;
* the scan carry is donated to the jitted run, so the stacked node state is
  updated in place across time steps instead of being reallocated.

Harvest traces are per-node (shape (N, S)): heterogeneous energy income is
the point of fleet simulation — per-node energy dynamics diverge (Gobieski et
al., arXiv:1810.07751), and the Seeker companion evaluation (arXiv:2204.13106)
runs exactly such heterogeneous wearable fleets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.aac import AACTable
from ..core.coreset import raw_payload_bytes
from ..core.energy import EnergyCosts, predictor_init
from ..kernels.ops import signature_corr_op
from ..models.har import HARConfig
from .edge_host import (SeekerNodeState, seeker_host_step,
                        seeker_sensor_step_given_corr)

__all__ = ["fleet_node_init", "seeker_fleet_simulate"]


def fleet_node_init(n_nodes: int, predictor_window: int = 8,
                    initial_uj: float = 50.0) -> SeekerNodeState:
    """Stacked state for ``n_nodes`` nodes (leading node axis on every leaf)."""
    return SeekerNodeState(
        stored_uj=jnp.full((n_nodes,), initial_uj, jnp.float32),
        predictor=predictor_init(predictor_window, batch=n_nodes),
        prev_label=jnp.zeros((n_nodes,), jnp.int32))


@functools.lru_cache(maxsize=32)
def _build_fleet_run(har_cfg: HARConfig, costs: EnergyCosts, quant_bits: int,
                     k_max: int, m_samples: int, corr_threshold: float,
                     shared_stream: bool, donate: bool):
    """Compile-cached fleet scan, keyed on the static configuration.

    All arrays (params, signatures, windows, state) are jit *arguments*, so
    repeated simulations with the same config — the benchmark's timed
    iterations, a serving loop — reuse the compiled executable instead of
    re-tracing a fresh closure each call.
    """

    def run(state0, keys0, xs_w, xs_h, signatures, qdnn_params, host_params,
            gen_params, aac_table):
        n = keys0.shape[0]
        t = xs_w.shape[-2]

        def step(carry, inp):
            state, keys = carry
            win_t, harv_t = inp
            if shared_stream:
                win_t = jnp.broadcast_to(win_t[None], (n,) + win_t.shape)
            # same split discipline as the single-node scan:
            # carry, sensor, host
            ks = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)  # (N,3,2)

            # memoization hot path: one batched signature-bank correlation
            # for the entire fleet (the Pallas kernel's (B, L) MXU tiling on
            # TPU, the validated jnp oracle elsewhere)
            corr = signature_corr_op(win_t, signatures)       # (N, L)

            out = jax.vmap(
                lambda w, st, h, co, kk: seeker_sensor_step_given_corr(
                    w, st, h, co, qdnn_params=qdnn_params, har_cfg=har_cfg,
                    aac_table=aac_table, costs=costs, key=kk, k_max=k_max,
                    m_samples=m_samples, quant_bits=quant_bits,
                    corr_threshold=corr_threshold)
            )(win_t, state, harv_t, corr, ks[:, 1])
            host_logits = jax.vmap(
                lambda o, kk: seeker_host_step(
                    o, host_params=host_params, gen_params=gen_params,
                    har_cfg=har_cfg, key=kk, t=t)
            )(out, ks[:, 2])
            trace = {"decision": out.decision, "payload": out.payload_bytes,
                     "stored": out.state.stored_uj, "k": out.coreset_k,
                     "logits": host_logits}
            return (out.state, ks[:, 0]), trace

        (state, _), traces = jax.lax.scan(step, (state0, keys0), (xs_w, xs_h))
        return traces, state

    # donate the stacked node state (it is returned, so XLA can alias it);
    # the key array is consumed without a matching output and stays undonated
    return jax.jit(run, donate_argnums=(0,) if donate else ())


def seeker_fleet_simulate(windows: jnp.ndarray, harvest: jnp.ndarray, *,
                          signatures, qdnn_params, host_params, gen_params,
                          har_cfg: HARConfig,
                          aac_table: AACTable | None = None,
                          costs: EnergyCosts | None = None,
                          key: jax.Array | None = None, quant_bits: int = 16,
                          k_max: int = 12, m_samples: int = 20,
                          corr_threshold: float = 0.95,
                          predictor_window: int = 8, initial_uj: float = 50.0,
                          donate: bool = True):
    """Simulate N independent Seeker nodes over S time slots in one scan.

    Args:
        windows: (S, T, C) — one stream shared by every node (the sensor-
            ensemble deployment), or (N, S, T, C) — a stream per node.
        harvest: (N, S) µJ harvested per node per slot (heterogeneous traces;
            see :func:`repro.core.energy.fleet_harvest_traces`).
        key: fleet PRNG; node ``i`` uses ``fold_in(key, i)`` and then splits
            exactly like the single-node simulator, so an N=1 fleet
            reproduces a single-node run.
        donate: donate the stacked node state to the jitted run so XLA can
            alias its buffers into the returned final state (the key array
            has no matching output and is never donated).

    Returns a dict of per-node traces, time-major:
        ``decisions``/``payload_bytes``/``stored_uj``/``k_trace``: (S, N),
        ``logits``/``preds``: (S, N, L) / (S, N),
        ``bytes_on_wire``: () total payload bytes the fleet transmitted,
        ``raw_bytes_per_window``: () the uncompressed (T, C) baseline per
            window (all channels, the benchmarks' raw-equivalent convention),
        ``final_state``: stacked ``SeekerNodeState``.
    """
    costs = costs or EnergyCosts()
    key = key if key is not None else jax.random.PRNGKey(0)
    n, s = harvest.shape
    assert windows.ndim in (3, 4), f"windows must be (S,T,C) or (N,S,T,C), got {windows.shape}"
    shared_stream = windows.ndim == 3
    if shared_stream:
        assert windows.shape[0] == s, (windows.shape, s)
        xs_windows = windows                                  # (S, T, C)
    else:
        assert windows.shape[:2] == (n, s), (windows.shape, n, s)
        xs_windows = jnp.moveaxis(windows, 0, 1)              # (S, N, T, C)
    t = windows.shape[-2]

    state0 = fleet_node_init(n, predictor_window, initial_uj)
    keys0 = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    run_fn = _build_fleet_run(har_cfg, costs, quant_bits, k_max, m_samples,
                              corr_threshold, shared_stream, donate)
    traces, final_state = run_fn(state0, keys0, xs_windows, harvest.T,
                                 signatures, qdnn_params, host_params,
                                 gen_params, aac_table)

    return {
        "decisions": traces["decision"],                      # (S, N)
        "payload_bytes": traces["payload"],                   # (S, N)
        "stored_uj": traces["stored"],                        # (S, N)
        "k_trace": traces["k"],                               # (S, N)
        "logits": traces["logits"],                           # (S, N, L)
        "preds": jnp.argmax(traces["logits"], axis=-1),       # (S, N)
        "bytes_on_wire": jnp.sum(traces["payload"]),
        "raw_bytes_per_window": jnp.asarray(
            float(raw_payload_bytes(t)) * windows.shape[-1], jnp.float32),
        "final_state": final_state,
    }
