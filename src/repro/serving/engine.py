"""LM serving engine: prefill + batched autoregressive decode.

``generate`` runs the standard two-phase serving loop: one full-sequence
prefill builds the cache, then ``lax.scan`` over decode steps.  Sampling is
greedy or temperature; everything jits into two programs (prefill_step /
decode-scan), matching the two dry-run serving shapes (prefill_* and
decode_* / long_*).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import decode_step, forward
from ..models.config import ModelConfig

__all__ = ["greedy_sample", "temperature_sample", "generate"]


def greedy_sample(logits: jnp.ndarray, key=None) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jnp.ndarray, key: jax.Array,
                       temperature: float = 0.8) -> jnp.ndarray:
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompt: jnp.ndarray, max_new: int,
             key: jax.Array | None = None, temperature: float = 0.0,
             cache_margin: int = 0, **extra):
    """prompt (B, S) int32 -> (B, max_new) generated tokens.

    extra: enc_frames / patch_embeds for the multimodal archs."""
    b, s = prompt.shape
    cache_len = s + max_new + cache_margin
    logits, cache = forward(params, cfg, prompt, return_cache=True,
                            cache_len=cache_len, **extra)
    # the first generated token comes from the last prefill logit
    first = (greedy_sample(logits[:, -1]) if temperature == 0.0 else
             temperature_sample(logits[:, -1], key, temperature))

    def step(carry, k):
        cache, tok = carry
        lg, cache = decode_step(params, cfg, cache, tok[:, None])
        nxt = (greedy_sample(lg[:, 0]) if temperature == 0.0 else
               temperature_sample(lg[:, 0], k, temperature))
        return (cache, nxt), nxt

    keys = (jax.random.split(key, max_new - 1) if key is not None
            else jnp.zeros((max_new - 1, 2), jnp.uint32))
    (_, _), rest = jax.lax.scan(step, (cache, first), keys)
    return jnp.concatenate([first[:, None], rest.T], axis=1)
