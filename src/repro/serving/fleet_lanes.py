"""The fleet carry-lane registry: one registration site per per-node lane.

PR 5 (brown-outs), PR 7 (the intermittent lane) and PR 8 (telemetry) each
paid a multiplicative cost to land one per-node capability: a positional,
conditionally-shaped scan carry (``state, keys, browned[, it][, metrics]``)
threaded through three near-duplicate engine bodies plus the resume
contract, the streamed driver's key lists, the telemetry spec and the docs.
This module makes that contract *structural*:

* :class:`FleetCarry` is the typed scan carry of ALL three fleet engines
  (single-device, sharded, streamed segments).  Absent lanes are ``None`` —
  a ``None`` field is an empty pytree, so jit signatures, scan carries and
  ``shard_map`` specs need no conditional shapes, and ``lane=None`` stays
  bitwise-off by construction (no inputs, no ops);
* :class:`FleetLane` is one lane's REGISTRATION: its initializer, its
  freeze-on-dead behavior, its resume-contract fields, the result keys of
  its psum'd aggregates, the per-segment trace/counter keys the streamed
  driver chains, and the telemetry lanes it owns.  The engines, the
  streamed driver, :func:`repro.serving.fleet.fleet_telemetry_spec`, the
  resume-contract test harness (``tests/test_resume_contract.py``) and the
  lane-conformance check (``tests/test_lane_conformance.py``) all derive
  from :data:`FLEET_LANES` — adding a lane means adding ONE entry here
  (plus the lane's own step function), not editing six engine sites;
* the heterogeneous-task lane (:class:`TaskLaneConfig`) is the first lane
  shipped through the protocol: per-node task identity (HAR wearables and
  bearing-vibration monitors sharing one fleet), task-scaled per-stage
  energy costs, optional per-task host DNNs, and per-task
  completed/correct/deadline-miss splits in the psum'd aggregates.

Intermittent-computing systems (Islam et al., arXiv:2503.06663; Gobieski et
al., arXiv:1810.07751) live or die on exactly this kind of disciplined
suspended-state contract; docs/RESUME_CONTRACT.md documents the obligations
each registration declares.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core.decision import DEFER, D6_PARTIAL, N_INTERMITTENT_DECISIONS
from ..core.energy import BEARING_COST_SCALE
from ..obs import (Lane, counter, counter_add, gauge, gauge_set, histogram,
                   hist_observe)

__all__ = ["FleetCarry", "FleetLane", "FLEET_LANES", "TaskLaneConfig",
           "fleet_lane", "fleet_telemetry_lanes", "fleet_trace_keys",
           "fleet_counter_keys", "fleet_task_assignment", "stack_task_params",
           "FREEZE_KINDS"]

N_DECISIONS = DEFER + 1   # D0..D4 + DEFER: bins of the ladder histogram


class FleetCarry(NamedTuple):
    """The typed scan carry shared by every fleet engine.

    One field per carried lane, in registration order; an absent lane is
    ``None`` (an empty pytree — no jit inputs, no scan slots, no shard_map
    leaves), which is what keeps ``lane=None`` engines bitwise-identical to
    engines built before the lane existed.  Input lanes (churn's ``alive``
    trace) and static lanes (task identity) are per-slot/per-run arguments,
    not carry fields — see their registrations.
    """

    node: Any            # stacked SeekerNodeState — always present
    keys: Any            # (N, 2) per-node PRNG keys — always present
    brownout: Any        # (N,) bool browned-out flag — always present (inert
                         # all-False when brownout config is None)
    intermittent: Any    # stacked IntermittentState | None
    telemetry: Any       # {lane name: int32 array} | None


# freeze-on-dead vocabulary a lane must declare (conformance-checked):
#   keep     - dead/browned-out slots hold the lane's carry bitwise frozen
#              (the engine's keep() select)
#   trickle  - keep, except a declared physical side-channel still runs
#              (the brown-out lane's supercap trickle-charge)
#   merge    - the lane is a fleet-level accumulator, never frozen per node
#              (telemetry: dead nodes simply contribute zero)
#   input    - the lane is a per-slot input, not carried state (churn)
#   static   - per-node constants; freezing is moot (task identity)
FREEZE_KINDS = ("keep", "trickle", "merge", "input", "static")


@dataclasses.dataclass(frozen=True)
class FleetLane:
    """One lane's single registration site.

    ``init`` is the lane's initializer as a ``"module:attr"`` reference (the
    conformance check resolves it); ``resume_in``/``resume_out`` are the
    engine kwargs / result keys of its resume-contract slice;
    ``aggregates`` the result keys of its (psum'd) fleet aggregates;
    ``trace_keys``/``counter_keys`` what the streamed driver concatenates /
    sums per segment; ``telemetry`` the registry lanes it owns (a function
    of the active-lane set — the decision histogram widens when the
    intermittent lane is on) and ``telemetry_update`` advances them one
    slot from the engine's masked ``out_trace``.

    ``config_kwarg`` names the engine argument whose non-``None`` value
    activates the lane (``None`` = always on); ``outputs_when_off`` marks
    lanes whose traces/aggregates/telemetry are emitted even when inactive
    (the brown-out flag lane: the carry slot and its counters exist — as
    inert zeros — in every engine, which is what keeps ``brownout=None``
    bitwise).
    """

    name: str
    doc: str
    carry_field: str | None
    config_kwarg: str | None
    init: str
    freeze: str
    resume_in: tuple[str, ...]
    resume_out: tuple[str, ...]
    aggregates: tuple[str, ...]
    trace_keys: tuple[str, ...]
    counter_keys: tuple[str, ...]
    telemetry: Callable[[frozenset], tuple[Lane, ...]] | None = None
    telemetry_update: Callable[..., dict] | None = None
    outputs_when_off: bool = False

    def __post_init__(self):
        if self.freeze not in FREEZE_KINDS:
            raise ValueError(
                f"lane {self.name!r}: freeze must be one of {FREEZE_KINDS}, "
                f"got {self.freeze!r}")
        if self.carry_field is not None:
            if self.carry_field not in FleetCarry._fields:
                raise ValueError(
                    f"lane {self.name!r}: carry_field {self.carry_field!r} "
                    f"is not a FleetCarry field {FleetCarry._fields}")
            if not self.resume_in or not self.resume_out:
                raise ValueError(
                    f"lane {self.name!r} carries state but declares no "
                    f"resume contract — streamed segment chains would "
                    f"silently replay it")

    def active(self, active_names: frozenset) -> bool:
        """Does this lane emit traces/aggregates for this engine build?"""
        return (self.config_kwarg is None or self.outputs_when_off
                or self.name in active_names)


@dataclasses.dataclass(frozen=True)
class TaskLaneConfig:
    """Heterogeneous multi-workload fleets: per-node task identity.

    The paper evaluates Seeker on HAR *and* predictive maintenance; a mixed
    fleet assigns every node a task id (``tasks`` (N,) int32 — HAR wearables
    and bearing-vibration monitors sharing one deployment).  ``cost_scale``
    scales the WHOLE Table-2 cost ladder (and the intermittent lane's
    per-stage costs) per task: a bearing monitor's 48-kHz vibration
    front-end pays more per window than a 50-Hz IMU — the default scale is
    :data:`repro.core.energy.BEARING_COST_SCALE`.

    ``per_task_host`` switches the host/DNN step to per-task weights: pass
    ``host_params`` as a length-``n_tasks`` tuple of trees (stacked by
    :func:`stack_task_params`; node ``i`` infers through tree
    ``tasks[i]``).  The backbone tensor shapes stay shared — mixed fleets
    run one window shape, e.g. bearing streams resampled to the HAR (T, C)
    grid (:func:`repro.data.sensors.bearing_stream` with ``t=60``, tiled to
    3 channels) — so the lane changes WHICH weights a node runs, never the
    compiled shapes.

    Frozen + hashable: the config keys the engines' compile caches like
    ``BrownoutConfig`` and ``IntermittentConfig`` do.
    """

    names: tuple[str, ...] = ("har", "bearing")
    cost_scale: tuple[float, ...] = (1.0, BEARING_COST_SCALE)
    per_task_host: bool = False

    def __post_init__(self):
        if len(self.names) < 1:
            raise ValueError("TaskLaneConfig needs at least one task")
        if len(self.cost_scale) != len(self.names):
            raise ValueError(
                f"TaskLaneConfig: {len(self.names)} task names but "
                f"{len(self.cost_scale)} cost scales")
        if any(not s > 0.0 for s in self.cost_scale):
            raise ValueError(
                f"TaskLaneConfig.cost_scale must be > 0, got "
                f"{self.cost_scale}")

    @property
    def n_tasks(self) -> int:
        return len(self.names)


def fleet_task_assignment(n_nodes: int, n_tasks: int = 2) -> jnp.ndarray:
    """Round-robin (N,) task ids — the default mixed-fleet layout (task
    populations within one node of equal, interleaved so every shard of a
    sharded fleet carries every task)."""
    return (jnp.arange(n_nodes, dtype=jnp.int32) % n_tasks).astype(jnp.int32)


def stack_task_params(params_by_task) -> Any:
    """Stack per-task param trees leaf-wise onto a leading task axis.  The
    engines gather node ``i``'s tree with ``tree_map(lambda p: p[tasks[i]])``
    inside the vmapped step — same compiled shapes for every node."""
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves, axis=0), *params_by_task)


# ---------------------------------------------------------------------------
# Telemetry ownership: each lane declares the registry lanes it owns and how
# one slot of the engine's masked out_trace advances them.  The spec in
# repro.serving.fleet.fleet_telemetry_spec is the union of these — spec and
# carry cannot drift apart.
# ---------------------------------------------------------------------------

def _sent_mask(out_trace: dict, active: frozenset) -> jnp.ndarray:
    """Completed == put a result on the wire: not DEFER, and (with the
    intermittent lane) not a D6 suspension."""
    dec = out_trace["decision"]
    sent = (dec != DEFER) & out_trace["alive"]
    if "intermittent" in active:
        sent = sent & (dec != D6_PARTIAL)
    return sent


def _node_telemetry(active: frozenset) -> tuple[Lane, ...]:
    n_bins = (N_INTERMITTENT_DECISIONS if "intermittent" in active
              else N_DECISIONS)
    return (counter("fleet.wire_bytes", "B"),
            counter("fleet.completed", "windows"),
            counter("fleet.alive_slots", "slots"),
            gauge("fleet.stored_uj", "uJ"),
            histogram("fleet.decisions", n_bins, log=False,
                      unit="decisions"))


def _node_telemetry_update(spec, metrics, out_trace, *, exo_alive_t, active,
                           tasks=None):
    act = out_trace["alive"]
    m = counter_add(spec, metrics, "fleet.wire_bytes",
                    out_trace["payload"], act)
    m = counter_add(spec, m, "fleet.completed",
                    _sent_mask(out_trace, active))
    m = counter_add(spec, m, "fleet.alive_slots", act)
    m = gauge_set(spec, m, "fleet.stored_uj",
                  jnp.sum(jnp.where(
                      act, jnp.floor(out_trace["stored"]).astype(jnp.int32),
                      0)))
    return hist_observe(spec, m, "fleet.decisions", out_trace["decision"],
                        act)


def _brownout_telemetry(active: frozenset) -> tuple[Lane, ...]:
    return (counter("fleet.brownout_slots", "slots"),
            counter("fleet.brownout_events", "events"))


def _brownout_telemetry_update(spec, metrics, out_trace, *, exo_alive_t,
                               active, tasks=None):
    m = counter_add(spec, metrics, "fleet.brownout_slots",
                    out_trace["brownout"] & exo_alive_t)
    return counter_add(spec, m, "fleet.brownout_events",
                       out_trace["bo_event"])


def _intermittent_telemetry(active: frozenset) -> tuple[Lane, ...]:
    return (counter("fleet.it_full", "windows"),
            counter("fleet.it_early", "windows"))


def _intermittent_telemetry_update(spec, metrics, out_trace, *, exo_alive_t,
                                   active, tasks=None):
    act = out_trace["alive"]
    emit = out_trace["it_emit"]
    m = counter_add(spec, metrics, "fleet.it_full", (emit == 2) & act)
    return counter_add(spec, m, "fleet.it_early", (emit == 1) & act)


def _task_telemetry(active: frozenset) -> tuple[Lane, ...]:
    # per-task completion counts as a categorical histogram over task ids;
    # the bin count rides the active-set tag "task:K" (the spec is a pure
    # function of the active set, so engines with different task counts get
    # different — correctly sized — specs)
    for tag in active:
        if tag.startswith("task:"):
            n_tasks = int(tag.split(":", 1)[1])
            return (histogram("fleet.task_completed", max(n_tasks, 2),
                              log=False, unit="windows"),)
    return ()


def _task_telemetry_update(spec, metrics, out_trace, *, exo_alive_t, active,
                           tasks=None):
    sent = _sent_mask(out_trace, active)
    return hist_observe(spec, metrics, "fleet.task_completed",
                        jnp.broadcast_to(tasks, sent.shape), sent)


# ---------------------------------------------------------------------------
# THE registry.  Order = carry order = documentation order.
# ---------------------------------------------------------------------------

FLEET_LANES: tuple[FleetLane, ...] = (
    FleetLane(
        name="node",
        doc="Stacked per-node Seeker state: supercap charge, harvest "
            "predictor, AAC label continuity.",
        carry_field="node", config_kwarg=None,
        init="repro.serving.fleet:fleet_node_init", freeze="keep",
        resume_in=("state0",), resume_out=("final_state",),
        aggregates=("bytes_on_wire", "bytes_on_wire_i32",
                    "decision_histogram", "completed", "alive_slots",
                    "correct"),
        trace_keys=("decisions", "payload_bytes", "stored_uj", "k_trace",
                    "logits", "preds"),
        counter_keys=("decision_histogram", "completed", "alive_slots",
                      "correct"),
        telemetry=_node_telemetry, telemetry_update=_node_telemetry_update),
    FleetLane(
        name="prng",
        doc="Per-node PRNG keys: node i's stream is fold_in(key, i), split "
            "3-ways per slot (carry/sensor/host) exactly like the "
            "single-node scan.",
        carry_field="keys", config_kwarg=None,
        init="repro.serving.fleet:fleet_node_keys", freeze="keep",
        resume_in=("node_keys",), resume_out=("final_keys",),
        aggregates=(), trace_keys=(), counter_keys=()),
    FleetLane(
        name="churn",
        doc="Exogenous dropout/rejoin: an (N, S) alive trace input; dead "
            "slots freeze every 'keep' lane and emit DEFER with zero "
            "payload.",
        carry_field=None, config_kwarg="alive",
        init="repro.core.energy:fleet_alive_traces", freeze="input",
        resume_in=(), resume_out=(),
        aggregates=(), trace_keys=("alive",), counter_keys=(),
        outputs_when_off=True),
    FleetLane(
        name="brownout",
        doc="Endogenous churn: supercap-hysteresis brown-out flag in the "
            "carry; browned-out slots freeze like dead ones but the "
            "harvester keeps trickle-charging.",
        carry_field="brownout", config_kwarg="brownout",
        init="repro.serving.fleet:_resolve_brownout0", freeze="trickle",
        resume_in=("brownout_state0",), resume_out=("final_brownout",),
        aggregates=("brownout_slots", "brownout_events"),
        trace_keys=("brownout",),
        counter_keys=("brownout_slots", "brownout_events"),
        telemetry=_brownout_telemetry,
        telemetry_update=_brownout_telemetry_update,
        outputs_when_off=True),
    FleetLane(
        name="intermittent",
        doc="Staged partial inference: suspended activations ride the carry "
            "across slots and brown-outs; DEFER slots become D6/D7/D8.",
        carry_field="intermittent", config_kwarg="intermittent",
        init="repro.serving.edge_host:intermittent_fleet_init", freeze="keep",
        resume_in=("intermittent_state0", "slot0"),
        resume_out=("final_intermittent",),
        aggregates=("it_full", "it_early", "correct_ladder",
                    "it_correct_full", "it_correct_early"),
        trace_keys=("it_emit", "it_label", "it_conf", "it_src", "it_stage"),
        counter_keys=("it_full", "it_early", "correct_ladder"),
        telemetry=_intermittent_telemetry,
        telemetry_update=_intermittent_telemetry_update),
    FleetLane(
        name="telemetry",
        doc="Registry metrics lanes riding the carry; a fleet-level "
            "accumulator merged across segments, never frozen per node.",
        carry_field="telemetry", config_kwarg="telemetry",
        init="repro.obs:metrics_init", freeze="merge",
        resume_in=("telemetry_state0",), resume_out=("telemetry",),
        aggregates=(), trace_keys=(), counter_keys=()),
    FleetLane(
        name="task",
        doc="Heterogeneous multi-workload fleets: static per-node task ids "
            "switch energy-cost scale, host weights and the per-task "
            "aggregate splits.",
        carry_field=None, config_kwarg="task",
        init="repro.serving.fleet_lanes:fleet_task_assignment",
        freeze="static",
        resume_in=(), resume_out=(),
        aggregates=("completed_by_task", "deadline_miss_by_task",
                    "correct_by_task"),
        trace_keys=(), counter_keys=("completed_by_task",
                                     "deadline_miss_by_task"),
        telemetry=_task_telemetry, telemetry_update=_task_telemetry_update),
)


def fleet_lane(name: str) -> FleetLane:
    """Look one lane up by name (KeyError with the known set otherwise)."""
    for ln in FLEET_LANES:
        if ln.name == name:
            return ln
    raise KeyError(f"no fleet lane {name!r}; registered: "
                   f"{[ln.name for ln in FLEET_LANES]}")


def fleet_telemetry_lanes(active: frozenset) -> tuple[Lane, ...]:
    """Union of the telemetry lanes every active (or always-emitting) lane
    owns — the registry-derived body of
    :func:`repro.serving.fleet.fleet_telemetry_spec`."""
    out: list[Lane] = []
    for ln in FLEET_LANES:
        if ln.telemetry is not None and ln.active(active):
            out.extend(ln.telemetry(active))
    return tuple(out)


def fleet_trace_keys(active: frozenset) -> tuple[str, ...]:
    """Per-segment (S, N) trace keys the streamed driver concatenates, in
    registration order."""
    return tuple(k for ln in FLEET_LANES if ln.active(active)
                 for k in ln.trace_keys)


def fleet_counter_keys(active: frozenset) -> tuple[str, ...]:
    """Additive integer aggregate keys the streamed driver sums exactly
    across segments, in registration order."""
    return tuple(k for ln in FLEET_LANES if ln.active(active)
                 for k in ln.counter_keys)
