from .engine import generate, greedy_sample, temperature_sample  # noqa: F401
from .edge_host import (  # noqa: F401
    SeekerNodeState, seeker_node_init, seeker_sensor_step,
    seeker_sensor_step_given_corr, seeker_host_step, seeker_simulate,
    seeker_simulate_reference, edge_host_serve_step, fleet_serve_step,
    WirePayload, encode_wire_coresets, decode_wire_coresets,
    wire_payload_nbytes, wire_payload_to_bytes, wire_payload_from_bytes,
    WireSamplePayload, encode_wire_samples, decode_wire_samples,
    wire_sample_nbytes, IntermittentState, intermittent_node_init,
    intermittent_fleet_init, IntermittentLaneOut, intermittent_lane_step,
)
from .fleet import (  # noqa: F401
    fleet_node_init, fleet_node_keys, fleet_telemetry_spec,
    seeker_fleet_simulate, seeker_fleet_simulate_sharded,
    seeker_fleet_simulate_streamed, wire_bytes_exact,
)
from .fleet_lanes import (  # noqa: F401
    FLEET_LANES, FleetCarry, FleetLane, TaskLaneConfig, fleet_counter_keys,
    fleet_lane, fleet_task_assignment, fleet_telemetry_lanes,
    fleet_trace_keys, stack_task_params,
)
