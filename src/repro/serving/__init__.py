from .engine import generate, greedy_sample, temperature_sample  # noqa: F401
from .edge_host import (  # noqa: F401
    SeekerNodeState, seeker_node_init, seeker_sensor_step, seeker_host_step,
    seeker_simulate, edge_host_serve_step,
)
