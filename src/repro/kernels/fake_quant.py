"""Pallas TPU kernel: fused symmetric fake-quantization (16/12/8-bit).

The paper deploys two post-training-quantized DNN copies (16- and 12-bit) on
the sensor's ReRAM crossbars (§4, C6).  On TPU the analogue is fake-quant
(quantize-dequantize) fused into a single VMEM pass: ``round(clip(x/s))*s``
with the scale precomputed per tensor (or per output channel).

The kernel is deliberately trivial compute — its value is *fusion*: one HBM
round-trip instead of the 4 ops XLA would otherwise materialize, and it is
the template every quantized layer in the serving path reuses.  Tiles are
(block_r, block_c) with the last dim 128-aligned by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fake_quant_pallas"]


def _quant_kernel(x_ref, scale_ref, out_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)                  # (1, 1) or (1, BC)
    q = jnp.round(x / s)
    q = jnp.clip(q, -qmax, qmax)
    out_ref[...] = q * s


@functools.partial(jax.jit, static_argnames=("bits", "per_channel", "block_r",
                                             "block_c", "interpret"))
def fake_quant_pallas(x2d: jnp.ndarray, bits: int, per_channel: bool = False,
                      block_r: int = 256, block_c: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """Fake-quantize a 2-D tensor (rows, channels). Wrapper pads/reshapes.

    Args:
        x2d: (R, C) float tensor, R % block_r == 0, C % block_c == 0.
        bits: precision (paper: 16 and 12; 8 for the ablation of Fig. 2c).
        per_channel: scale per last-dim channel instead of per tensor.
    """
    r, c = x2d.shape
    block_r = min(block_r, r)
    block_c = min(block_c, c)
    assert r % block_r == 0 and c % block_c == 0
    qmax = 2.0 ** (bits - 1) - 1.0
    if per_channel:
        amax = jnp.max(jnp.abs(x2d), axis=0, keepdims=True)  # (1, C)
        scale_spec = pl.BlockSpec((1, block_c), lambda i, j: (0, j))
    else:
        amax = jnp.max(jnp.abs(x2d)).reshape(1, 1)
        scale_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    scale = jnp.maximum(amax, 1e-9) / qmax

    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )(x2d.astype(jnp.float32), scale)
