"""Pallas TPU kernel: batched fixed-iteration k-means coreset construction.

This is the paper's clustering-coreset engine (§4.2) re-targeted from a
fixed-function ASIC to a TPU core.  The ASIC insight that transfers directly:
an iteration only needs per-cluster ``(sum, count)`` and the final pass only
``radius`` — so the VMEM working set per window block is

    points (BB, N, D) + centers (BB, K, D) + distance tile (BB, N, K)

with N=64 (60-pt window padded), D≤8, K≤16: a few KB per window, hundreds of
windows per VMEM residency.  The grid is 1-D over window blocks; each program
runs the full Lloyd budget (paper: 4 iterations) so nothing but the coreset
triple ever leaves VMEM — the exact analogue of the paper's "no point storage"
datapath.

MXU note: the (onehot.T @ points) cluster-sum contraction and the (N, K)
distance tile are the two matmul-shaped ops; K and D are zero-padded by the
wrapper to lane-friendly sizes when running on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kmeans_coreset_pallas"]


def _kmeans_kernel(points_ref, centers_ref, radii_ref, counts_ref, *,
                   k: int, iters: int):
    pts = points_ref[...].astype(jnp.float32)              # (BB, N, D)
    bb, n, d = pts.shape

    stride_idx = (jnp.arange(k) * n) // k
    centers = pts[:, stride_idx, :]                        # (BB, K, D)

    def lloyd(_, centers):
        d2 = jnp.sum((pts[:, :, None, :] - centers[:, None, :, :]) ** 2,
                     axis=-1)                               # (BB, N, K)
        assign = jnp.argmin(d2, axis=-1)                    # (BB, N)
        onehot = (assign[..., None] == jnp.arange(k)[None, None, :]
                  ).astype(jnp.float32)                     # (BB, N, K)
        counts = jnp.sum(onehot, axis=1)                    # (BB, K)
        sums = jnp.einsum("bnk,bnd->bkd", onehot, pts,
                          preferred_element_type=jnp.float32)
        return jnp.where(counts[..., None] > 0,
                         sums / jnp.maximum(counts[..., None], 1.0), centers)

    centers = jax.lax.fori_loop(0, iters, lloyd, centers)

    d2 = jnp.sum((pts[:, :, None, :] - centers[:, None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=-1)
    onehot = (assign[..., None] == jnp.arange(k)[None, None, :]).astype(jnp.float32)
    counts = jnp.sum(onehot, axis=1)
    mind2 = jnp.min(d2, axis=-1)                            # (BB, N)
    dist = jnp.sqrt(jnp.maximum(mind2, 0.0))
    radii = jnp.max(onehot * dist[..., None], axis=1)       # (BB, K)

    centers_ref[...] = centers
    radii_ref[...] = radii
    counts_ref[...] = counts.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "iters", "block_b", "interpret"))
def kmeans_coreset_pallas(points: jnp.ndarray, k: int, iters: int = 4,
                          block_b: int = 8, interpret: bool = True):
    """Batched clustering-coreset construction.

    Args:
        points: (B, N, D) float window point clouds; B % block_b == 0
            (wrapper in ops.py pads).
        k: clusters (≤16 in the paper's hardware).
        iters: fixed Lloyd budget (paper: 4).
        block_b: windows per program (VMEM tile height).
        interpret: run the kernel body in Python (CPU validation mode).

    Returns (centers (B,k,D) f32, radii (B,k) f32, counts (B,k) i32).
    """
    b, n, d = points.shape
    assert b % block_b == 0, f"B={b} not a multiple of block_b={block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_kmeans_kernel, k=k, iters=iters),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n, d), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((block_b, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k, d), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(points.astype(jnp.float32))
