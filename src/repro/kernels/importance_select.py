"""Pallas TPU kernel: importance weighting + top-m selection.

The paper's importance-sampling engine (§4.2): per window, score every sample
by its deviation from a local moving average (the fixed-function stand-in for
"magnitude in the frequency response" — no FFT in a µW datapath), then select
the m most important samples.

TPU adaptation: the MCU engine iterates ≤7 times serially; here one program
holds a (BB, T, C) window block in VMEM, computes the box-filtered deviation
with T-length shifted adds (static unroll of the 8-tap box), and runs an
m-step argmax/mask selection loop entirely in registers/VMEM.  Selection is
returned *sorted by time index* so downstream payload encoding is monotone —
sorting m≤32 keys uses a static insertion network over the carried arrays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["importance_select_pallas"]


def _moving_average(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """Edge-padded box filter along axis 1 of (BB, T, C), static unroll."""
    pad_l = width // 2
    pad_r = width - 1 - pad_l
    first = jnp.repeat(x[:, :1, :], pad_l, axis=1)
    last = jnp.repeat(x[:, -1:, :], pad_r, axis=1)
    xp = jnp.concatenate([first, x, last], axis=1)          # (BB, T+w-1, C)
    t = x.shape[1]
    acc = jnp.zeros_like(x)
    for j in range(width):                                   # static unroll
        acc = acc + jax.lax.dynamic_slice_in_dim(xp, j, t, axis=1)
    return acc / width


def _select_kernel(windows_ref, idx_ref, vals_ref, weights_ref, *,
                   m: int, spread: float, avg_width: int):
    x = windows_ref[...].astype(jnp.float32)                # (BB, T, C)
    bb, t, c = x.shape

    ma = _moving_average(x, avg_width)
    detr = jnp.sum(jnp.abs(x - ma), axis=-1)                # (BB, T)
    w = detr / jnp.maximum(jnp.sum(detr, axis=-1, keepdims=True), 1e-9)
    w = (1.0 - spread) * w + spread / t                     # (BB, T)

    def pick(i, carry):
        masked, sel = carry
        best = jnp.argmax(masked, axis=-1)                  # (BB,)
        sel = sel.at[:, i].set(best.astype(jnp.int32))
        masked = masked * (jnp.arange(t)[None, :] != best[:, None])
        return masked, sel

    sel0 = jnp.zeros((bb, m), jnp.int32)
    _, sel = jax.lax.fori_loop(0, m, pick, (w, sel0))

    sel = jnp.sort(sel, axis=-1)                            # ascending time order
    onehot = (sel[..., None] == jnp.arange(t)[None, None, :]).astype(jnp.float32)
    vals = jnp.einsum("bmt,btc->bmc", onehot, x,
                      preferred_element_type=jnp.float32)   # gather via matmul
    sel_w = jnp.einsum("bmt,bt->bm", onehot, w,
                       preferred_element_type=jnp.float32)
    weights = 1.0 / jnp.maximum(m * sel_w, 1e-9)

    idx_ref[...] = sel
    vals_ref[...] = vals
    weights_ref[...] = weights


@functools.partial(jax.jit,
                   static_argnames=("m", "spread", "avg_width", "block_b",
                                    "interpret"))
def importance_select_pallas(windows: jnp.ndarray, m: int, spread: float = 0.25,
                             avg_width: int = 8, block_b: int = 8,
                             interpret: bool = True):
    """Deterministic top-m importance selection over a window batch.

    Args:
        windows: (B, T, C) float windows; B % block_b == 0.
        m: samples to keep (paper: 20 for HAR).

    Returns (indices (B,m) i32 ascending, values (B,m,C) f32,
             HT-weights (B,m) f32).
    """
    b, t, c = windows.shape
    assert b % block_b == 0
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_select_kernel, m=m, spread=spread,
                          avg_width=avg_width),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, t, c), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, m, c), jnp.float32),
            jax.ShapeDtypeStruct((b, m), jnp.float32),
        ],
        interpret=interpret,
    )(windows.astype(jnp.float32))
