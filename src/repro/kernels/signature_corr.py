"""Pallas TPU kernel: batched signature-bank Pearson correlation.

The paper's correlation/memoization engine (§3.2.1): every fresh window is
correlated against one stored ground-truth trace per class; corr ≥ 0.95 skips
DNN inference outright.

TPU adaptation: per-channel Pearson correlation of (B, T, C) windows against
an (L, T, C) signature bank is a *fused normalize-then-matmul*: center both
operands along T, compute the (B, L) numerator with a C-batched (T-contracted)
``dot_general`` on the MXU, and divide by the outer product of the L2 norms.
Grid tiles (B, L); the signature block is re-streamed per B-tile (L is tiny —
the whole bank usually fits VMEM, making this effectively signature-stationary
like the paper's engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["signature_corr_pallas"]


def _corr_kernel(win_ref, sig_ref, out_ref):
    w = win_ref[...].astype(jnp.float32)                    # (BB, T, C)
    s = sig_ref[...].astype(jnp.float32)                    # (BL, T, C)

    wm = w - jnp.mean(w, axis=1, keepdims=True)
    sm = s - jnp.mean(s, axis=1, keepdims=True)

    # (C, BB, T) x (C, BL, T) -> (C, BB, BL): channel-batched MXU matmul
    num = jax.lax.dot_general(
        wm.transpose(2, 0, 1), sm.transpose(2, 0, 1),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                       # (C, BB, BL)
    wn = jnp.sqrt(jnp.sum(wm * wm, axis=1))                 # (BB, C)
    sn = jnp.sqrt(jnp.sum(sm * sm, axis=1))                 # (BL, C)
    den = wn.T[:, :, None] * sn.T[:, None, :]               # (C, BB, BL)
    corr = num / jnp.maximum(den, 1e-9)
    out_ref[...] = jnp.mean(corr, axis=0)                   # (BB, BL)


@functools.partial(jax.jit, static_argnames=("block_b", "block_l", "interpret"))
def signature_corr_pallas(windows: jnp.ndarray, signatures: jnp.ndarray,
                          block_b: int = 8, block_l: int = 8,
                          interpret: bool = True) -> jnp.ndarray:
    """(B, T, C) x (L, T, C) -> (B, L) mean per-channel Pearson correlations."""
    b, t, c = windows.shape
    l, t2, c2 = signatures.shape
    assert (t, c) == (t2, c2)
    assert b % block_b == 0 and l % block_l == 0
    grid = (b // block_b, l // block_l)
    return pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, t, c), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_l, t, c), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.float32),
        interpret=interpret,
    )(windows.astype(jnp.float32), signatures.astype(jnp.float32))
