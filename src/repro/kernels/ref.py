"""Pure-jnp oracles for every Pallas kernel in this package.

These are THE definition of correctness: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions.  They intentionally use
only `jnp` ops (no pallas), in float32, with the exact same algorithmic
choices the kernels make (fixed iteration budgets, strided init, etc.).

Kernel inventory (the paper's fixed-function sensor hardware, §4.2, adapted
to VMEM/MXU tiling):

* ``kmeans_coreset_ref``       — batched fixed-iteration Lloyd on windows
* ``importance_select_ref``    — importance weights + top-m selection
* ``signature_corr_ref``       — batched Pearson correlation vs signature bank
* ``fake_quant_ref``           — symmetric uniform quantize-dequantize
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kmeans_coreset_ref", "importance_select_ref", "signature_corr_ref",
           "fake_quant_ref"]


def kmeans_coreset_ref(points: jnp.ndarray, k: int, iters: int = 4):
    """Batched Lloyd with strided init and fixed iteration budget.

    Args:
        points: (B, N, D) float32 point clouds.
        k: clusters.
        iters: fixed Lloyd iterations (paper: 4).

    Returns (centers (B,k,D), radii (B,k), counts (B,k) int32).
    """
    b, n, d = points.shape
    stride_idx = (jnp.arange(k) * n) // k
    centers = points[:, stride_idx, :]                      # (B, k, D)

    def one_iter(centers, _):
        d2 = jnp.sum((points[:, :, None, :] - centers[:, None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=-1)                    # (B, N)
        onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)  # (B, N, k)
        counts = jnp.sum(onehot, axis=1)                    # (B, k)
        sums = jnp.einsum("bnk,bnd->bkd", onehot, points)
        new = jnp.where(counts[..., None] > 0,
                        sums / jnp.maximum(counts[..., None], 1.0), centers)
        return new, None

    centers, _ = jax.lax.scan(one_iter, centers, None, length=iters)
    d2 = jnp.sum((points[:, :, None, :] - centers[:, None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=-1)
    onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)
    counts = jnp.sum(onehot, axis=1).astype(jnp.int32)
    dist = jnp.sqrt(jnp.take_along_axis(d2, assign[..., None], axis=-1)[..., 0])
    radii = jnp.max(onehot * dist[..., None], axis=1)
    return centers, radii, counts


def _hw_importance(windows: jnp.ndarray, spread: float = 0.25,
                   avg_width: int = 8) -> jnp.ndarray:
    """The *hardware* importance metric: |x - moving_average(x)| summed over
    channels plus a uniform floor.  (The MCU variant of
    ``repro.core.coreset.importance_weights`` — no FFT in fixed-function HW.)

    windows: (B, T, C) -> (B, T) weights summing to 1 per window.
    """
    b, t, c = windows.shape
    kern = jnp.ones((avg_width,), windows.dtype) / avg_width
    pad = avg_width // 2
    xp = jnp.pad(windows, ((0, 0), (pad, avg_width - 1 - pad), (0, 0)), mode="edge")
    # moving average along T for each (b, c)
    ma = jax.vmap(lambda w: jnp.stack(
        [jnp.convolve(w[:, ci], kern, mode="valid") for ci in range(c)], axis=-1
    ))(xp)
    detr = jnp.abs(windows - ma)
    w = jnp.sum(detr, axis=-1)                               # (B, T)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return (1.0 - spread) * w + spread / t


def importance_select_ref(windows: jnp.ndarray, m: int, spread: float = 0.25):
    """Deterministic top-m importance selection (the fixed-function sampler).

    windows: (B, T, C).  Returns (indices (B,m) int32 ascending,
    values (B,m,C), weights (B,m)).
    """
    w = _hw_importance(windows, spread)
    _, idx = jax.lax.top_k(w, m)
    idx = jnp.sort(idx, axis=-1).astype(jnp.int32)
    vals = jnp.take_along_axis(windows, idx[..., None], axis=1)
    sel_w = jnp.take_along_axis(w, idx, axis=1)
    weights = 1.0 / jnp.maximum(m * sel_w, 1e-9)
    return idx, vals, weights


def signature_corr_ref(windows: jnp.ndarray, signatures: jnp.ndarray) -> jnp.ndarray:
    """Batched per-channel Pearson correlation, averaged over channels.

    windows: (B, T, C); signatures: (L, T, C) -> (B, L).
    """
    wm = windows - jnp.mean(windows, axis=1, keepdims=True)
    sm = signatures - jnp.mean(signatures, axis=1, keepdims=True)
    num = jnp.einsum("btc,ltc->blc", wm, sm)
    wn = jnp.sqrt(jnp.sum(wm * wm, axis=1))                 # (B, C)
    sn = jnp.sqrt(jnp.sum(sm * sm, axis=1))                 # (L, C)
    den = wn[:, None, :] * sn[None, :, :]
    return jnp.mean(num / jnp.maximum(den, 1e-9), axis=-1)


def fake_quant_ref(x: jnp.ndarray, bits: int, per_channel: bool = False) -> jnp.ndarray:
    """Symmetric uniform quantize-dequantize at ``bits`` precision.

    Scale = max|x| over the tensor (or per last-dim channel).  This is the
    paper's post-training quantization model for the 16/12-bit edge DNNs.
    """
    if per_channel:
        amax = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(amax, 1e-9) / qmax
    return jnp.round(x / scale).clip(-qmax, qmax) * scale
