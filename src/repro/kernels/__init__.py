"""Pallas TPU kernels for Seeker's fixed-function sensor hardware (paper
§4.2), validated in interpret mode against the pure-jnp oracles in ref.py.

Kernels:
    kmeans_coreset    — clustering-coreset engine (4-iteration Lloyd)
    importance_select — importance-sampling engine (top-m selection)
    signature_corr    — memoization correlation engine
    fake_quant        — 16/12/8-bit quantized-inference building block
"""
from .ops import (  # noqa: F401
    kmeans_coreset_op, importance_select_op, signature_corr_op, fake_quant_op,
    default_interpret,
)
from . import ref  # noqa: F401
