"""Public jit'd wrappers around the Pallas kernels, with backend dispatch.

Handles padding to block multiples, dtype coercion, interpret-mode selection
(``interpret=True`` everywhere except a real TPU backend), and un-padding of
the results.  Call these, not the kernels, from library code.

Dispatch: every op takes ``impl`` — ``"pallas"`` runs the Pallas kernel
(interpret mode off-TPU), ``"ref"`` runs the pure-jnp oracle from
:mod:`repro.kernels.ref`.  The default (``None``) resolves to ``"pallas"``
on a real TPU backend and ``"ref"`` elsewhere: the oracles are validated
bit-for-tolerance against the kernels (tests/test_kernels.py), compile to
plain XLA on CPU/GPU, and — unlike interpret-mode Pallas — stay fast under
``vmap``/``scan``, which is what the fleet engine's hot path needs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .fake_quant import fake_quant_pallas
from .importance_select import importance_select_pallas
from .kmeans_coreset import kmeans_coreset_pallas
from .signature_corr import signature_corr_pallas

__all__ = ["kmeans_coreset_op", "importance_select_op", "signature_corr_op",
           "fake_quant_op", "default_interpret", "default_impl"]


def default_interpret() -> bool:
    """Pallas interpret mode: Python-evaluated kernel body off-TPU."""
    return jax.default_backend() != "tpu"


def default_impl() -> str:
    """Backend dispatch: the compiled kernel on TPU, the jnp oracle elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve_impl(impl: str | None) -> str:
    impl = default_impl() if impl is None else impl
    if impl not in ("pallas", "ref"):
        raise ValueError(f"impl must be 'pallas' or 'ref', got {impl!r}")
    return impl


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, mode="edge"), n


def kmeans_coreset_op(points: jnp.ndarray, k: int, iters: int = 4,
                      block_b: int = 8, interpret: bool | None = None,
                      impl: str | None = None):
    """Batched clustering coresets. points: (B, N, D) -> (centers, radii, counts)."""
    if _resolve_impl(impl) == "ref":
        return ref.kmeans_coreset_ref(points.astype(jnp.float32), k=k,
                                      iters=iters)
    interpret = default_interpret() if interpret is None else interpret
    padded, b = _pad_axis(points, 0, block_b)
    centers, radii, counts = kmeans_coreset_pallas(
        padded, k=k, iters=iters, block_b=block_b, interpret=interpret)
    return centers[:b], radii[:b], counts[:b]


def importance_select_op(windows: jnp.ndarray, m: int, spread: float = 0.25,
                         avg_width: int = 8, block_b: int = 8,
                         interpret: bool | None = None,
                         impl: str | None = None):
    """Batched top-m importance selection. windows: (B, T, C)."""
    if _resolve_impl(impl) == "ref":
        return ref.importance_select_ref(windows.astype(jnp.float32), m=m,
                                         spread=spread)
    interpret = default_interpret() if interpret is None else interpret
    padded, b = _pad_axis(windows, 0, block_b)
    idx, vals, weights = importance_select_pallas(
        padded, m=m, spread=spread, avg_width=avg_width, block_b=block_b,
        interpret=interpret)
    return idx[:b], vals[:b], weights[:b]


def signature_corr_op(windows: jnp.ndarray, signatures: jnp.ndarray,
                      block_b: int = 8, block_l: int = 8,
                      interpret: bool | None = None,
                      impl: str | None = None) -> jnp.ndarray:
    """(B, T, C) vs (L, T, C) -> (B, L) correlations.

    This is the fleet simulator's memoization hot path: every node correlates
    its fresh window against the whole signature bank each slot, so the
    batched form (B = all fleet nodes) is the one that must scale.  Under the
    sharded fleet engine this op runs *inside* the shard_map manual region, so
    B is the local node tile (N/d) — the block sizes clamp to the actual tile
    so a small shard is one kernel block instead of being padded up 8x.
    """
    if _resolve_impl(impl) == "ref":
        return ref.signature_corr_ref(windows.astype(jnp.float32),
                                      signatures.astype(jnp.float32))
    interpret = default_interpret() if interpret is None else interpret
    block_b = max(1, min(block_b, windows.shape[0]))
    block_l = max(1, min(block_l, signatures.shape[0]))
    wp, b = _pad_axis(windows, 0, block_b)
    # Signatures pad with zeros NOT edge: a zero signature correlates ~0 and
    # never wins the memo argmax.
    l = signatures.shape[0]
    pad_l = (-l) % block_l
    sp = jnp.pad(signatures, ((0, pad_l), (0, 0), (0, 0)))
    out = signature_corr_pallas(wp, sp, block_b=block_b, block_l=block_l,
                                interpret=interpret)
    return out[:b, :l]


def fake_quant_op(x: jnp.ndarray, bits: int, per_channel: bool = False,
                  interpret: bool | None = None,
                  impl: str | None = None) -> jnp.ndarray:
    """Fake-quantize an arbitrary-shape tensor at ``bits`` precision."""
    orig_shape = x.shape
    orig_dtype = x.dtype
    x2d = x.reshape(-1, orig_shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    if _resolve_impl(impl) == "ref":
        out = ref.fake_quant_ref(x2d.astype(jnp.float32), bits=bits,
                                 per_channel=per_channel)
        return out.reshape(orig_shape).astype(orig_dtype)
    interpret = default_interpret() if interpret is None else interpret
    r, c = x2d.shape
    block_r = min(256, r)
    block_c = min(512, c)
    # pad with zeros (zeros quantize to zero; amax computed pre-pad inside on
    # padded array is unchanged because |0| adds nothing)
    pr = (-r) % block_r
    pc = (-c) % block_c
    xp = jnp.pad(x2d, ((0, pr), (0, pc)))
    out = fake_quant_pallas(xp, bits=bits, per_channel=per_channel,
                            block_r=block_r, block_c=block_c,
                            interpret=interpret)
    return out[:r, :c].reshape(orig_shape).astype(orig_dtype)
