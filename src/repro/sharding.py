"""Logical-axis sharding: MaxText-style rules mapping model-semantic axis
names onto physical mesh axes.

Models annotate params/activations with *logical* names ("batch", "heads",
"ff", "embed", ...).  A :class:`ShardingRules` table maps each name to mesh
axes; :func:`spec_for` resolves a logical spec against a concrete mesh with
automatic divisibility fallback (an axis that doesn't divide is silently
replicated — e.g. gemma-2b's single KV head can't split 16 ways, grok's 8
experts can't split 16 ways; the roofline table shows the idle axis).

A thread-local context (:func:`use_sharding`) lets model code call
:func:`constrain` without threading the mesh through every function; outside
a context (CPU smoke tests) it is a no-op.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES", "FSDP_RULES", "DP_TP_RULES", "FLEET_RULES",
    "ShardingRules", "use_sharding", "current_context", "spec_for",
    "constrain", "named_sharding", "tree_named_shardings",
    "shard_map_compat", "make_mesh_compat", "node_mesh_axes",
]


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with all-Auto axis types across jax versions.

    Newer jax wants ``axis_types=(AxisType.Auto, ...)`` spelled out for
    meshes that mix manual ``shard_map`` regions with auto sharding; 0.4.x
    has no ``AxisType`` and every mesh axis is implicitly auto.
    """
    shape, axes = tuple(shape), tuple(axes)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, axis_names=None):
    """``shard_map`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., axis_names=, check_vma=)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)``
    where ``auto`` is the complement of the manual axes.  Library code calls
    this wrapper with the manual ``axis_names`` (default: every mesh axis).
    """
    manual = frozenset(mesh.axis_names) if axis_names is None \
        else frozenset(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      auto=auto, check_rep=False)

# Logical axis -> mesh axis (or tuple of mesh axes).  Mesh axes that do not
# exist in the active mesh are dropped at resolution time, so one rule table
# serves both the single-pod ("data","model") and multi-pod
# ("pod","data","model") meshes.
ShardingRules = Mapping[str, tuple[str, ...] | str | None]

# FSDP flavour (default for the big models): weight embed-dim sharded over
# "data" => XLA inserts per-layer all-gathers (ZeRO-3 style); optimizer state
# inherits the same sharding (ZeRO-1 falls out for free).
FSDP_RULES: ShardingRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # weight d_model dim (FSDP axis)
    "embed_act": None,        # activation d_model dim stays unsharded
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    # NOTE on non-divisible head counts (yi 56H, whisper/qwen 12H, gemma-2b
    # 8H): head_dim->model (Megatron contracted-dim sharding) was tried and
    # REJECTED — it psums the full attention-score tensors (yi prefill
    # collective term exploded 100x; see EXPERIMENTS.md §Perf iteration
    # history).  Instead the model zero-pads q-heads to the mesh quantum and
    # expands KV (exact math, bounded pad waste) — see transformer._attn_mix.
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_ff": "model",
    "state": "model",         # SSM/RG-LRU inner state dim
    "conv": None,
    "layers": None,
    "seq_shard": "data",      # long-context activation sequence sharding
    # decode KV-cache sequence dim: split-KV ("flash-decode") sharding — the
    # cache shards over "model" when kv_heads can't (kv<16); attention over
    # the sharded axis becomes a distributed softmax (XLA inserts the small
    # max/sum all-reduces)
    "kv_seq": "model",
}

# Plain DP+TP flavour: weights replicated over "data" — the configuration in
# which gradient all-reduce dominates, i.e. where Seeker's coreset gradient
# compression acts (the paper-representative hillclimb cell).
DP_TP_RULES: ShardingRules = dict(FSDP_RULES, embed=None)

# Pure-DP flavour for models too small to feed a 16-way tensor axis
# (mamba2-130m, whisper-small): batch shards across the WHOLE mesh, weights
# FSDP over "data", the model axis carries no tensor parallelism at all —
# kills the intra-layer resharding collectives (§Perf mamba2 iteration log).
PURE_DP_RULES: ShardingRules = {
    **{k: None for k in FSDP_RULES},
    "batch": ("pod", "data", "model"),
    "embed": "data",
    "layers": None,
}

# Fleet-serving flavour: the ONLY sharded axis is the fleet's node axis.
# Stacked SeekerNodeState, per-node PRNG keys, harvest traces and per-node
# window streams all shard their leading "nodes" dim over ("pod", "data");
# the signature bank, DNN params, generator params and AAC table are
# replicated — every shard runs the full Seeker decision ladder for its
# local node tile and only fleet-level aggregates (bytes on wire, decision
# histograms, accuracy counts) cross shards via psum.  Consumed by
# :func:`repro.serving.fleet.seeker_fleet_simulate_sharded`.
FLEET_RULES: ShardingRules = {
    **{k: None for k in FSDP_RULES},
    "nodes": ("pod", "data"),
    "signatures": None,       # memo bank: replicated, streamed per shard
    "params": None,           # qDNN / host DNN / generator weights
}

DEFAULT_RULES = FSDP_RULES

_ctx = threading.local()


class _Context:
    def __init__(self, mesh: Mesh, rules: ShardingRules):
        self.mesh = mesh
        self.rules = dict(rules)


def current_context() -> _Context | None:
    return getattr(_ctx, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    prev = current_context()
    _ctx.ctx = _Context(mesh, rules)
    try:
        with mesh:
            yield _ctx.ctx
    finally:
        _ctx.ctx = prev


def _mesh_axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def node_mesh_axes(mesh: Mesh,
                   rules: ShardingRules = FLEET_RULES
                   ) -> tuple[tuple[str, ...], int]:
    """Resolve the "nodes" logical axis against ``mesh``.

    Returns ``(axes, quantum)``: the mesh axes the fleet's node dim shards
    over (rule axes absent from the mesh are dropped, so the same table
    serves ("data",) and ("pod", "data") meshes) and their total size — the
    shard quantum fleets are padded to a multiple of.
    """
    rule = rules.get("nodes") or ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    axes = tuple(a for a in axes if a in mesh.shape)
    return axes, (_mesh_axis_size(mesh, axes) if axes else 1)


def spec_for(logical: Sequence[str | None], shape: Sequence[int],
             mesh: Mesh | None = None,
             rules: ShardingRules | None = None) -> P:
    """Resolve a logical spec to a PartitionSpec for ``mesh``.

    Drops (a) mesh axes absent from the mesh, (b) assignments that do not
    divide the dimension, (c) duplicate uses of one mesh axis (first wins).
    """
    ctx = current_context()
    mesh = mesh or (ctx.mesh if ctx else None)
    rules = rules or (ctx.rules if ctx else DEFAULT_RULES)
    if mesh is None:
        return P(*([None] * len(shape)))
    used: set[str] = set()
    out = []
    for name, dim in zip(logical, shape):
        assignment = None
        if name is not None:
            rule = rules.get(name)
            if rule is not None:
                axes = (rule,) if isinstance(rule, str) else tuple(rule)
                axes = tuple(a for a in axes if a in mesh.shape and a not in used)
                # longest prefix of the rule that divides the dimension
                # (e.g. batch=32 on ("pod","data","model"): 32 % 512 != 0
                # but 32 % 32 == 0 -> shard over ("pod","data"))
                while axes and dim % _mesh_axis_size(mesh, axes) != 0:
                    axes = axes[:-1]
                if axes:
                    assignment = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
        out.append(assignment)
    return P(*out)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a context."""
    ctx = current_context()
    if ctx is None:
        return x
    spec = spec_for(logical, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(logical: Sequence[str | None], shape: Sequence[int],
                   mesh: Mesh | None = None,
                   rules: ShardingRules | None = None) -> NamedSharding:
    ctx = current_context()
    mesh = mesh or (ctx.mesh if ctx else None)
    if mesh is None:
        raise ValueError("named_sharding requires a mesh (or use_sharding ctx)")
    return NamedSharding(mesh, spec_for(logical, shape, mesh, rules))


def tree_named_shardings(spec_tree, shape_tree, mesh: Mesh,
                         rules: ShardingRules = DEFAULT_RULES):
    """Zip a logical-spec pytree against a ShapeDtypeStruct pytree ->
    NamedSharding pytree (for jit in_shardings / out_shardings)."""
    return jax.tree_util.tree_map(
        lambda spec, sds: NamedSharding(
            mesh, spec_for(spec, sds.shape, mesh, rules)),
        spec_tree, shape_tree,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            isinstance(e, (str, type(None))) for e in s),
    )
