"""Train-step factories: plain SPMD, microbatched, and coreset-compressed DP.

Three flavours:

* :func:`make_train_step` — canonical pjit step: fwd/bwd (+optional
  microbatch accumulation scanned over the batch), AdamW.  XLA inserts all
  collectives from the sharding annotations (FSDP all-gathers, DP psum, TP
  reduce).  This is what the dry-run lowers.

* :func:`make_compressed_train_step` — the paper's C1/C2 applied to the DP
  gradient reduction: ``shard_map`` manual over the data axes (auto over
  "model"), local grads -> top-k importance-sampling coreset + error
  feedback -> all_gather of the compact payload -> decompress-sum.  The
  collective term drops by ~ratio x (idx+val)/val (see EXPERIMENTS.md §Perf).

Losses are computed in fp32 with the standard next-token shift.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.compression import CompressionConfig, coreset_allreduce
from ..models import forward, param_specs
from ..models.config import ModelConfig
from ..optim import OptConfig, adamw_init, adamw_update, opt_state_specs
from ..optim.schedule import warmup_cosine

__all__ = ["TrainHyper", "cross_entropy", "make_loss_fn", "make_train_step",
           "make_compressed_train_step", "init_train_state",
           "train_state_specs"]


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    microbatch: int = 0               # 0 = no accumulation
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE in fp32. logits (B,S,V), labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(cfg: ModelConfig):
    """batch: {"tokens": (B, S+1)} (+ optional enc_frames / patch_embeds)."""

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        extra = {k: batch[k] for k in ("enc_frames", "patch_embeds")
                 if k in batch}
        logits = forward(params, cfg, inputs, **extra)
        p = cfg.vision_patches
        if p:
            logits = logits[:, p:]                 # text positions only
        loss = cross_entropy(logits, labels)
        return loss, {"loss": loss}

    return loss_fn


def init_train_state(key: jax.Array, cfg: ModelConfig, hyper: TrainHyper,
                     compression: CompressionConfig | None = None):
    from ..models import init_params
    params = init_params(key, cfg)
    state = {"params": params, "opt": adamw_init(params, hyper.opt)}
    if compression is not None and compression.error_feedback:
        state["ef"] = jax.tree_util.tree_map(jnp.zeros_like, params)
    return state


def train_state_specs(cfg: ModelConfig, compression: CompressionConfig | None = None):
    ps = param_specs(cfg)
    specs = {"params": ps, "opt": opt_state_specs(ps)}
    if compression is not None and compression.error_feedback:
        is_leaf = lambda s: isinstance(s, tuple) and all(
            isinstance(e, (str, type(None))) for e in s)
        specs["ef"] = jax.tree_util.tree_map(lambda s: s, ps, is_leaf=is_leaf)
    return specs


def make_train_step(cfg: ModelConfig, hyper: TrainHyper):
    """Canonical SPMD train step: state, batch -> state, metrics."""
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        params = state["params"]
        if hyper.microbatch and hyper.microbatch < batch["tokens"].shape[0]:
            b = batch["tokens"].shape[0]
            n_micro = b // hyper.microbatch
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, hyper.microbatch) + x.shape[1:]),
                batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        else:
            (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr = warmup_cosine(state["opt"]["step"], hyper.peak_lr, hyper.warmup,
                           hyper.total_steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, state["opt"],
                                                  hyper.opt, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_compressed_train_step(cfg: ModelConfig, hyper: TrainHyper,
                               compression: CompressionConfig, mesh: Mesh,
                               dp_axes: tuple[str, ...] = ("data",)):
    """Seeker gradient-coreset DP step.

    Manual (shard_map) over ``dp_axes``; auto over the remaining mesh axes so
    tensor-parallel sharding inside the model is still XLA-managed.  Params
    and optimizer state are replicated over ``dp_axes`` (DP+TP layout — pair
    with ``DP_TP_RULES``); the batch is split over them.
    """
    loss_fn = make_loss_fn(cfg)
    manual = frozenset(dp_axes)

    # inside shard_map, with_sharding_constraint may not mention the manual
    # axes — strip them from the logical rules the model's constrain() sees
    from .. import sharding as shd

    def _strip(rule):
        if rule is None:
            return None
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        kept = tuple(a for a in axes if a not in manual)
        return kept[0] if len(kept) == 1 else (kept or None)

    def step_body(state, batch):
        params = state["params"]
        ctx = shd.current_context()
        rules = dict(ctx.rules) if ctx else dict(shd.DP_TP_RULES)
        stripped = {k: _strip(v) for k, v in rules.items()}
        with shd.use_sharding(mesh, stripped):
            (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        grads, new_ef = coreset_allreduce(grads, dp_axes, compression,
                                          state.get("ef"))
        for ax in dp_axes:
            loss = jax.lax.pmean(loss, ax)
        lr = warmup_cosine(state["opt"]["step"], hyper.peak_lr, hyper.warmup,
                           hyper.total_steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, state["opt"],
                                                  hyper.opt, lr)
        new_state = {"params": new_params, "opt": new_opt}
        if "ef" in state:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    def batch_spec(batch):
        return jax.tree_util.tree_map(
            lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]), batch)

    def train_step(state, batch):
        state_spec = jax.tree_util.tree_map(lambda _: P(), state)
        metric_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        from ..sharding import shard_map_compat
        fn = shard_map_compat(
            step_body, mesh,
            in_specs=(state_spec, batch_spec(batch)),
            out_specs=(state_spec, metric_spec),
            axis_names=manual)
        return fn(state, batch)

    return train_step
