"""Fault-tolerant training loop: checkpoint/restart, preemption simulation,
EH-budget throttling, straggler-drop.

The paper's sensor node makes progress under a fickle energy budget by
store-and-execute with NVP checkpoints; the pod-scale analogues here:

* **checkpoint/restart** — atomic checkpoints every ``ckpt_every`` steps;
  on (simulated or real) preemption the loop restores the latest manifest
  and replays from there.  The data pipeline is a pure function of the step,
  so the replayed batch sequence is identical.
* **budget throttling** — an EH trace gates step execution: when the
  harvested budget is below the per-step cost the loop *defers* (the RRn
  store-cycles of the paper).  On a real fleet this is the power-cap /
  degraded-node path.
* **straggler drop** — with ``straggler_drop_frac > 0`` a deterministic
  fraction of microbatches is dropped (gradient rescaled), modelling
  backup-worker semantics where slow shards are abandoned.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..core.energy import harvest_trace

__all__ = ["TrainLoopConfig", "run_training", "PreemptionError"]


class PreemptionError(RuntimeError):
    """Raised by the preemption simulator mid-run."""


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 200
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    # fault injection
    preempt_at: tuple[int, ...] = ()       # steps that raise PreemptionError
    max_restarts: int = 10
    # EH-budget throttling (None = always-on power)
    budget_source: str | None = None       # "rf" | "wifi" | "piezo" | "solar"
    budget_cost_uj: float = 20.0           # per-step energy cost
    budget_seed: int = 0


def _run_once(state, step0: int, train_step: Callable, batch_fn: Callable,
              loop: TrainLoopConfig, log: list, preempted: set):
    budget = None
    stored = 0.0
    if loop.budget_source:
        key = jax.random.PRNGKey(loop.budget_seed)
        budget = np.asarray(harvest_trace(key, loop.total_steps + 1,
                                          loop.budget_source))
    step = step0
    while step < loop.total_steps:
        if step in loop.preempt_at and step not in preempted:
            preempted.add(step)
            raise PreemptionError(f"simulated preemption at step {step}")
        if budget is not None:
            stored += budget[step]
            if stored < loop.budget_cost_uj:
                log.append({"step": step, "deferred": True, "stored": stored})
                step += 1
                continue                      # defer: store cycle (paper ERR)
            stored -= loop.budget_cost_uj
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        if step % loop.log_every == 0 or step == loop.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            log.append(m)
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            save_checkpoint(loop.ckpt_dir, step + 1, state, keep=loop.keep)
        step += 1
    return state, step


def run_training(state, train_step: Callable, batch_fn: Callable,
                 loop: TrainLoopConfig, shardings=None):
    """Run to ``total_steps`` with restart-on-preemption.

    Args:
        state: initial train state pytree (ignored when a checkpoint exists).
        train_step: (state, batch) -> (state, metrics), jitted.
        batch_fn: step -> batch (pure function: restart safety).
        loop: loop config.
        shardings: optional NamedSharding tree for elastic restore.

    Returns (final_state, log: list of metric dicts incl. restart events).
    """
    log: list = []
    preempted: set = set()
    restarts = 0
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    step0 = 0
    if loop.ckpt_dir:
        s = latest_step(loop.ckpt_dir)
        if s is not None:
            state = restore_checkpoint(loop.ckpt_dir, s, abstract, shardings)
            step0 = s
            log.append({"event": "resume", "step": s})
    while True:
        try:
            state, _ = _run_once(state, step0, train_step, batch_fn, loop,
                                 log, preempted)
            break
        except PreemptionError as e:
            restarts += 1
            log.append({"event": "preempted", "detail": str(e),
                        "restarts": restarts})
            if restarts > loop.max_restarts:
                raise
            s = latest_step(loop.ckpt_dir) if loop.ckpt_dir else None
            if s is None:
                step0 = 0           # nothing saved yet: restart from scratch
            else:
                state = restore_checkpoint(loop.ckpt_dir, s, abstract,
                                           shardings)
                step0 = s
                log.append({"event": "resume", "step": s})
    if loop.ckpt_dir:
        save_checkpoint(loop.ckpt_dir, loop.total_steps, state, keep=loop.keep)
    return state, log
