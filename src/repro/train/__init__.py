from .step import (  # noqa: F401
    TrainHyper, cross_entropy, make_loss_fn, make_train_step,
    make_compressed_train_step, init_train_state, train_state_specs,
)
from .loop import TrainLoopConfig, run_training, PreemptionError  # noqa: F401
