"""Model zoo: run-structured transformer LM (all 10 assigned archs) + the
paper's HAR/bearing edge classifiers + the coreset-recovery generator."""
from .config import ModelConfig, MoEConfig, pattern_runs  # noqa: F401
from .transformer import (  # noqa: F401
    init_params, abstract_params, param_specs, forward, decode_step,
    init_cache, abstract_cache, cache_specs, build_mrope_positions,
)
from .har import (  # noqa: F401
    HARConfig, har_init, har_apply, har_apply_quantized, quantize_params,
)
