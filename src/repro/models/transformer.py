"""Run-structured decoder LM covering all ten assigned architectures.

Layers are grouped into *runs* of consecutive identical (mixer, moe) kinds
(``pattern_runs``); each run's params are stacked with a leading layer dim
and executed with ``lax.scan``.  Dense LMs are a single run; gemma3's
5-local:1-global pattern becomes alternating runs (so local runs get
window-sized ring caches — crucial for the 500k cells); recurrentgemma's
(R,R,A) pattern and deepseek's dense-layer-0 fall out the same way.

Three entry points (all pure functions of a params pytree):

* :func:`forward`     — full-sequence: training loss input & prefill
  (``return_cache=True`` also emits the serving cache).
* :func:`decode_step` — one token against the cache (serve_step).
* :func:`init_params` / :func:`abstract_params` / :func:`param_specs` — the
  single source of truth for shapes / logical sharding / dry-run SDS trees.

Whisper's encoder and Qwen2-VL's vision stub enter through ``enc_frames`` /
``patch_embeds`` (precomputed embeddings per the assignment spec).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig, pattern_runs
from .flash import flash_banded_attention, flash_causal_attention
from .layers import (apply_mrope, apply_rope, banded_attention, dense_attention,
                     decode_attention, geglu, pair_chunked_attention, rms_norm,
                     rope_sincos, sinusoidal_at, sinusoidal_positions, swiglu)


def _pick_chunk(s: int, chunk: int) -> int:
    return chunk if (s % chunk == 0 and s >= chunk) else s
from .moe import moe_apply, moe_param_shapes
from .rglru import (rglru_apply, rglru_decode_step, rglru_param_shapes,
                    rglru_state_shapes)
from .ssd import ssd_apply, ssd_decode_step, ssd_param_shapes, ssd_state_shapes
from ..sharding import constrain

__all__ = ["init_params", "abstract_params", "param_specs", "forward",
           "decode_step", "init_cache", "abstract_cache", "cache_specs",
           "PSpec", "build_mrope_positions"]


class PSpec(NamedTuple):
    """Declarative parameter leaf: shape + logical axes + init rule."""
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"


def _act(cfg: ModelConfig):
    return {"swiglu": swiglu, "geglu": geglu}.get(cfg.mlp, geglu)


# ---------------------------------------------------------------------------
# Parameter shape declarations
# ---------------------------------------------------------------------------

def _mlp_shapes(cfg: ModelConfig, is_moe: bool) -> dict[str, PSpec]:
    d = cfg.d_model
    if is_moe and cfg.moe is not None:
        return {k: PSpec(*v) for k, v in moe_param_shapes(d, cfg.moe).items()}
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "mlp_gate": PSpec((d, cfg.d_ff), ("embed", "ff")),
            "mlp_up": PSpec((d, cfg.d_ff), ("embed", "ff")),
            "mlp_down": PSpec((cfg.d_ff, d), ("ff", "embed")),
        }
    return {
        "mlp_up": PSpec((d, cfg.d_ff), ("embed", "ff")),
        "mlp_down": PSpec((cfg.d_ff, d), ("ff", "embed")),
    }


def _attn_shapes(cfg: ModelConfig) -> dict[str, PSpec]:
    d = cfg.d_model
    return {
        "wq": PSpec((d, cfg.n_heads, cfg.head_dim), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((cfg.n_heads, cfg.head_dim, d), ("heads", "head_dim", "embed")),
    }


def _block_shapes(cfg: ModelConfig, kind: str, is_moe: bool,
                  cross: bool = False) -> dict[str, PSpec]:
    d = cfg.d_model
    sh: dict[str, PSpec] = {"norm1": PSpec((d,), (None,), "zeros")}
    if kind in ("attn", "local"):
        sh.update(_attn_shapes(cfg))
    elif kind == "rglru":
        sh.update({k: PSpec(v[0], v[1], "rglru_lam" if k == "lam" else "normal")
                   for k, v in rglru_param_shapes(cfg).items()})
    elif kind == "ssd":
        init_map = {"A_log": "ssm_A", "dt_bias": "ssm_dt", "D": "ones",
                    "norm_scale": "zeros"}
        sh.update({k: PSpec(v[0], v[1], init_map.get(k, "normal"))
                   for k, v in ssd_param_shapes(cfg).items()})
    else:
        raise ValueError(kind)
    if cross:
        sh["xnorm"] = PSpec((d,), (None,), "zeros")
        sh.update({f"x{k}": v for k, v in _attn_shapes(cfg).items()})
    if cfg.mlp != "none" and kind != "ssd":
        sh["norm2"] = PSpec((d,), (None,), "zeros")
        sh.update(_mlp_shapes(cfg, is_moe))
    return sh


def _stack(sh: dict[str, PSpec], n: int) -> dict[str, PSpec]:
    return {k: PSpec((n,) + v.shape, ("layers",) + v.logical, v.init)
            for k, v in sh.items()}


def model_param_shapes(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    tree: dict[str, Any] = {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), "zeros"),
        "runs": [],
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = PSpec((d, cfg.padded_vocab), ("embed", "vocab"))
    cross = cfg.encoder_layers > 0
    for kind, is_moe, _start, length in pattern_runs(cfg):
        tree["runs"].append(_stack(_block_shapes(cfg, kind, is_moe, cross), length))
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.encoder_layers, mlp="gelu", moe_layers=(),
            block_pattern=("attn",) * cfg.encoder_layers, n_kv=cfg.n_heads)
        tree["encoder"] = {
            "runs": [_stack(_block_shapes(enc_cfg, "attn", False), cfg.encoder_layers)],
            "final_norm": PSpec((d,), (None,), "zeros"),
        }
        tree["_enc_cfg"] = enc_cfg  # static companion, stripped from pytrees
    return tree


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _strip_static(tree):
    return {k: v for k, v in tree.items() if not k.startswith("_")} if isinstance(tree, dict) else tree


def _map_shapes(cfg: ModelConfig, fn):
    tree = model_param_shapes(cfg)

    def rec(t):
        if _is_pspec(t):
            return fn(t)
        if isinstance(t, dict):
            return {k: rec(v) for k, v in t.items() if not k.startswith("_")}
        if isinstance(t, list):
            return [rec(v) for v in t]
        raise TypeError(type(t))

    return rec(tree)


def _init_leaf(key: jax.Array, p: PSpec, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.param_dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    if p.init == "ssm_A":
        return jnp.log(jax.random.uniform(key, p.shape, dt, 1.0, 16.0))
    if p.init == "ssm_dt":
        u = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dt)  # softplus^-1
    if p.init == "rglru_lam":
        # a = sigmoid(lam)^(c) target a in (0.9, 0.999)
        u = jax.random.uniform(key, p.shape, jnp.float32, 0.9, 0.999)
        a = u ** 2
        lam = jnp.log(jnp.expm1(-jnp.log(a) / 8.0))  # softplus^-1(-log a / c)
        return lam.astype(dt)
    # fan-in init: product of all-but-last dims, excluding the stacked layer dim
    shape = p.shape[1:] if (p.logical and p.logical[0] == "layers") else p.shape
    fan_in = math.prod(shape[:-1]) if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, p.shape, jnp.float32)
            / jnp.sqrt(jnp.maximum(fan_in, 1.0))).astype(dt)


def init_params(key: jax.Array, cfg: ModelConfig):
    leaves_count = [0]

    def fn(p: PSpec):
        leaves_count[0] += 1
        return _init_leaf(jax.random.fold_in(key, leaves_count[0]), p, cfg)

    return _map_shapes(cfg, fn)


def abstract_params(cfg: ModelConfig):
    return _map_shapes(cfg, lambda p: jax.ShapeDtypeStruct(p.shape, cfg.param_dtype))


def param_specs(cfg: ModelConfig):
    return _map_shapes(cfg, lambda p: p.logical)


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------

def _project_qkv(p: dict, h: jnp.ndarray, cfg: ModelConfig, prefix: str = "w"):
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p[prefix + "q"].astype(dt))
    k = jnp.einsum("bsd,dgk->bsgk", h, p[prefix + "k"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", h, p[prefix + "v"].astype(dt))
    return q, k, v


def _attn_mix(p: dict, x: jnp.ndarray, cfg: ModelConfig, *, kind: str,
              positions: jnp.ndarray, mrope_positions: jnp.ndarray | None,
              theta: float, causal: bool = True):
    """Full-sequence attention mixer. Returns (out, (k, v)) for caching.

    When ``cfg.head_pad_multiple`` is set and the q-head count doesn't divide
    it (yi 56H, whisper/qwen 12H, gemma-2b 8H on a 16-way model axis), q-heads
    are ZERO-PADDED to the quantum and KV is gather-expanded to per-q-head
    streams: padded wq/wo rows are zero so the math is exact, every einsum
    shards cleanly on "heads", and no score-tensor psums appear (the rejected
    head_dim-contraction alternative — see EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    hp = cfg.padded_heads
    expand = hp != cfg.n_heads
    dt = x.dtype
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if expand:
        pad = hp - cfg.n_heads
        wq = jnp.pad(p["wq"], ((0, 0), (0, pad), (0, 0))).astype(dt)
        q = jnp.einsum("bsd,dhk->bshk", h, wq)
        k = jnp.einsum("bsd,dgk->bsgk", h, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dgk->bsgk", h, p["wv"].astype(dt))
    else:
        q, k, v = _project_qkv(p, h, cfg)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, theta)
    elif theta > 0:
        sin, cos = rope_sincos(positions, cfg.head_dim, theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    if expand:
        # per-q-head KV streams: padded tail maps to group g-1 (masked by wo)
        rep = max(cfg.n_heads // cfg.n_kv, 1)
        kv_map = jnp.minimum(jnp.arange(hp) // rep, cfg.n_kv - 1)
        k_att = constrain(k[:, :, kv_map], "batch", "seq", "heads", "head_dim")
        v_att = constrain(v[:, :, kv_map], "batch", "seq", "heads", "head_dim")
        q5 = q.reshape(b, s, hp, 1, cfg.head_dim)
    else:
        k_att, v_att = k, v
        q5 = q.reshape(b, s, cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.head_dim)
    window = cfg.window if kind == "local" else None
    if not causal:
        out = dense_attention(q5, k_att, v_att, causal=False,
                              softcap=cfg.attn_softcap)
    elif s <= cfg.dense_attn_max_seq and (window is None or not cfg.flash_attention):
        out = dense_attention(q5, k_att, v_att, causal=True, window=window,
                              softcap=cfg.attn_softcap)
    elif window is not None:
        if cfg.flash_attention:
            out = flash_banded_attention(q5, k_att, v_att, window,
                                         _pick_chunk(s, cfg.attn_chunk),
                                         cfg.attn_softcap)
        else:
            out = banded_attention(q5, k_att, v_att, window=window,
                                   chunk=cfg.attn_chunk,
                                   softcap=cfg.attn_softcap)
    elif cfg.flash_attention:
        out = flash_causal_attention(q5, k_att, v_att,
                                     _pick_chunk(s, cfg.attn_chunk),
                                     cfg.attn_softcap)
    else:
        out = pair_chunked_attention(q5, k_att, v_att, chunk=cfg.attn_chunk,
                                     softcap=cfg.attn_softcap)
    out = out.reshape(b, s, hp, cfg.head_dim)
    if expand:
        wo = jnp.pad(p["wo"], ((0, hp - cfg.n_heads), (0, 0), (0, 0))).astype(dt)
    else:
        wo = p["wo"].astype(dt)
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    return out, (k, v)


def _cross_attn(p: dict, x: jnp.ndarray, enc_kv, cfg: ModelConfig):
    """Cross-attention with precomputed encoder K/V (B, Tf, G, Dh)."""
    b, s, d = x.shape
    g, rep = cfg.n_kv, cfg.n_heads // cfg.n_kv
    h = rms_norm(x, p["xnorm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["xwq"].astype(x.dtype))
    q5 = q.reshape(b, s, g, rep, cfg.head_dim)
    k, v = enc_kv
    out = dense_attention(q5, k, v, causal=False, softcap=cfg.attn_softcap)
    out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["xwo"].astype(x.dtype))


def _enc_kv(p: dict, enc_out: jnp.ndarray, cfg: ModelConfig):
    dt = enc_out.dtype
    k = jnp.einsum("btd,dgk->btgk", enc_out, p["xwk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", enc_out, p["xwv"].astype(dt))
    return k, v


def _mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig, is_moe: bool) -> jnp.ndarray:
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if is_moe and cfg.moe is not None:
        return moe_apply(p, h, cfg.moe, _act(cfg))
    dt = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        gate = h @ p["mlp_gate"].astype(dt)
        up = h @ p["mlp_up"].astype(dt)
        inner = _act(cfg)(gate, up)
    else:
        inner = jax.nn.gelu(h @ p["mlp_up"].astype(dt), approximate=True)
    inner = constrain(inner, "batch", "seq", "ff")
    return inner @ p["mlp_down"].astype(dt)


def _block_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *, kind: str,
                 is_moe: bool, theta: float, positions, mrope_positions,
                 enc_out=None, causal: bool = True, want_cache: bool = False):
    """One layer; returns (x, cache_aux)."""
    aux = {}
    if kind in ("attn", "local"):
        mix, (k, v) = _attn_mix(p, x, cfg, kind=kind, positions=positions,
                                mrope_positions=mrope_positions, theta=theta,
                                causal=causal)
        if want_cache:
            aux["k"], aux["v"] = k, v
    elif kind == "rglru":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            mix, state = rglru_apply(p, h, return_state=True)
            aux.update(state)
        else:
            mix = rglru_apply(p, h)
    elif kind == "ssd":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            mix, state = ssd_apply(p, h, cfg, return_state=True)
            aux.update(state)
        else:
            mix = ssd_apply(p, h, cfg)
    else:
        raise ValueError(kind)
    x = x + mix
    if enc_out is not None and "xnorm" in p:
        enc_kv = _enc_kv(p, enc_out, cfg)
        x = x + _cross_attn(p, x, enc_kv, cfg)
        if want_cache:
            aux["xk"], aux["xv"] = enc_kv
    if cfg.mlp != "none" and kind != "ssd":
        x = x + _mlp(p, x, cfg, is_moe)
    x = constrain(x, "batch", "seq", "embed_act")
    return x, aux


def _run_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "attn" and cfg.global_rope_theta > 0:
        return cfg.global_rope_theta
    return cfg.rope_theta


def _logits(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Unembed + padded-vocab mask + optional softcap. x: (B, S, D)."""
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(cfg.dtype))
    logits = constrain(logits, "batch", "seq", "vocab")
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask
    return logits


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def build_mrope_positions(cfg: ModelConfig, batch: int, seq: int) -> jnp.ndarray:
    """(3, B, S) M-RoPE ids: vision patches get a (t=0, h, w) grid, text runs
    sequentially after the max patch coordinate (Qwen2-VL scheme)."""
    p = cfg.vision_patches
    grid = max(int(math.sqrt(max(p, 1))), 1)
    idx = jnp.arange(seq)
    is_text = idx >= p
    t_pos = jnp.where(is_text, idx - p + grid, 0)
    h_pos = jnp.where(is_text, idx - p + grid, jnp.minimum(idx // grid, grid - 1))
    w_pos = jnp.where(is_text, idx - p + grid, idx % grid)
    pos = jnp.stack([t_pos, h_pos, w_pos])                    # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def _embed_tokens(params, cfg: ModelConfig, tokens: jnp.ndarray,
                  positions: jnp.ndarray | None = None) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    if cfg.rope_theta == 0 and not cfg.mrope_sections and positions is not None:
        # RoPE disabled (whisper): absolute sinusoidal position embedding
        x = x + sinusoidal_at(positions, cfg.d_model).astype(cfg.dtype)
    return x


def _encode(params, cfg: ModelConfig, enc_frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    enc_cfg = dataclasses.replace(
        cfg, n_layers=cfg.encoder_layers, mlp="gelu", moe_layers=(),
        block_pattern=("attn",) * cfg.encoder_layers, n_kv=cfg.n_heads)
    x = enc_frames.astype(cfg.dtype)
    pos_tab = sinusoidal_positions(x.shape[1], cfg.d_model).astype(cfg.dtype)
    x = x + pos_tab[None]
    p_run = params["encoder"]["runs"][0]

    def body(h, p_l):
        h, _ = _block_apply(p_l, h, enc_cfg, kind="attn", is_moe=False,
                            theta=0.0, positions=None, mrope_positions=None,
                            causal=False)
        return h, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p_run)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            enc_frames: jnp.ndarray | None = None,
            patch_embeds: jnp.ndarray | None = None,
            positions: jnp.ndarray | None = None,
            return_cache: bool = False, cache_len: int | None = None):
    """Full-sequence forward.

    tokens: (B, S_text) int32.  With ``patch_embeds`` (B, P, D) the effective
    sequence is P + S_text.  Returns logits (B, S, vocab), or
    (logits, cache) with ``return_cache`` (prefill).
    """
    b = tokens.shape[0]
    s_total = tokens.shape[1] + (patch_embeds.shape[1] if patch_embeds is not None else 0)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s_total)[None], (b, s_total))
    tok_positions = positions[:, s_total - tokens.shape[1]:]
    x = _embed_tokens(params, cfg, tokens, tok_positions)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(cfg.dtype), x], axis=1)
    s = x.shape[1]
    mpos = (build_mrope_positions(cfg, b, s) if cfg.mrope_sections else None)
    enc_out = _encode(params, cfg, enc_frames) if cfg.encoder_layers else None
    x = constrain(x, "batch", "seq", "embed_act")

    run_caches = []
    for run_idx, (kind, is_moe, _start, _length) in enumerate(pattern_runs(cfg)):
        p_run = params["runs"][run_idx]
        theta = _run_theta(cfg, kind)

        def body(h, p_l, _kind=kind, _moe=is_moe, _theta=theta):
            h, aux = _block_apply(p_l, h, cfg, kind=_kind, is_moe=_moe,
                                  theta=_theta, positions=positions,
                                  mrope_positions=mpos, enc_out=enc_out,
                                  want_cache=return_cache)
            return h, aux

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, aux = jax.lax.scan(body, x, p_run)
        if return_cache:
            run_caches.append(_prefill_run_cache(p_run, aux, x, cfg, kind,
                                                 cache_len or s, s))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    if not return_cache:
        return logits
    cache = {"pos": jnp.asarray(s, jnp.int32), "runs": run_caches}
    if enc_out is not None:
        cache["enc_out"] = enc_out
    return logits, cache


def _prefill_run_cache(p_run, aux, x_out, cfg: ModelConfig, kind: str,
                       cache_len: int, s: int):
    """Build the decode cache for one run from prefill byproducts."""
    if kind in ("attn", "local"):
        w = min(cfg.window, cache_len) if kind == "local" else cache_len
        k, v = aux["k"], aux["v"]                       # (L, B, S, G, Dh)
        if s >= w:
            k = jax.lax.dynamic_slice_in_dim(k, s - w, w, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, s - w, w, axis=2)
            # ring layout: slot = pos % w
            roll = (-(s % w)) % w
            k = jnp.roll(k, -roll, axis=2) if kind == "local" else k
            v = jnp.roll(v, -roll, axis=2) if kind == "local" else v
        else:
            pad = w - s
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        out = {"k": k, "v": v}
        if "xk" in aux:
            out["xk"], out["xv"] = aux["xk"], aux["xv"]
        return out
    # recurrent runs: the scanned aux already holds the stacked final states
    return dict(aux)


# ---------------------------------------------------------------------------
# Cache init / specs
# ---------------------------------------------------------------------------

def _run_cache_shapes(cfg: ModelConfig, kind: str, length: int, batch: int,
                      max_len: int) -> dict[str, tuple]:
    g, dh = cfg.n_kv, cfg.head_dim
    if kind in ("attn", "local"):
        w = min(cfg.window, max_len) if kind == "local" else max_len
        # kv_heads shards when divisible; kv_seq ("split-KV") otherwise —
        # spec_for's first-win dedup keeps exactly one of them on "model"
        kv_spec = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        if g % 16 == 0:
            kv_spec = ("layers", "batch", None, "kv_heads", "head_dim")
        sh = {
            "k": ((length, batch, w, g, dh), kv_spec),
            "v": ((length, batch, w, g, dh), kv_spec),
        }
        if cfg.encoder_layers:
            tf = cfg.encoder_frames
            sh["xk"] = ((length, batch, tf, g, dh), kv_spec)
            sh["xv"] = ((length, batch, tf, g, dh), kv_spec)
        return sh
    if kind == "rglru":
        base = rglru_state_shapes(cfg, batch)
    elif kind == "ssd":
        base = ssd_state_shapes(cfg, batch)
    else:
        raise ValueError(kind)
    return {k: ((length,) + sh, ("layers",) + spec) for k, (sh, spec) in base.items()}


def _cache_tree(cfg: ModelConfig, batch: int, max_len: int, fn):
    runs = []
    for kind, _moe, _start, length in pattern_runs(cfg):
        shapes = _run_cache_shapes(cfg, kind, length, batch, max_len)
        runs.append({k: fn(sh, spec) for k, (sh, spec) in shapes.items()})
    out = {"pos": fn((), (None,)), "runs": runs}
    if cfg.encoder_layers:
        out["enc_out"] = fn((batch, cfg.encoder_frames, cfg.d_model),
                            ("batch", None, "embed_act"))
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    def fn(sh, spec):
        dt = jnp.int32 if sh == () else cfg.dtype
        return jnp.zeros(sh, dt)
    return _cache_tree(cfg, batch, max_len, fn)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    def fn(sh, spec):
        dt = jnp.int32 if sh == () else cfg.dtype
        return jax.ShapeDtypeStruct(sh, dt)
    return _cache_tree(cfg, batch, max_len, fn)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return _cache_tree(cfg, batch, max_len, lambda sh, spec: spec)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def _slot_positions(pos: jnp.ndarray, w: int) -> jnp.ndarray:
    """Global position held by each of the w ring slots after writing ``pos``
    at slot pos % w.  (-1 where the slot is still empty.)"""
    i = jnp.arange(w)
    p = pos - jnp.mod(pos - i, w)
    return jnp.where(p >= 0, p, -1)


def _attn_decode(p: dict, c: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                 kind: str, theta: float, pos: jnp.ndarray):
    b = x.shape[0]
    g, rep = cfg.n_kv, cfg.n_heads // cfg.n_kv
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    if cfg.mrope_sections:
        mpos = jnp.broadcast_to(pos[None, None, None], (3, b, 1))
        q = apply_mrope(q, mpos, cfg.mrope_sections, theta)
        k = apply_mrope(k, mpos, cfg.mrope_sections, theta)
    elif theta > 0:
        sin, cos = rope_sincos(positions, cfg.head_dim, theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    w = c["k"].shape[1]
    slot = jnp.mod(pos, w)
    k_cache = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype),
                                                  slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype),
                                                  slot, axis=1)
    slot_pos = _slot_positions(pos, w)
    q5 = q.reshape(b, 1, g, rep, cfg.head_dim)
    out = decode_attention(q5, k_cache, v_cache, slot_pos, pos,
                           softcap=cfg.attn_softcap)
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    new_c = dict(c, k=k_cache, v=v_cache)
    return out, new_c


def decode_step(params, cfg: ModelConfig, cache, tokens: jnp.ndarray):
    """One decoding step. tokens: (B, 1) int32. Returns (logits (B,1,V), cache)."""
    pos = cache["pos"]
    b = tokens.shape[0]
    x = _embed_tokens(params, cfg, tokens,
                      jnp.broadcast_to(pos[None, None], (b, 1)))
    x = constrain(x, "batch", "seq", "embed_act")
    enc_out = cache.get("enc_out")
    new_runs = []
    for run_idx, (kind, is_moe, _start, _length) in enumerate(pattern_runs(cfg)):
        p_run = params["runs"][run_idx]
        c_run = cache["runs"][run_idx]
        theta = _run_theta(cfg, kind)

        def body(h, inp, _kind=kind, _moe=is_moe, _theta=theta):
            p_l, c_l = inp
            if _kind in ("attn", "local"):
                mix, c_new = _attn_decode(p_l, c_l, h, cfg, kind=_kind,
                                          theta=_theta, pos=pos)
            elif _kind == "rglru":
                hn = rms_norm(h, p_l["norm1"], cfg.norm_eps)
                mix, c_new = rglru_decode_step(p_l, c_l, hn)
            elif _kind == "ssd":
                hn = rms_norm(h, p_l["norm1"], cfg.norm_eps)
                mix, c_new = ssd_decode_step(p_l, c_l, hn, cfg)
            h = h + mix
            if enc_out is not None and "xnorm" in p_l:
                h = h + _cross_attn(p_l, h, (c_l["xk"], c_l["xv"]), cfg)
                c_new["xk"], c_new["xv"] = c_l["xk"], c_l["xv"]
            if cfg.mlp != "none" and _kind != "ssd":
                h = h + _mlp(p_l, h, cfg, _moe)
            return h, c_new

        x, c_new = jax.lax.scan(body, x, (p_run, c_run))
        new_runs.append(c_new)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    new_cache = dict(cache, pos=pos + 1, runs=new_runs)
    return logits, new_cache
