"""Mamba-2 SSD (state-space duality) block — chunked, attention-free.

Implements the SSD "minimal" algorithm (Mamba-2 paper §6): the sequence is
split into chunks; within a chunk the quadratic dual form runs on the MXU,
across chunks a tiny recurrence carries the (H, P, N) state.  Train/prefill
cost is O(S * chunk) matmuls + O((S/chunk)^2) scalar decay products; decode
is a constant-time state update — which is why mamba2 runs the long_500k
cell.

Head grouping follows Mamba-2: ``ssm_groups`` B/C projections are shared by
``heads_per_group`` heads (the GQA analogue, "multi-value attention").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["ssd_param_shapes", "ssd_apply", "ssd_decode_step", "ssd_state_shapes"]


def ssd_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.conv_width
    conv_ch = di + 2 * g * n
    return {
        "w_z": ((d, di), ("embed", "state")),
        "w_x": ((d, di), ("embed", "state")),
        "w_B": ((d, g * n), ("embed", None)),
        "w_C": ((d, g * n), ("embed", None)),
        "w_dt": ((d, h), ("embed", None)),
        "dt_bias": ((h,), (None,)),
        "A_log": ((h,), (None,)),
        "D": ((h,), (None,)),
        "norm_scale": ((di,), ("state",)),
        "w_out": ((di, d), ("state", "embed")),
        "conv_w": ((cw, conv_ch), ("conv", None)),
    }


def ssd_state_shapes(cfg: ModelConfig, batch: int) -> dict[str, tuple]:
    g, n = cfg.ssm_groups, cfg.ssm_state
    hg = cfg.ssm_heads // g
    conv_ch = cfg.d_inner + 2 * g * n
    return {
        "ssm": ((batch, g, hg, cfg.ssm_headdim, n), ("batch", None, "heads", None, None)),
        "conv_buf": ((batch, cfg.conv_width - 1, conv_ch), ("batch", None, "state")),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., l) -> (..., l, l): seg[i, j] = sum_{j < k <= i} x[k]; -inf above
    the diagonal (so exp() gives the lower-triangular decay matrix)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(cw):
        out = out + jax.lax.dynamic_slice_in_dim(
            xp, j, x.shape[1], axis=1) * w[j].astype(x.dtype)
    return out


def _ssd_scan(xdt: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
              chunk: int):
    """Chunked SSD.  xdt (b,s,g,hg,p) is x pre-multiplied by dt; dA (b,s,g,hg)
    is dt*A (negative log-decays); B, C (b,s,g,n).
    Returns (y (b,s,g,hg,p), final_state (b,g,hg,p,n))."""
    b, s, g, hg, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    xdt = xdt.reshape(b, c, chunk, g, hg, p)
    B = B.reshape(b, c, chunk, g, n)
    C = C.reshape(b, c, chunk, g, n)
    dA = dA.reshape(b, c, chunk, g, hg).transpose(0, 3, 4, 1, 2)  # (b,g,hg,c,l)
    dA_cs = jnp.cumsum(dA, axis=-1)

    # 1. intra-chunk (quadratic dual form on the MXU)
    L = jnp.exp(_segsum(dA))                                  # (b,g,hg,c,l,l)
    y_diag = jnp.einsum("bclgn,bcsgn,bghcls,bcsghp->bclghp", C, B, L.transpose(0, 1, 2, 3, 4, 5), xdt)

    # 2. per-chunk terminal states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)           # (b,g,hg,c,l)
    states = jnp.einsum("bclgn,bghcl,bclghp->bcghpn", B, decay_states, xdt)

    # 3. inter-chunk recurrence (scan over the few chunks)
    chunk_decay = jnp.exp(dA_cs[..., -1])                     # (b,g,hg,c)

    def step(s_prev, inp):
        st, dec = inp                                         # (b,g,hg,p,n), (b,g,hg)
        s_new = s_prev * dec[..., None, None].astype(s_prev.dtype) \
            + st.astype(s_prev.dtype)
        return s_new, s_prev                                   # emit state *before* chunk

    s0 = jnp.zeros((b, g, hg, p, n), states.dtype)
    final_state, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(3, 0, 1, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)     # (b,c,g,hg,p,n)

    # 4. state -> output within each chunk
    state_decay_out = jnp.exp(dA_cs)                          # (b,g,hg,c,l)
    y_off = jnp.einsum("bclgn,bcghpn,bghcl->bclghp", C, prev_states, state_decay_out)
    return (y_diag + y_off).reshape(b, s, g, hg, p), final_state


def ssd_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
              chunk: int = 128, return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (B, S, D_model).

    With ``return_state`` also emits {ssm: (B,g,hg,P,N), conv_buf} for
    decode-resumable prefill."""
    b, s, d = x.shape
    g, n, h, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    hg = h // g
    di = cfg.d_inner

    z = x @ params["w_z"].astype(x.dtype)
    xs = x @ params["w_x"].astype(x.dtype)
    Bp = x @ params["w_B"].astype(x.dtype)
    Cp = x @ params["w_C"].astype(x.dtype)
    xbc_raw = jnp.concatenate([xs, Bp, Cp], axis=-1)
    xbc = jax.nn.silu(_conv1d_causal(xbc_raw, params["conv_w"]))
    xs, Bp, Cp = jnp.split(xbc, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(
        (x @ params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))              # (b,s,h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))         # (h,)
    dA = (dt * A).reshape(b, s, g, hg)

    xh = xs.reshape(b, s, g, hg, p)
    xdt = xh * dt.reshape(b, s, g, hg)[..., None].astype(x.dtype)
    y, final_state = _ssd_scan(xdt, dA, Bp.reshape(b, s, g, n),
                               Cp.reshape(b, s, g, n), chunk=min(chunk, s))
    y = y + xh * params["D"].astype(x.dtype).reshape(g, hg)[None, None, :, :, None]
    y = y.reshape(b, s, di)

    # gated RMSNorm then output projection (Mamba-2)
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + params["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"].astype(x.dtype)
    if not return_state:
        return out
    cw = params["conv_w"].shape[0]
    tail = xbc_raw[:, -(cw - 1):] if cw > 1 else xbc_raw[:, :0]
    pad = (cw - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return out, {"ssm": final_state.astype(x.dtype), "conv_buf": tail}


def ssd_decode_step(params: dict, state: dict, x: jnp.ndarray, cfg: ModelConfig):
    """One-token update. x (B, 1, D). Returns (out (B,1,D), new_state)."""
    b = x.shape[0]
    g, n, h, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    hg = h // g
    di = cfg.d_inner
    xt = x[:, 0]

    z = xt @ params["w_z"].astype(x.dtype)
    xs = xt @ params["w_x"].astype(x.dtype)
    Bp = xt @ params["w_B"].astype(x.dtype)
    Cp = xt @ params["w_C"].astype(x.dtype)
    xbc = jnp.concatenate([xs, Bp, Cp], axis=-1)              # (b, conv_ch)
    hist = jnp.concatenate([state["conv_buf"].astype(x.dtype), xbc[:, None]], axis=1)
    cw = params["conv_w"].shape[0]
    xbc = jax.nn.silu(jnp.einsum("bwd,wd->bd", hist[:, -cw:],
                                 params["conv_w"].astype(x.dtype)))
    xs, Bp, Cp = jnp.split(xbc, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(
        (xt @ params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))              # (b,h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A).reshape(b, g, hg)                    # decay

    xh = xs.reshape(b, g, hg, p)
    Bh = Bp.reshape(b, g, n)
    Ch = Cp.reshape(b, g, n)
    dx = xh * dt.reshape(b, g, hg)[..., None].astype(x.dtype)
    ssm = (state["ssm"].astype(jnp.float32) * dA[..., None, None]
           + jnp.einsum("bghp,bgn->bghpn", dx, Bh).astype(jnp.float32))
    y = jnp.einsum("bgn,bghpn->bghp", Ch, ssm.astype(x.dtype))
    y = y + xh * params["D"].astype(x.dtype).reshape(g, hg)[None, :, :, None]
    y = y.reshape(b, di)

    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + params["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"].astype(x.dtype)
    new_state = {"ssm": ssm.astype(state["ssm"].dtype),
                 "conv_buf": hist[:, 1:].astype(state["conv_buf"].dtype)}
    return out[:, None], new_state
