"""The paper's edge workloads: HAR / bearing-fault 1-D CNN classifiers.

Architecture follows Ha & Choi [26] as optimized for edge deployment in the
paper (two conv/pool stages + dense head), with three deployment variants:

* full-precision (Baseline-1 / host-side inference),
* 16-bit and 12-bit post-training fake-quantized copies (the sensor's two
  ReRAM crossbars, decision D1/D2) via the :mod:`repro.kernels` quant op,
* a *coreset-input* variant whose first layer consumes the (recovered or
  raw-coreset) representation (paper §3.2 "retrain the DNN models to
  recognize the compressed representation").

Pure functional JAX: params dict + apply fns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels.ops import fake_quant_op

__all__ = ["HARConfig", "har_init", "har_apply", "har_apply_quantized",
           "quantize_params", "har_stage_sizes", "har_act_buffer",
           "har_apply_stage", "har_apply_staged", "har_aux_init",
           "har_apply_aux"]


@dataclasses.dataclass(frozen=True)
class HARConfig:
    window: int = 60          # samples per window (paper: 60 @ 50 Hz)
    channels: int = 3         # IMU channels per sensor
    n_classes: int = 12       # MHEALTH activities
    conv1: int = 32
    conv2: int = 64
    kernel: int = 5
    hidden: int = 128


def har_init(key: jax.Array, cfg: HARConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def norm(k, shape, fan_in):
        return jax.random.normal(k, shape) / jnp.sqrt(fan_in)

    flat = (cfg.window // 4) * cfg.conv2
    return {
        "conv1_w": norm(k1, (cfg.kernel, cfg.channels, cfg.conv1),
                        cfg.kernel * cfg.channels),
        "conv1_b": jnp.zeros((cfg.conv1,)),
        "conv2_w": norm(k2, (cfg.kernel, cfg.conv1, cfg.conv2),
                        cfg.kernel * cfg.conv1),
        "conv2_b": jnp.zeros((cfg.conv2,)),
        "dense_w": norm(k3, (flat, cfg.hidden), flat),
        "dense_b": jnp.zeros((cfg.hidden,)),
        "head_w": norm(k4, (cfg.hidden, cfg.n_classes), cfg.hidden),
        "head_b": jnp.zeros((cfg.n_classes,)),
    }


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x (B, T, Cin), w (K, Cin, Cout) -> (B, T, Cout), SAME padding."""
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    b, t, c = x.shape
    return jnp.max(x.reshape(b, t // 2, 2, c), axis=2)


def har_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, C) float windows -> (B, n_classes) logits."""
    h = jax.nn.relu(_conv1d(x, params["conv1_w"], params["conv1_b"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv1d(h, params["conv2_w"], params["conv2_b"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense_w"] + params["dense_b"])
    return h @ params["head_w"] + params["head_b"]


def quantize_params(params: dict, bits: int) -> dict:
    """Post-training quantization of every weight tensor (paper Fig. 2c)."""
    return {k: (fake_quant_op(v, bits) if v.ndim >= 2 else v)
            for k, v in params.items()}


def har_apply_quantized(params: dict, x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantized inference: weights *and* activations fake-quantized — the
    ReRAM-crossbar deployment model of decisions D1/D2."""
    qp = quantize_params(params, bits)
    h = jax.nn.relu(_conv1d(fake_quant_op(x, bits), qp["conv1_w"], qp["conv1_b"]))
    h = fake_quant_op(_maxpool2(h), bits)
    h = jax.nn.relu(_conv1d(h, qp["conv2_w"], qp["conv2_b"]))
    h = fake_quant_op(_maxpool2(h), bits)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ qp["dense_w"] + qp["dense_b"])
    return h @ qp["head_w"] + qp["head_b"]


# ---------------------------------------------------------------------------
# Staged (intermittent) quantized inference — the same computation as
# har_apply_quantized, cut at the two pooling boundaries so an EH node can
# execute it piecewise across slots and brown-outs (Islam et al.,
# arXiv:2503.06663; Gobieski et al., arXiv:1810.07751).  Stage boundaries:
#
#   stage 0: fq(window) -> conv1 -> relu -> maxpool2 -> fq   ((T/2)·conv1)
#   stage 1:             conv2 -> relu -> maxpool2 -> fq     ((T/4)·conv2)
#   stage 2:             flatten -> dense -> relu -> head    (n_classes,)
#
# Each stage maps a flat activation buffer to the next (zero-padded to the
# common :func:`har_act_buffer` width so the buffer can ride a scan carry
# with one static shape), and running all three reproduces
# :func:`har_apply_quantized` BITWISE — the op order, fake-quant points and
# reshapes are mirrored exactly (pinned by tests/test_intermittent.py).
# ---------------------------------------------------------------------------


def har_stage_sizes(cfg: HARConfig) -> tuple[int, int, int, int]:
    """Flat float counts entering stages 0..2 plus the final logits width:
    (T·C, (T/2)·conv1, (T/4)·conv2, n_classes)."""
    return (cfg.window * cfg.channels,
            (cfg.window // 2) * cfg.conv1,
            (cfg.window // 4) * cfg.conv2,
            cfg.n_classes)


def har_act_buffer(cfg: HARConfig) -> int:
    """Width of the staged-activation carry buffer: every stage input/output
    (window, pooled conv maps, logits) zero-padded to one static size."""
    return max(har_stage_sizes(cfg))


def _pad_flat(v: jnp.ndarray, width: int) -> jnp.ndarray:
    return jnp.concatenate([v, jnp.zeros((width - v.shape[0],), v.dtype)])


def har_apply_stage(qp: dict, buf: jnp.ndarray, stage: int, cfg: HARConfig,
                    bits: int) -> jnp.ndarray:
    """Run ONE inference stage on a flat (A,) activation buffer, returning
    the next (A,) buffer.  ``qp`` is the pre-quantized params
    (:func:`quantize_params`); ``stage`` is static (0, 1 or 2).  The batch
    dim is kept at 1 internally so the conv/matmul shapes match the engine's
    per-node ``har_apply_quantized(window[None])`` call exactly."""
    a = buf.shape[0]
    s_in, s1, s2, n_cls = har_stage_sizes(cfg)
    if stage == 0:
        x = buf[:s_in].reshape(cfg.window, cfg.channels)
        h = jax.nn.relu(_conv1d(fake_quant_op(x[None], bits),
                                qp["conv1_w"], qp["conv1_b"]))
        h = fake_quant_op(_maxpool2(h), bits)
        return _pad_flat(h[0].reshape(-1), a)
    if stage == 1:
        h = buf[:s1].reshape(1, cfg.window // 2, cfg.conv1)
        h = jax.nn.relu(_conv1d(h, qp["conv2_w"], qp["conv2_b"]))
        h = fake_quant_op(_maxpool2(h), bits)
        return _pad_flat(h[0].reshape(-1), a)
    if stage == 2:
        h = buf[:s2][None]                       # (1, flat) like .reshape(B,-1)
        h = jax.nn.relu(h @ qp["dense_w"] + qp["dense_b"])
        logits = h @ qp["head_w"] + qp["head_b"]
        return _pad_flat(logits[0], a)
    raise ValueError(f"stage must be 0, 1 or 2, got {stage}")


def har_apply_staged(params: dict, x: jnp.ndarray, bits: int,
                     cfg: HARConfig) -> jnp.ndarray:
    """Chain all three stages over a (T, C) window -> (n_classes,) logits.

    The reference composition the intermittent lane's per-slot execution
    must agree with; bitwise-equal to ``har_apply_quantized(params, x[None],
    bits)[0]`` (tests pin it)."""
    qp = quantize_params(params, bits)
    buf = _pad_flat(x.reshape(-1), har_act_buffer(cfg))
    for stage in range(3):
        buf = har_apply_stage(qp, buf, stage, cfg, bits)
    return buf[:cfg.n_classes]


def har_aux_init(key: jax.Array, cfg: HARConfig) -> dict:
    """Early-exit auxiliary heads: one linear head per intermediate stage
    output (post-stage-0 and post-stage-1 pooled activations -> class
    logits).  A SEPARATE key from :func:`har_init` — the backbone's
    4-way key split is pinned by every bitwise-parity test and must not
    change."""
    k1, k2 = jax.random.split(key)
    _, s1, s2, n_cls = har_stage_sizes(cfg)

    def norm(k, shape, fan_in):
        return jax.random.normal(k, shape) / jnp.sqrt(fan_in)

    return {
        "aux1_w": norm(k1, (s1, n_cls), s1),
        "aux1_b": jnp.zeros((n_cls,)),
        "aux2_w": norm(k2, (s2, n_cls), s2),
        "aux2_b": jnp.zeros((n_cls,)),
    }


def har_apply_aux(aux_params: dict, buf: jnp.ndarray, prog: jnp.ndarray,
                  cfg: HARConfig, bits: int) -> jnp.ndarray:
    """Auxiliary-head logits from a flat staged-activation buffer holding
    the output of ``prog`` completed stages (traced; 1 or 2).  Both heads
    run (static shapes) and ``prog`` selects — the buffer is already
    fake-quantized by its producing stage; the head weights quantize at the
    same ``bits`` as the backbone crossbars."""
    _, s1, s2, n_cls = har_stage_sizes(cfg)
    a1 = (buf[:s1][None] @ fake_quant_op(aux_params["aux1_w"], bits)
          + aux_params["aux1_b"])[0]
    a2 = (buf[:s2][None] @ fake_quant_op(aux_params["aux2_w"], bits)
          + aux_params["aux2_b"])[0]
    return jnp.where(prog == 1, a1, a2)
