"""The paper's edge workloads: HAR / bearing-fault 1-D CNN classifiers.

Architecture follows Ha & Choi [26] as optimized for edge deployment in the
paper (two conv/pool stages + dense head), with three deployment variants:

* full-precision (Baseline-1 / host-side inference),
* 16-bit and 12-bit post-training fake-quantized copies (the sensor's two
  ReRAM crossbars, decision D1/D2) via the :mod:`repro.kernels` quant op,
* a *coreset-input* variant whose first layer consumes the (recovered or
  raw-coreset) representation (paper §3.2 "retrain the DNN models to
  recognize the compressed representation").

Pure functional JAX: params dict + apply fns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels.ops import fake_quant_op

__all__ = ["HARConfig", "har_init", "har_apply", "har_apply_quantized",
           "quantize_params"]


@dataclasses.dataclass(frozen=True)
class HARConfig:
    window: int = 60          # samples per window (paper: 60 @ 50 Hz)
    channels: int = 3         # IMU channels per sensor
    n_classes: int = 12       # MHEALTH activities
    conv1: int = 32
    conv2: int = 64
    kernel: int = 5
    hidden: int = 128


def har_init(key: jax.Array, cfg: HARConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def norm(k, shape, fan_in):
        return jax.random.normal(k, shape) / jnp.sqrt(fan_in)

    flat = (cfg.window // 4) * cfg.conv2
    return {
        "conv1_w": norm(k1, (cfg.kernel, cfg.channels, cfg.conv1),
                        cfg.kernel * cfg.channels),
        "conv1_b": jnp.zeros((cfg.conv1,)),
        "conv2_w": norm(k2, (cfg.kernel, cfg.conv1, cfg.conv2),
                        cfg.kernel * cfg.conv1),
        "conv2_b": jnp.zeros((cfg.conv2,)),
        "dense_w": norm(k3, (flat, cfg.hidden), flat),
        "dense_b": jnp.zeros((cfg.hidden,)),
        "head_w": norm(k4, (cfg.hidden, cfg.n_classes), cfg.hidden),
        "head_b": jnp.zeros((cfg.n_classes,)),
    }


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x (B, T, Cin), w (K, Cin, Cout) -> (B, T, Cout), SAME padding."""
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    b, t, c = x.shape
    return jnp.max(x.reshape(b, t // 2, 2, c), axis=2)


def har_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, C) float windows -> (B, n_classes) logits."""
    h = jax.nn.relu(_conv1d(x, params["conv1_w"], params["conv1_b"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv1d(h, params["conv2_w"], params["conv2_b"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense_w"] + params["dense_b"])
    return h @ params["head_w"] + params["head_b"]


def quantize_params(params: dict, bits: int) -> dict:
    """Post-training quantization of every weight tensor (paper Fig. 2c)."""
    return {k: (fake_quant_op(v, bits) if v.ndim >= 2 else v)
            for k, v in params.items()}


def har_apply_quantized(params: dict, x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantized inference: weights *and* activations fake-quantized — the
    ReRAM-crossbar deployment model of decisions D1/D2."""
    qp = quantize_params(params, bits)
    h = jax.nn.relu(_conv1d(fake_quant_op(x, bits), qp["conv1_w"], qp["conv1_b"]))
    h = fake_quant_op(_maxpool2(h), bits)
    h = jax.nn.relu(_conv1d(h, qp["conv2_w"], qp["conv2_b"]))
    h = fake_quant_op(_maxpool2(h), bits)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ qp["dense_w"] + qp["dense_b"])
    return h @ qp["head_w"] + qp["head_b"]
