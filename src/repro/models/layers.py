"""Shared transformer layers: norms, projections, RoPE/M-RoPE, attention.

Attention comes in four execution shapes, chosen by the caller for memory
*and* FLOP fidelity (the roofline reads HLO FLOPs, so we never compute masked
garbage at scale):

* :func:`dense_attention`       — materialized scores; short sequences & decode.
* :func:`pair_chunked_attention`— causal online-softmax over the *lower
  triangle of chunk pairs only* (~2x fewer FLOPs than mask-everything
  flash-style scans; exact).
* :func:`banded_attention`      — sliding-window attention via per-chunk KV
  band slices: FLOPs scale with S*(window+chunk), not S^2.
* :func:`decode_attention`      — one query step against a (ring or linear)
  KV cache with position-validity masking.

All attention functions take q:(B,S,G,R,D), k/v:(B,T,G,D) — GQA is expressed
by the (G=kv heads, R=q heads per kv head) split so repeated K/V are never
materialized.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..sharding import constrain

__all__ = [
    "rms_norm", "swiglu", "geglu", "rope_sincos", "apply_rope", "apply_mrope",
    "dense_attention", "pair_chunked_attention", "banded_attention",
    "decode_attention", "sinusoidal_positions", "NEG_INF",
]

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def geglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(gate, approximate=True) * up


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_sincos(positions: jnp.ndarray, head_dim: int,
                theta) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., S) -> sin/cos (..., S, head_dim//2). ``theta`` may be a
    traced scalar (per-layer theta inside a scanned run)."""
    half = head_dim // 2
    theta = jnp.asarray(theta, jnp.float32)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); sin/cos: (B, S, D/2) — rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, sections: tuple[int, ...],
                theta: float) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: ``positions`` (3, B, S) carries (temporal, h,
    w) ids; ``sections`` split the half-dim among the three components
    (sum(sections) == head_dim // 2)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    sins, coss = [], []
    for comp, sec in enumerate(sections):
        freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        lo = sum(sections[:comp])
        ang = positions[comp].astype(jnp.float32)[..., None] * freq[lo:lo + sec]
        sins.append(jnp.sin(ang))
        coss.append(jnp.cos(ang))
    sin = jnp.concatenate(sins, axis=-1)
    cos = jnp.concatenate(coss, axis=-1)
    return apply_rope(x, sin, cos)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embedding table (n, d)."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(n)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding evaluated at arbitrary (possibly traced)
    positions (..., ) -> (..., d).  Used when RoPE is disabled (whisper)."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention bodies
# ---------------------------------------------------------------------------

def _softmax_f32(scores: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    s = scores.astype(jnp.float32)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    return jax.nn.softmax(s, axis=-1)


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, softcap: float = 0.0) -> jnp.ndarray:
    """Materialized-score attention.  q (B,S,G,R,D); k,v (B,T,G,D)."""
    b, s, g, r, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    scores = (jnp.einsum("bsgrd,btgd->bgrst", q, k) * scale).astype(jnp.float32)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap   # BEFORE masking
    qpos = q_offset + jnp.arange(s)
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrst,btgd->bsgrd", probs, v)


def pair_chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           chunk: int = 512, softcap: float = 0.0) -> jnp.ndarray:
    """Exact causal attention scanning ONLY the lower-triangular chunk pairs.

    One sequential scan walks (i, j) pairs with j <= i in row-major order,
    carrying the online-softmax state (m, l, acc) of the current query row;
    each step writes the row's current normalized estimate back to the output
    buffer, so the final step of a row leaves the exact result.  FLOPs match
    T(T+1)/2 chunk pairs — no masked-garbage compute in the upper triangle.
    """
    b, s, g, r, d = q.shape
    assert s % chunk == 0, (s, chunk)
    t = s // chunk
    scale = 1.0 / math.sqrt(d)

    pairs_i = jnp.concatenate([jnp.full((i + 1,), i, jnp.int32) for i in range(t)])
    pairs_j = jnp.concatenate([jnp.arange(i + 1, dtype=jnp.int32) for i in range(t)])

    out0 = jnp.zeros_like(q)
    m0 = jnp.full((b, g, r, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, g, r, chunk), jnp.float32)
    acc0 = jnp.zeros((b, chunk, g, r, d), jnp.float32)

    def step(carry, ij):
        out, m, l, acc = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qi, kj).astype(jnp.float32) * scale
        if softcap > 0.0:
            scores = jnp.tanh(scores / softcap) * softcap
        qpos = i * chunk + jnp.arange(chunk)
        kpos = j * chunk + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrst,btgd->bsgrd", p.astype(q.dtype), vj).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        row = (acc_new / jnp.maximum(l_new, 1e-30).transpose(0, 3, 1, 2)[..., None])
        out = jax.lax.dynamic_update_slice_in_dim(out, row.astype(q.dtype),
                                                  i * chunk, axis=1)
        # reset the online state at the end of a row (j == i)
        is_end = (j == i)
        m_next = jnp.where(is_end, jnp.full_like(m_new, NEG_INF), m_new)
        l_next = jnp.where(is_end, jnp.zeros_like(l_new), l_new)
        acc_next = jnp.where(is_end, jnp.zeros_like(acc_new), acc_new)
        return (out, m_next, l_next, acc_next), None

    (out, _, _, _), _ = jax.lax.scan(step, (out0, m0, l0, acc0),
                                     (pairs_i, pairs_j))
    return out


def banded_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     window: int, chunk: int = 512,
                     softcap: float = 0.0) -> jnp.ndarray:
    """Sliding-window causal attention with FLOPs ~ S*(window+chunk).

    Each query chunk i attends to the KV band [i*chunk - window + 1,
    i*chunk + chunk); the band is a static-size dynamic slice of a
    left-padded KV, so no O(S^2) score tensor ever exists.
    """
    b, s, g, r, d = q.shape
    assert s % chunk == 0
    t = s // chunk
    scale = 1.0 / math.sqrt(d)
    band = window + chunk
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def row(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * chunk, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * chunk, band, axis=1)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qi, kb).astype(jnp.float32) * scale
        if softcap > 0.0:
            scores = jnp.tanh(scores / softcap) * softcap
        qpos = i * chunk + jnp.arange(chunk)
        kpos = i * chunk - window + jnp.arange(band)
        mask = ((kpos[None, :] >= 0) & (qpos[:, None] >= kpos[None, :])
                & (qpos[:, None] - kpos[None, :] < window))
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bgrst,btgd->bsgrd", probs, vb)

    rows = jax.lax.map(row, jnp.arange(t))                  # (T, B, chunk, G, R, D)
    return rows.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, g, r, d)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     slot_pos: jnp.ndarray, pos: jnp.ndarray, *,
                     window: int | None = None,
                     softcap: float = 0.0) -> jnp.ndarray:
    """One query step vs a cache.  q (B,1,G,R,D); caches (B,W,G,D);
    slot_pos (W,) int32 holds the *global* position stored in each slot
    (-1 = empty) so both linear and ring caches use the same masking."""
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bsgrd,btgd->bgrst", q, k_cache).astype(jnp.float32) * scale
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= (pos - slot_pos) < window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrst,btgd->bsgrd", probs, v_cache)
