"""Mixture-of-Experts FFN: GShard-style top-k dispatch with capacity.

TPU-idiomatic (static shapes, einsum dispatch): tokens are split into groups
of ``group_size``; within each group every token's top-k experts get a slot
up to ``capacity = ceil(group_size * top_k * capacity_factor / n_experts)``;
over-capacity tokens fall back to their residual (token dropping, as in
GShard/Switch).  Expert weights are sharded on the "experts"/"expert_ff"
logical axes so XLA emits the expected all-to-all when experts land on the
"model" mesh axis.

DeepSeekMoE's shared experts are a plain dense FFN of width
``n_shared * d_expert`` added unconditionally.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import MoEConfig
from ..sharding import constrain

__all__ = ["moe_capacity", "moe_apply", "moe_param_shapes"]


def moe_capacity(m: MoEConfig, group_size: int) -> int:
    cap = int(math.ceil(group_size * m.top_k * m.capacity_factor / m.n_experts))
    return max(cap, 1)


def moe_param_shapes(d_model: int, m: MoEConfig) -> dict[str, tuple]:
    """name -> (shape, logical_spec)."""
    shapes = {
        "router": ((d_model, m.n_experts), ("embed", "experts")),
        "w_gate": ((m.n_experts, d_model, m.d_expert), ("experts", "embed", "expert_ff")),
        "w_up": ((m.n_experts, d_model, m.d_expert), ("experts", "embed", "expert_ff")),
        "w_down": ((m.n_experts, m.d_expert, d_model), ("experts", "expert_ff", "embed")),
    }
    if m.n_shared:
        ds = m.n_shared * m.d_expert
        shapes.update({
            "shared_gate": ((d_model, ds), ("embed", "ff")),
            "shared_up": ((d_model, ds), ("embed", "ff")),
            "shared_down": ((ds, d_model), ("ff", "embed")),
        })
    return shapes


def moe_apply(params: dict, x: jnp.ndarray, m: MoEConfig, act) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).  ``act``: gate activation (silu/gelu)."""
    b, s, d = x.shape
    tokens = b * s
    group = min(m.group_size, tokens)
    assert tokens % group == 0, (tokens, group)
    g = tokens // group
    cap = moe_capacity(m, group)
    e = m.n_experts
    xt = x.reshape(g, group, d)

    logits = jnp.einsum("gsd,de->gse", xt, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)              # (g, s, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert queue, group-local
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)      # (g, s, k, e)
    flat = onehot.reshape(g, group * m.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, group, m.top_k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                      # (g, s, k)
    keep = pos < cap
    cap_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)      # (g, s, k, cap)

    # dispatch (g, s, e, cap) and combine (weighted) tensors
    dispatch = jnp.einsum("gske,gskc->gsec", onehot,
                          cap_oh * keep[..., None]).astype(x.dtype)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot,
                         cap_oh * keep[..., None],
                         top_p.astype(jnp.float32)).astype(x.dtype)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xt)    # (g, e, cap, d)
    expert_in = constrain(expert_in, None, "experts", None, "embed_act")
    gate = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"].astype(x.dtype))
    h = act(gate, up)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)

    if m.n_shared:
        sg = jnp.einsum("gsd,df->gsf", xt, params["shared_gate"].astype(x.dtype))
        su = jnp.einsum("gsd,df->gsf", xt, params["shared_up"].astype(x.dtype))
        out = out + jnp.einsum("gsf,fd->gsd", act(sg, su),
                               params["shared_down"].astype(x.dtype))
    return out.reshape(b, s, d)
