"""Unified model configuration covering all ten assigned architectures.

One ``ModelConfig`` describes a decoder-only LM (optionally with a Whisper
style encoder for the enc-dec case).  Per-layer heterogeneity (local vs
global attention, RG-LRU vs attention, dense vs MoE FFN) is expressed by
``block_pattern`` / ``moe_layers``; the transformer groups consecutive
identical layers into *runs* and ``lax.scan``s each run over stacked params.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["MoEConfig", "ModelConfig", "pattern_runs"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0                 # shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    group_size: int = 512             # tokens per dispatch group
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv: int
    d_ff: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # per-layer mixer type: "attn" | "local" | "rglru" | "ssd"
    block_pattern: tuple[str, ...] = ()
    mlp: str = "swiglu"               # "swiglu" | "geglu" | "gelu" | "none"
    moe: MoEConfig | None = None
    moe_layers: tuple[int, ...] = ()  # layer indices whose FFN is the MoE
    window: int = 1024                # sliding window for "local" layers
    rope_theta: float = 10000.0
    global_rope_theta: float = 0.0    # gemma3: distinct theta on global layers
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (empty = off)
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    embed_scale: bool = False         # gemma family: embeddings * sqrt(d)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # SSM (mamba2 / SSD)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    rnn_width: int = 0                # 0 -> d_model
    # encoder (whisper): frames arrive pre-embedded (conv frontend is a stub)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # vision stub (qwen2-vl): patch embeddings are prepended to the sequence
    vision_patches: int = 0
    # numerics
    dtype: Any = jnp.bfloat16         # compute/activation dtype
    param_dtype: Any = jnp.float32
    # embedding table padded up so logits shard cleanly on the model axis
    # (Megatron/MaxText convention); padded ids are masked to -inf
    vocab_pad_multiple: int = 256
    # zero-pad q-heads up to this quantum when the head count doesn't divide
    # the model mesh axis (exact math: padded wq/wo rows are zero; KV heads
    # are gather-expanded).  0 disables (smoke/CPU configs).
    head_pad_multiple: int = 0
    # attention execution thresholds
    dense_attn_max_seq: int = 2048
    attn_chunk: int = 512
    # flash (custom-vjp recompute-backward) attention for chunked paths:
    # exact, avoids scan-carry residuals (§Perf iteration "flash-vjp")
    flash_attention: bool = True
    remat: str = "none"               # "none" | "full"

    def __post_init__(self):
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("attn",) * self.n_layers)
        assert len(self.block_pattern) == self.n_layers, (
            len(self.block_pattern), self.n_layers)
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def padded_heads(self) -> int:
        m = self.head_pad_multiple
        if m and self.n_heads % m:
            return ((self.n_heads + m - 1) // m) * m
        return self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, idx: int) -> tuple[str, bool]:
        """(mixer_type, is_moe) for layer ``idx``."""
        return self.block_pattern[idx], idx in self.moe_layers

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d = self.d_model
        n = self.vocab * d                                   # embedding
        if not self.tie_embeddings:
            n += self.vocab * d
        for i in range(self.n_layers):
            kind, is_moe = self.layer_kind(i)
            if kind in ("attn", "local"):
                n += d * self.n_heads * self.head_dim        # wq
                n += 2 * d * self.n_kv * self.head_dim       # wk, wv
                n += self.n_heads * self.head_dim * d        # wo
            elif kind == "rglru":
                w = self.rnn_width
                n += 2 * d * w + self.conv_width * w + 2 * w * w + 3 * w + w * d
            elif kind == "ssd":
                di, g, ns, h = (self.d_inner, self.ssm_groups, self.ssm_state,
                                self.ssm_heads)
                n += d * (2 * di + 2 * g * ns + h)           # in projections
                n += self.conv_width * (di + 2 * g * ns)     # conv
                n += 3 * h + di                              # A, D, dt_bias, norm
                n += di * d                                  # out_proj
            if self.mlp != "none":
                if is_moe and self.moe is not None:
                    m = self.moe
                    n += d * m.n_experts                      # router
                    n += m.n_experts * 3 * d * m.d_expert     # routed experts
                    n += 3 * d * (m.n_shared * m.d_expert)    # shared experts
                else:
                    mult = 3 if self.mlp in ("swiglu", "geglu") else 2
                    n += mult * d * self.d_ff
            n += 2 * d                                       # pre-norms
        n += d                                               # final norm
        if self.encoder_layers:
            n += self.encoder_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
            # decoder cross-attention
            n += self.n_layers * (4 * d * d + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None or not self.moe_layers:
            return self.param_count()
        m = self.moe
        inactive = len(self.moe_layers) * (m.n_experts - m.top_k) * 3 * self.d_model * m.d_expert
        return self.param_count() - inactive


def pattern_runs(cfg: ModelConfig) -> list[tuple[str, bool, int, int]]:
    """Group consecutive identical layers: [(mixer, is_moe, start, length)].

    A run is scanned over stacked params; heterogeneous patterns (gemma3's
    5 local : 1 global, recurrentgemma's R,R,A) become short run sequences.
    """
    runs: list[tuple[str, bool, int, int]] = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if runs and (runs[-1][0], runs[-1][1]) == kind:
            mixer, moe, start, length = runs[-1]
            runs[-1] = (mixer, moe, start, length + 1)
        else:
            runs.append((kind[0], kind[1], i, 1))
    return runs
