"""Flash-style exact attention with custom VJP (pure XLA ops).

Why: differentiating ``lax.scan``-based chunked attention makes JAX save the
scan carries for every step — for a (B,4096,56,128) query block that is
~8 GiB of residuals PER LAYER, the dominant memory term of the big train
cells (see EXPERIMENTS.md §Perf, yi-34b iteration log).  The classic fix is
FlashAttention's recompute-backward: forward saves only (out, LSE); backward
re-walks the chunk pairs, recomputing probabilities.  Since both walks live
inside ``jax.custom_vjp`` they are never themselves differentiated, so no
scan carries are ever saved.

Two variants, both numerically exact (validated against dense attention in
tests/test_models.py):

* :func:`flash_causal_attention` — lower-triangular chunk-pair walk
  (FLOPs = T(T+1)/2 pairs; no masked-garbage compute).
* :func:`flash_banded_attention` — sliding-window band walk
  (FLOPs ~ S*(window+chunk)).

Shapes follow layers.py: q (B,S,G,R,D), k/v (B,T,G,D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["flash_causal_attention", "flash_banded_attention"]


# ---------------------------------------------------------------------------
# Causal (lower-triangular chunk pairs)
# ---------------------------------------------------------------------------

def _pairs(t: int):
    pi = jnp.concatenate([jnp.full((i + 1,), i, jnp.int32) for i in range(t)])
    pj = jnp.concatenate([jnp.arange(i + 1, dtype=jnp.int32) for i in range(t)])
    return pi, pj


def _causal_fwd_walk(q, k, v, chunk: int, softcap: float):
    b, s, g, r, d = q.shape
    t = s // chunk
    scale = 1.0 / math.sqrt(d)
    pi, pj = _pairs(t)

    out0 = jnp.zeros_like(q)
    lse0 = jnp.zeros((b, g, r, s), jnp.float32)
    m0 = jnp.full((b, g, r, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, g, r, chunk), jnp.float32)
    acc0 = jnp.zeros((b, chunk, g, r, d), jnp.float32)

    def step(carry, ij):
        out, lse, m, l, acc = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qi, kj).astype(jnp.float32) * scale
        if softcap > 0.0:
            scores = jnp.tanh(scores / softcap) * softcap
        qpos = i * chunk + jnp.arange(chunk)
        kpos = j * chunk + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrst,btgd->bsgrd", p.astype(q.dtype), vj).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        row = acc_new / jnp.maximum(l_new, 1e-30).transpose(0, 3, 1, 2)[..., None]
        out = jax.lax.dynamic_update_slice_in_dim(out, row.astype(q.dtype),
                                                  i * chunk, axis=1)
        lse_row = m_new + jnp.log(jnp.maximum(l_new, 1e-30))
        lse = jax.lax.dynamic_update_slice_in_dim(lse, lse_row, i * chunk,
                                                  axis=3)
        is_end = (j == i)
        m = jnp.where(is_end, jnp.full_like(m_new, NEG_INF), m_new)
        l = jnp.where(is_end, jnp.zeros_like(l_new), l_new)
        acc = jnp.where(is_end, jnp.zeros_like(acc_new), acc_new)
        return (out, lse, m, l, acc), None

    (out, lse, _, _, _), _ = jax.lax.scan(step, (out0, lse0, m0, l0, acc0),
                                          (pi, pj))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_causal_attention(q, k, v, chunk: int = 512, softcap: float = 0.0):
    out, _ = _causal_fwd_walk(q, k, v, chunk, softcap)
    return out


def _causal_fwd(q, k, v, chunk, softcap):
    out, lse = _causal_fwd_walk(q, k, v, chunk, softcap)
    return out, (q, k, v, out, lse)


def _causal_bwd(chunk, softcap, res, dout):
    q, k, v, out, lse = res
    b, s, g, r, d = q.shape
    t = s // chunk
    scale = 1.0 / math.sqrt(d)
    pi, pj = _pairs(t)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                  # (b,s,g,r)
    delta = delta.transpose(0, 2, 3, 1)                       # (b,g,r,s)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def step(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        doi = jax.lax.dynamic_slice_in_dim(dout, i * chunk, chunk, axis=1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * chunk, chunk, axis=3)
        del_i = jax.lax.dynamic_slice_in_dim(delta, i * chunk, chunk, axis=3)

        raw = jnp.einsum("bsgrd,btgd->bgrst", qi, kj).astype(jnp.float32) * scale
        if softcap > 0.0:
            capped = jnp.tanh(raw / softcap)
            scores = capped * softcap
        else:
            scores = raw
        qpos = i * chunk + jnp.arange(chunk)
        kpos = j * chunk + jnp.arange(chunk)
        mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        p = jnp.exp(scores - lse_i[..., None])                # (b,g,r,s,t)
        dp = jnp.einsum("bsgrd,btgd->bgrst", doi, vj).astype(jnp.float32)
        ds = p * (dp - del_i[..., None])
        if softcap > 0.0:
            ds = ds * (1.0 - capped ** 2)                     # softcap chain
        ds = jnp.where(mask, ds, 0.0) * scale
        dq_i = jnp.einsum("bgrst,btgd->bsgrd", ds.astype(q.dtype), kj)
        dk_j = jnp.einsum("bgrst,bsgrd->btgd", ds.astype(q.dtype), qi)
        dv_j = jnp.einsum("bgrst,bsgrd->btgd", p.astype(q.dtype), doi)
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * chunk, chunk, 1)
            + dq_i.astype(jnp.float32), i * chunk, axis=1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * chunk, chunk, 1)
            + dk_j.astype(jnp.float32), j * chunk, axis=1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * chunk, chunk, 1)
            + dv_j.astype(jnp.float32), j * chunk, axis=1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), (pi, pj))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_causal_attention.defvjp(_causal_fwd, _causal_bwd)


# ---------------------------------------------------------------------------
# Banded (sliding window)
# ---------------------------------------------------------------------------

def _band_scores(qi, kb, i, chunk, window, band, scale, softcap):
    raw = jnp.einsum("bsgrd,btgd->bgrst", qi, kb).astype(jnp.float32) * scale
    capped = None
    if softcap > 0.0:
        capped = jnp.tanh(raw / softcap)
        raw = capped * softcap
    qpos = i * chunk + jnp.arange(chunk)
    kpos = i * chunk - window + jnp.arange(band)
    mask = ((kpos[None, :] >= 0) & (qpos[:, None] >= kpos[None, :])
            & (qpos[:, None] - kpos[None, :] < window))[None, None, None]
    return jnp.where(mask, raw, NEG_INF), mask, capped


def _banded_fwd_walk(q, k, v, window: int, chunk: int, softcap: float):
    b, s, g, r, d = q.shape
    t = s // chunk
    band = window + chunk
    scale = 1.0 / math.sqrt(d)
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def row(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * chunk, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * chunk, band, axis=1)
        scores, _, _ = _band_scores(qi, kb, i, chunk, window, band, scale,
                                    softcap)
        m = jnp.max(scores, axis=-1)
        p = jnp.exp(scores - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bgrst,btgd->bsgrd", (p / jnp.maximum(l, 1e-30)[..., None]
                                             ).astype(q.dtype), vb)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return o, lse

    outs, lses = jax.lax.map(row, jnp.arange(t))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, g, r, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, g, r, s)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_banded_attention(q, k, v, window: int, chunk: int = 512,
                           softcap: float = 0.0):
    out, _ = _banded_fwd_walk(q, k, v, window, chunk, softcap)
    return out


def _banded_fwd(q, k, v, window, chunk, softcap):
    out, lse = _banded_fwd_walk(q, k, v, window, chunk, softcap)
    return out, (q, k, v, out, lse)


def _banded_bwd(window, chunk, softcap, res, dout):
    q, k, v, out, lse = res
    b, s, g, r, d = q.shape
    t = s // chunk
    band = window + chunk
    scale = 1.0 / math.sqrt(d)
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 3, 1)            # (b,g,r,s)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dkp0 = jnp.zeros(kp.shape, jnp.float32)
    dvp0 = jnp.zeros(vp.shape, jnp.float32)

    def step(carry, i):
        dq, dkp, dvp = carry
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * chunk, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * chunk, band, axis=1)
        doi = jax.lax.dynamic_slice_in_dim(dout, i * chunk, chunk, axis=1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * chunk, chunk, axis=3)
        del_i = jax.lax.dynamic_slice_in_dim(delta, i * chunk, chunk, axis=3)
        scores, mask, capped = _band_scores(qi, kb, i, chunk, window, band,
                                            scale, softcap)
        p = jnp.exp(scores - lse_i[..., None])
        dp = jnp.einsum("bsgrd,btgd->bgrst", doi, vb).astype(jnp.float32)
        ds = p * (dp - del_i[..., None])
        if softcap > 0.0:
            ds = ds * (1.0 - capped ** 2)
        ds = jnp.where(mask, ds, 0.0) * scale
        dq_i = jnp.einsum("bgrst,btgd->bsgrd", ds.astype(q.dtype), kb)
        dk_b = jnp.einsum("bgrst,bsgrd->btgd", ds.astype(q.dtype), qi)
        dv_b = jnp.einsum("bgrst,bsgrd->btgd", p.astype(q.dtype), doi)
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, dq_i.astype(jnp.float32), i * chunk, axis=1)
        dkp = jax.lax.dynamic_update_slice_in_dim(
            dkp, jax.lax.dynamic_slice_in_dim(dkp, i * chunk, band, 1)
            + dk_b.astype(jnp.float32), i * chunk, axis=1)
        dvp = jax.lax.dynamic_update_slice_in_dim(
            dvp, jax.lax.dynamic_slice_in_dim(dvp, i * chunk, band, 1)
            + dv_b.astype(jnp.float32), i * chunk, axis=1)
        return (dq, dkp, dvp), None

    (dq, dkp, dvp), _ = jax.lax.scan(step, (dq0, dkp0, dvp0), jnp.arange(t))
    return (dq.astype(q.dtype), dkp[:, window:].astype(k.dtype),
            dvp[:, window:].astype(v.dtype))


flash_banded_attention.defvjp(_banded_fwd, _banded_bwd)
