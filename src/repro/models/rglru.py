"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Griffin recurrent block: two input branches (a GeLU gate and a conv1d'd
signal path), a Real-Gated Linear Recurrent Unit over the signal path, and an
output projection of the gated product.

RG-LRU recurrence (Griffin eq. 3-6):

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_i x_t + b_i)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (log-depth);
decode is the single-step update, with a (B, W-1, D) conv ring for the
temporal conv.  State is O(B*D) — why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["rglru_param_shapes", "rglru_apply", "rglru_decode_step",
           "rglru_state_shapes"]

_C = 8.0


def rglru_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, w, cw = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return {
        "w_x": ((d, w), ("embed", "state")),
        "w_g": ((d, w), ("embed", "state")),
        "conv_w": ((cw, w), ("conv", "state")),
        "lam": ((w,), ("state",)),
        "w_a": ((w, w), ("state", None)),
        "b_a": ((w,), ("state",)),
        "w_i": ((w, w), ("state", None)),
        "b_i": ((w,), ("state",)),
        "w_o": ((w, d), ("state", "embed")),
    }


def rglru_state_shapes(cfg: ModelConfig, batch: int) -> dict[str, tuple]:
    return {
        "h": ((batch, cfg.rnn_width), ("batch", "state")),
        "conv_buf": ((batch, cfg.conv_width - 1, cfg.rnn_width),
                     ("batch", None, "state")),
    }


def _gates(p: dict, xt: jnp.ndarray):
    r = jax.nn.sigmoid(xt @ p["w_a"].astype(xt.dtype) + p["b_a"].astype(xt.dtype))
    i = jax.nn.sigmoid(xt @ p["w_i"].astype(xt.dtype) + p["b_i"].astype(xt.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, (beta * (i.astype(jnp.float32) * xt.astype(jnp.float32)))


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along axis 1 of (B, S, D); w (cw, D)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(cw):
        out = out + jax.lax.dynamic_slice_in_dim(
            xp, j, x.shape[1], axis=1) * w[j].astype(x.dtype)
    return out


def rglru_apply(p: dict, x: jnp.ndarray, return_state: bool = False):
    """Full-sequence Griffin recurrent block. x: (B, S, D_model).

    With ``return_state`` also emits the decode-resumable state
    {h: (B, W), conv_buf: (B, cw-1, W)} for prefill."""
    gate = jax.nn.gelu(x @ p["w_g"].astype(x.dtype), approximate=True)
    sig_raw = x @ p["w_x"].astype(x.dtype)
    sig = _conv1d_causal(sig_raw, p["conv_w"])
    a, bx = _gates(p, sig)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["w_o"].astype(x.dtype)
    if not return_state:
        return out
    cw = p["conv_w"].shape[0]
    tail = sig_raw[:, -(cw - 1):] if cw > 1 else sig_raw[:, :0]
    pad = (cw - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return out, {"h": h[:, -1].astype(x.dtype), "conv_buf": tail}


def rglru_decode_step(p: dict, state: dict, x: jnp.ndarray):
    """One-token update. x: (B, 1, D). Returns (out (B,1,D), new_state)."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ p["w_g"].astype(x.dtype), approximate=True)
    sig = xt @ p["w_x"].astype(x.dtype)
    # temporal conv over the ring buffer + current input
    hist = jnp.concatenate([state["conv_buf"].astype(x.dtype), sig[:, None]], axis=1)
    cw = p["conv_w"].shape[0]
    sig_c = jnp.einsum("bwd,wd->bd", hist[:, -cw:], p["conv_w"].astype(x.dtype))
    a, bx = _gates(p, sig_c)
    h = a * state["h"].astype(jnp.float32) + bx
    out = (h.astype(x.dtype) * gate) @ p["w_o"].astype(x.dtype)
    new_state = {
        "h": h.astype(state["h"].dtype),
        "conv_buf": hist[:, 1:].astype(state["conv_buf"].dtype),
    }
    return out[:, None], new_state
