from .adamw import OptConfig, adamw_init, adamw_update, opt_state_specs, global_norm  # noqa: F401
from .schedule import warmup_cosine, constant_lr  # noqa: F401
