"""In-house AdamW with sharding-aware state and selectable moment dtype.

Moments inherit each parameter's logical sharding (ZeRO-1 falls out of the
FSDP rules for free); ``moment_dtype=bfloat16`` halves optimizer memory for
the 314B-class configs (the grok fit lever — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_spec_tree):
    """Logical specs for the optimizer state, mirroring the params."""
    is_leaf = lambda s: isinstance(s, tuple) and all(
        isinstance(e, (str, type(None))) for e in s)
    copy = lambda: jax.tree_util.tree_map(lambda s: s, param_spec_tree,
                                          is_leaf=is_leaf)
    return {"m": copy(), "v": copy(), "step": ()}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state, cfg: OptConfig, lr: jnp.ndarray):
    """One AdamW step. Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:     # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
