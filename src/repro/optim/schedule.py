"""Learning-rate schedules (pure jnp functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant_lr"]


def warmup_cosine(step: jnp.ndarray, peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = peak * s / jnp.maximum(warmup, 1)
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(s < warmup, warm, cos)


def constant_lr(step: jnp.ndarray, peak: float, **_) -> jnp.ndarray:
    return jnp.full_like(step, peak, dtype=jnp.float32)
