"""Shard-aware checkpointing with atomic commit and restart semantics.

Design (single-process container; the multi-host story is the same protocol
per host with a rendezvous commit):

* a checkpoint is a directory ``step_<k>/`` of one ``.npy`` per pytree leaf
  (key-path encoded file names) plus a ``MANIFEST.json`` written LAST — a
  checkpoint without a manifest is an aborted write and is ignored/garbage
  collected, which makes the save atomic under preemption (the paper's NVP
  "commit" semantics at pod scale).
* restore takes an *abstract* target tree (ShapeDtypeStructs) and optional
  NamedShardings and `device_put`s each leaf to its shard layout, so a
  checkpoint written on one mesh restores onto another (elastic re-mesh).
* ``keep`` bounds retained checkpoints (oldest pruned after commit).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_MANIFEST = "MANIFEST.json"


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = leaf
    return out


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and os.path.exists(
                os.path.join(root, d, _MANIFEST)):
            steps.append(int(d.split("_")[1]))
    return sorted(steps)


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def save_checkpoint(root: str, step: int, tree, keep: int = 3) -> str:
    """Write ``tree`` at ``step``; atomic via tmp-dir + manifest-last."""
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][name] = {"file": fname, "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # prune
    steps = list_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
    # drop aborted writes
    for d in os.listdir(root):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)
    return final


def restore_checkpoint(root: str, step: int, abstract_tree, shardings=None):
    """Restore ``step`` into the structure of ``abstract_tree``.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    leaves are device_put to them (elastic restore onto any mesh)."""
    d = _step_dir(root, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_abs = _flatten(abstract_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves_meta = manifest["leaves"]
    out = {}
    for name, ref in flat_abs.items():
        if name not in leaves_meta:
            raise KeyError(f"checkpoint at step {step} missing leaf {name}")
        arr = np.load(os.path.join(d, leaves_meta[name]["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        if name in flat_shard:
            out[name] = jax.device_put(arr, flat_shard[name])
        else:
            out[name] = jax.numpy.asarray(arr)
    # rebuild the tree
    flat_paths, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
    ordered = []
    for path, _leaf in flat_paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        ordered.append(out[name])
    return jax.tree_util.tree_unflatten(treedef, ordered)
