"""The paper's own workloads: HAR (MHEALTH/PAMAP2-like) and bearing-fault
(CWRU-like) edge classifiers + the Seeker system parameters.

These are the configs the benchmarks (Tables 1-2, Figs 2/6/10-13) run with.
Values straight from the paper: 60-sample windows at 50 Hz with 30 overlap,
3 IMU channels, 12 default clusters, 20 importance samples, corr >= 0.95
memoization, 16/12-bit quantized edge DNNs.
"""
import dataclasses

from repro.core.energy import EnergyCosts
from repro.models.har import HARConfig

HAR = HARConfig(window=60, channels=3, n_classes=12, conv1=32, conv2=64,
                kernel=5, hidden=128)

# PAMAP2: 12 activities (protocol subset), 3 IMUs (hand/chest/ankle)
PAMAP2 = HARConfig(window=60, channels=3, n_classes=12, conv1=32, conv2=64,
                   kernel=5, hidden=128)

# Bearing fault (CWRU-like): higher sample rate -> wider window, more
# clusters (paper A.2: 15-20 clusters needed), 10 fault classes
BEARING = HARConfig(window=120, channels=1, n_classes=10, conv1=32, conv2=64,
                    kernel=7, hidden=128)


@dataclasses.dataclass(frozen=True)
class SeekerSystem:
    """System-level knobs (paper §4)."""
    n_sensors: int = 3                 # left ankle, right arm, chest
    default_clusters: int = 12
    bearing_clusters: int = 18
    sampling_points: int = 20
    corr_threshold: float = 0.95
    quant_bits: tuple[int, int] = (16, 12)
    kmeans_iters: int = 4
    sampling_iters: int = 7
    max_points_per_cluster: int = 16
    supercap_uj: float = 200.0
    predictor_window: int = 8
    costs: EnergyCosts = dataclasses.field(default_factory=EnergyCosts)


SYSTEM = SeekerSystem()
