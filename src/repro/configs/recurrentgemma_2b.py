"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention at 1:2 ratio [arXiv:2402.19427; hf].

Griffin pattern: (recurrent, recurrent, local-attention) repeating; the two
trailing layers are recurrent (26 = 8x3 + 2).  Local window 2048; fixed-size
RG-LRU state => runs the long_500k cell.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _pattern(n_layers: int) -> tuple[str, ...]:
    return tuple(
        "local" if (i % 3) == 2 else "rglru"
        for i in range(n_layers))


CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    vocab=256_000,
    d_model=2560,
    n_layers=26,
    n_heads=10,
    n_kv=1,
    head_dim=256,
    d_ff=7680,
    mlp="geglu",
    block_pattern=_pattern(26),
    window=2048,
    rnn_width=2560,
    conv_width=4,
    rope_theta=10_000.0,
    embed_scale=True,
    tie_embeddings=True,
    head_pad_multiple=16,
    remat="full",
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    vocab=512,
    d_model=64,
    n_layers=6,
    n_heads=4,
    n_kv=1,
    head_dim=16,
    d_ff=128,
    mlp="geglu",
    block_pattern=_pattern(6),
    window=8,
    rnn_width=64,
    embed_scale=True,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = True  # fixed-size recurrent state + windowed attention
IS_DECODER = True
