"""whisper-small [audio]: 12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865 — encoder-decoder; conv frontend is a STUB per the assignment
(input_specs() supplies precomputed (B, 1500, 768) frame embeddings)
[arXiv:2212.04356; unverified].

Enc-dec (NOT encoder-only): decode shapes run against the decoder with
cached cross-attention K/V.  RoPE disabled (theta=0) — absolute sinusoidal
positions, as in the published model.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    vocab=51_865,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv=12,
    d_ff=3072,
    mlp="gelu",
    rope_theta=0.0,            # sinusoidal absolute positions instead
    encoder_layers=12,
    encoder_frames=1500,
    tie_embeddings=True,
    head_pad_multiple=16,
    remat="full",
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    mlp="gelu",
    rope_theta=0.0,
    encoder_layers=2,
    encoder_frames=24,
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # full-attention decoder
IS_DECODER = True
