"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].

d_inner = 2*768 = 1536, headdim 64 => 24 SSD heads, 1 B/C group, conv width
4.  Constant-size state => the cheapest long_500k cell in the fleet.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    vocab=50_280,
    d_model=768,
    n_layers=24,
    n_heads=0,
    n_kv=0,
    head_dim=1,
    d_ff=0,
    mlp="none",
    block_pattern=("ssd",) * 24,
    ssm_state=128,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    vocab=512,
    d_model=64,
    n_layers=3,
    n_heads=0,
    n_kv=0,
    head_dim=1,
    d_ff=0,
    mlp="none",
    block_pattern=("ssd",) * 3,
    ssm_state=16,
    ssm_headdim=16,
    ssm_groups=1,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = True  # attention-free constant state
IS_DECODER = True
