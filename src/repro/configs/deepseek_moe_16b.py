"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400, 64 routed experts top-6 + 2 shared, fine-grained
[arXiv:2401.06066; hf].

Faithful details: layer 0 uses a dense FFN (the published model's first
layer is non-MoE; width 8 x d_expert ~= the published 10944); layers 1..27
are MoE with 2 shared experts always-on.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    vocab=102_400,
    d_model=2048,
    n_layers=28,
    n_heads=16,
    n_kv=16,
    d_ff=8 * 1408,             # dense layer-0 FFN
    mlp="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25, group_size=512),
    moe_layers=tuple(range(1, 28)),
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat="full",
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke",
    vocab=512,
    d_model=64,
    n_layers=4,
    n_heads=4,
    n_kv=4,
    d_ff=256,
    mlp="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2,
                  capacity_factor=2.0, group_size=64),
    moe_layers=(1, 2, 3),
    tie_embeddings=False,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention
IS_DECODER = True
