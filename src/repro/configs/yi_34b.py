"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
llama-architecture GQA [arXiv:2403.04652; hf].  Yi uses theta=5e6 for its
4k->200k context extension."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    vocab=64_000,
    d_model=7168,
    n_layers=60,
    n_heads=56,
    n_kv=8,
    d_ff=20480,
    mlp="swiglu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    head_pad_multiple=16,
    remat="full",
)

SMOKE = ModelConfig(
    name="yi-34b-smoke",
    vocab=512,
    d_model=64,
    n_layers=3,
    n_heads=8,
    n_kv=2,
    d_ff=192,
    mlp="swiglu",
    tie_embeddings=False,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention
IS_DECODER = True
