"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt family; unverified].

Pattern: every 6th layer is global attention (theta=1M), the rest are
1024-window sliding-window layers (theta=10k).  Local runs get window-sized
ring caches, which is what makes the long_500k decode cell feasible:
40 local layers hold 1024-token KV, only 8 global layers hold the full 500k.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _pattern(n_layers: int, ratio: int = 5) -> tuple[str, ...]:
    return tuple(
        "attn" if (i % (ratio + 1)) == ratio else "local"
        for i in range(n_layers))


CONFIG = ModelConfig(
    name="gemma3-12b",
    vocab=262_144,
    d_model=3840,
    n_layers=48,
    n_heads=16,
    n_kv=8,
    head_dim=240,
    d_ff=15360,
    mlp="geglu",
    block_pattern=_pattern(48),
    window=1024,
    rope_theta=10_000.0,
    global_rope_theta=1_000_000.0,
    embed_scale=True,
    tie_embeddings=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke",
    vocab=512,
    d_model=64,
    n_layers=6,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    mlp="geglu",
    block_pattern=_pattern(6),
    window=8,
    rope_theta=10_000.0,
    global_rope_theta=1_000_000.0,
    embed_scale=True,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = True  # local-dominant (5:1): sub-quadratic in practice
IS_DECODER = True
