"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
— M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision tower is a STUB; input_specs()
supplies precomputed patch embeddings (B, 64, 1536) that are prepended to
the text tokens.  M-RoPE sections (16, 24, 24) over the 64-dim half of the
128 head_dim; vision patches get (t=0, h, w) grid ids, text continues
sequentially.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    vocab=151_936,
    d_model=1536,
    n_layers=28,
    n_heads=12,
    n_kv=2,
    head_dim=128,
    d_ff=8960,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    vision_patches=64,
    tie_embeddings=True,
    head_pad_multiple=16,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    vocab=512,
    d_model=64,
    n_layers=3,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    mlp="swiglu",
    mrope_sections=(4, 2, 2),
    vision_patches=4,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention
IS_DECODER = True
