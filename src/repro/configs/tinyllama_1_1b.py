"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-architecture small [arXiv:2401.02385; hf]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    vocab=32_000,
    d_model=2048,
    n_layers=22,
    n_heads=32,
    n_kv=4,
    d_ff=5632,
    mlp="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat="full",
)

SMOKE = ModelConfig(
    name="tinyllama-1.1b-smoke",
    vocab=512,
    d_model=64,
    n_layers=3,
    n_heads=8,
    n_kv=2,
    d_ff=160,
    mlp="swiglu",
    tie_embeddings=False,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention
IS_DECODER = True
