"""Architecture registry: one module per assigned architecture, each exposing

* ``CONFIG``  — the exact published configuration (full size),
* ``SMOKE``   — a reduced same-family config for CPU smoke tests,
* ``LONG_CONTEXT_OK`` — whether the arch runs the long_500k cell
  (sub-quadratic attention only, per the assignment spec),
* ``IS_DECODER`` — has a decode step (all ten do; encoder-only would not).

``get_config(name)`` / ``get_smoke(name)`` / ``ARCHS`` are the public API.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "gemma-2b", "gemma3-12b", "tinyllama-1.1b", "yi-34b", "recurrentgemma-2b",
    "deepseek-moe-16b", "grok-1-314b", "whisper-small", "mamba2-130m",
    "qwen2-vl-2b",
)

_MODULES = {
    "gemma-2b": "gemma_2b",
    "gemma3-12b": "gemma3_12b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "yi-34b": "yi_34b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "grok-1-314b": "grok_1_314b",
    "whisper-small": "whisper_small",
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).SMOKE


def long_context_ok(name: str) -> bool:
    return getattr(_mod(name), "LONG_CONTEXT_OK", False)
