"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256 [arXiv:2403.08295; hf].

MQA (kv=1) cannot split across the 16-way model axis — KV projections
replicate; Q heads still shard 8-way (spec_for drops non-divisible axes).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    vocab=256_000,
    d_model=2048,
    n_layers=18,
    n_heads=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    mlp="geglu",
    rope_theta=10_000.0,
    embed_scale=True,
    tie_embeddings=True,
    head_pad_multiple=16,
    remat="full",
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    vocab=512,
    d_model=64,
    n_layers=3,
    n_heads=4,
    n_kv=1,
    head_dim=16,
    d_ff=128,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention: long_500k skipped (DESIGN.md)
IS_DECODER = True
