"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, 8 experts top-2 [hf:xai-org/grok-1; unverified].

Every layer is MoE (8 experts, top-2, no shared).  8 experts < 16-way model
axis => experts replicate across "model" and the 32768 expert width shards
instead (spec_for handles the fallback); this is the memory-pressure cell of
the fleet and the default FSDP-sharding stress test.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    vocab=131_072,
    d_model=6144,
    n_layers=64,
    n_heads=48,
    n_kv=8,
    d_ff=32768,
    mlp="geglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768, n_shared=0,
                  capacity_factor=1.25, group_size=512),
    moe_layers=tuple(range(64)),
    rope_theta=10_000.0,
    attn_softcap=30.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    embed_scale=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    vocab=512,
    d_model=64,
    n_layers=2,
    n_heads=8,
    n_kv=2,
    d_ff=256,
    mlp="geglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=2.0,
                  group_size=64),
    moe_layers=(0, 1),
    attn_softcap=30.0,
    logit_softcap=30.0,
    embed_scale=True,
    dtype=jnp.float32,
)

LONG_CONTEXT_OK = False  # pure full attention
IS_DECODER = True
