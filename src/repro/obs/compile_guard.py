"""Compile-count accounting: (re)trace events as a tracked metric.

XLA recompiles are the silent performance killer in a jit-heavy serving
system — a shape that varies per fleet round turns the compile cache into a
treadmill.  The host tier pinned this with an ad-hoc ``serve_trace_count``
probe (PR 3); this module is that probe generalized for every component:

* traced function bodies call :func:`compile_event` — Python in a jitted
  function runs only at trace time, so the counter increments exactly once
  per distinct compiled shape;
* :func:`compile_count` reads per-component totals, and
  :func:`compile_guard` wraps a block and RAISES
  :class:`CompileBudgetError` when the block traced more shapes than its
  budget — compiled-shape budgets become regression-testable instead of
  folklore (the fleet engines and the host serve path are pinned at <= 2
  shapes under churny traces by ``tests/test_obs.py``).

An optional hashable ``key`` (a config dataclass, a shape tuple) splits a
component's count, mirroring the host probe's per-config accounting.
"""
from __future__ import annotations

import collections
import contextlib
from typing import Hashable

__all__ = ["compile_event", "compile_count", "compile_counts",
           "compile_key_counts", "reset_compile_counts", "compile_guard",
           "CompileBudgetError"]

_COUNTS: collections.Counter = collections.Counter()


class CompileBudgetError(RuntimeError):
    """A block compiled more distinct shapes than its declared budget."""


def compile_event(component: str, key: Hashable = None) -> None:
    """Count one (re)trace of ``component``.  Call from INSIDE the traced
    function body (runs at trace time only, never per step)."""
    _COUNTS[(component, key)] += 1


def compile_count(component: str | None = None,
                  key: Hashable = None) -> int:
    """Trace events so far: for one ``(component, key)``, for every key of a
    ``component``, or the global total."""
    if component is None:
        return sum(_COUNTS.values())
    if key is not None:
        return _COUNTS[(component, key)]
    return sum(n for (c, _), n in _COUNTS.items() if c == component)


def compile_key_counts(component: str) -> dict:
    """``{key: trace events}`` for one component — lets a caller group keys
    its own way (e.g. the host probe's ``batches_per_slot``-normalized
    per-config accounting)."""
    return {k: n for (c, k), n in _COUNTS.items() if c == component}


def compile_counts() -> dict[str, int]:
    """Per-component totals (the ``--emit-metrics`` dump's compile section)."""
    out: dict[str, int] = {}
    for (c, _), n in _COUNTS.items():
        out[c] = out.get(c, 0) + n
    return dict(sorted(out.items()))


def reset_compile_counts() -> None:
    _COUNTS.clear()


@contextlib.contextmanager
def compile_guard(component: str, budget: int):
    """Assert the wrapped block stays within its compiled-shape budget.

    ``with compile_guard("fleet.run", 2): ...`` raises
    :class:`CompileBudgetError` if more than ``budget`` new trace events for
    ``component`` occur inside the block.
    """
    before = compile_count(component)
    yield
    grew = compile_count(component) - before
    if grew > budget:
        raise CompileBudgetError(
            f"{component} compiled {grew} distinct shapes inside a "
            f"compile_guard budget of {budget} — a shape that varies per "
            f"call is defeating the compile cache")
