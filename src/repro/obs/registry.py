"""Metrics registry: declared-once telemetry lanes on a jit-friendly pytree.

Seeker's headline claims are *measurements* — communication volume,
completion fraction, QoS satisfaction under harvested-energy churn — so the
engines need one substrate every counter flows through instead of ad-hoc
aggregate dicts per engine.  This module is that substrate:

* a :class:`MetricsSpec` declares named lanes ONCE (counter, gauge, or
  fixed-bin histogram).  The spec is a frozen, hashable dataclass, so it can
  key the engines' compile caches and ride ``lru_cache`` builders;
* :func:`metrics_init` materializes the spec as a flat ``{name: array}``
  pytree that rides a ``lax.scan`` carry (the fleet engines) or a server
  state (the host tier).  Every update op is pure fixed-shape jnp;
* **exactness is the contract**: counters are (2,) int32 ``[hi, lo]``
  base-2**16 digit pairs (the PR-5 idiom — float32 sums lose bytes past
  2**24, int64 is off by default), histogram counts and gauges are int32.
  Integer adds are associative, so lanes are *bitwise-equal* across
  single-device, sharded (``psum`` component-wise via
  :func:`metrics_psum`), and streamed (:func:`metrics_merge` across
  segments) execution — observation never depends on layout;
* histograms are **fixed-bin**: log-spaced edges for latency-style values
  (percentile extraction via :func:`percentile_from_hist` on the host side)
  or categorical integer bins (decision codes).  Bin edges are static
  functions of the spec, never of the data, so recording stays jit-stable.

The fleet engines build their spec in
:func:`repro.serving.fleet.fleet_telemetry_spec`; the host tier in
:func:`repro.host.server.host_telemetry_spec` — this module knows nothing
about either (obs is a leaf dependency).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Lane", "MetricsSpec", "counter", "gauge", "histogram",
           "metrics_init", "counter_add", "gauge_set", "hist_observe",
           "metrics_psum", "metrics_merge", "counter_value", "int_pair_total",
           "int_pair_sum", "categorical_counts", "lane_edges",
           "percentile_from_hist", "metrics_summary", "spec_union"]

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"

# int32-pair digit base: per-slot/per-shard lo digits stay < 2**31 for fleets
# up to 32767 nodes (the same bound as PR 5's wire-byte pair)
_DIGIT = 16
_MASK = (1 << _DIGIT) - 1


@dataclasses.dataclass(frozen=True)
class Lane:
    """One declared metric lane (hashable; lives inside a MetricsSpec).

    ``kind``: ``"counter"`` — monotone exact int total, stored as a
    normalized (2,) int32 ``[hi, lo]`` base-2**16 pair; ``"gauge"`` — an
    int32 level re-set each slot (summed across shards, latest-wins across
    segments); ``"histogram"`` — (bins,) int32 counts over fixed edges:
    log-spaced over ``(lo, hi)`` when ``log`` (latency lanes), else
    categorical integer bins ``0..bins-1`` (decision codes), with the last
    bin catching overflow either way."""

    name: str
    kind: str
    unit: str = ""
    bins: int = 0
    lo: float = 1.0
    hi: float = 1024.0
    log: bool = True

    def __post_init__(self):
        if self.kind not in (COUNTER, GAUGE, HISTOGRAM):
            raise ValueError(f"unknown lane kind {self.kind!r}")
        if self.kind == HISTOGRAM:
            if self.bins < 2:
                raise ValueError(
                    f"histogram lane {self.name!r} needs >= 2 bins")
            if self.log and not 0 < self.lo < self.hi:
                raise ValueError(
                    f"histogram lane {self.name!r} needs 0 < lo < hi for "
                    f"log-spaced edges, got ({self.lo}, {self.hi})")


def counter(name: str, unit: str = "") -> Lane:
    return Lane(name, COUNTER, unit)


def gauge(name: str, unit: str = "") -> Lane:
    return Lane(name, GAUGE, unit)


def histogram(name: str, bins: int, lo: float = 1.0, hi: float = 1024.0,
              unit: str = "", log: bool = True) -> Lane:
    return Lane(name, HISTOGRAM, unit, bins=bins, lo=lo, hi=hi, log=log)


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """The declared lane set.  Frozen + hashable: one spec instance keys one
    compiled engine variant, exactly like ``BrownoutConfig`` et al."""

    lanes: tuple[Lane, ...]

    def __post_init__(self):
        names = [ln.name for ln in self.lanes]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"duplicate lane names: {sorted(dupes)}")

    def lane(self, name: str) -> Lane:
        for ln in self.lanes:
            if ln.name == name:
                return ln
        raise KeyError(
            f"no lane {name!r} declared; spec has "
            f"{[ln.name for ln in self.lanes]}")

    def names(self) -> tuple[str, ...]:
        return tuple(ln.name for ln in self.lanes)


def spec_union(*lane_groups) -> MetricsSpec:
    """A :class:`MetricsSpec` from several lane groups (tuples of
    :class:`Lane` or whole :class:`MetricsSpec` s), concatenated in order.
    This is how registries composed of per-subsystem lane OWNERS build one
    spec (the fleet engines union each carry lane's declared telemetry);
    duplicate names across groups fail the spec's own post-init check —
    two owners cannot silently claim one lane."""
    lanes: list[Lane] = []
    for group in lane_groups:
        if isinstance(group, MetricsSpec):
            lanes.extend(group.lanes)
        else:
            lanes.extend(group)
    return MetricsSpec(tuple(lanes))


@functools.lru_cache(maxsize=256)
def lane_edges(lane: Lane) -> tuple[float, ...]:
    """The ``bins - 1`` static bin edges of a histogram lane.  A value lands
    in bin ``sum(v > edges)``: log lanes put ``v <= lo`` in bin 0 and
    ``v > hi`` in the overflow bin; categorical lanes map integer ``k`` to
    bin ``k`` (clipped into the last bin)."""
    if lane.kind != HISTOGRAM:
        raise ValueError(f"{lane.name!r} is not a histogram lane")
    if lane.log:
        return tuple(float(e) for e in
                     np.geomspace(lane.lo, lane.hi, lane.bins - 1))
    return tuple(float(k) + 0.5 for k in range(lane.bins - 1))


def metrics_init(spec: MetricsSpec) -> dict:
    """The zeroed metrics pytree: ``{lane name: int32 array}`` — counters
    (2,), gauges (), histograms (bins,)."""
    out = {}
    for ln in spec.lanes:
        if ln.kind == COUNTER:
            out[ln.name] = jnp.zeros((2,), jnp.int32)
        elif ln.kind == GAUGE:
            out[ln.name] = jnp.zeros((), jnp.int32)
        else:
            out[ln.name] = jnp.zeros((ln.bins,), jnp.int32)
    return out


def _norm_pair(pair: jnp.ndarray) -> jnp.ndarray:
    """Canonical ``[hi, lo]``: carry lo's overflow digits into hi.  The
    canonical form (``lo < 2**16``) is unique for a given total, which is
    what makes counter pairs bitwise-comparable across layouts."""
    return jnp.stack([pair[0] + (pair[1] >> _DIGIT), pair[1] & _MASK])


def int_pair_sum(values: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Exact masked sum of non-negative int values as an UNNORMALIZED (2,)
    int32 ``[hi, lo]`` digit pair: each value is split into base-2**16
    digits *before* the reduction, so both digit sums stay exact in int32
    for up to 32767 terms of < 2**31 each (the PR-5 wire-byte idiom,
    generalized).  Combine with :func:`int_pair_total` or feed
    :func:`counter_add`."""
    v = jnp.asarray(values)
    if v.dtype == bool:
        v = v.astype(jnp.int32)
    elif jnp.issubdtype(v.dtype, jnp.floating):
        v = jnp.round(v).astype(jnp.int32)
    else:
        v = v.astype(jnp.int32)
    if mask is not None:
        v = jnp.where(mask, v, 0)
    return jnp.stack([jnp.sum(v >> _DIGIT),
                      jnp.sum(v & _MASK)]).astype(jnp.int32)


def int_pair_total(pair) -> int:
    """Combine a (2,) ``[hi, lo]`` pair into the exact arbitrary-precision
    Python int it represents (host side)."""
    hi, lo = (int(x) for x in np.asarray(pair))
    return (hi << _DIGIT) + lo


def counter_add(spec: MetricsSpec, metrics: dict, name: str,
                values: jnp.ndarray,
                mask: jnp.ndarray | None = None) -> dict:
    """Add a masked batch of non-negative values to a counter lane, exactly.
    ``values`` may be any shape (bool counts as 0/1, floats are rounded —
    whole-byte payload lanes); the pair stays normalized after every add."""
    if spec.lane(name).kind != COUNTER:
        raise ValueError(f"{name!r} is not a counter lane")
    pair = metrics[name] + int_pair_sum(values, mask)
    return {**metrics, name: _norm_pair(pair)}


def gauge_set(spec: MetricsSpec, metrics: dict, name: str,
              value: jnp.ndarray) -> dict:
    """Overwrite a gauge lane with this slot's level (() int32)."""
    if spec.lane(name).kind != GAUGE:
        raise ValueError(f"{name!r} is not a gauge lane")
    return {**metrics, name: jnp.asarray(value).astype(jnp.int32)}


def hist_observe(spec: MetricsSpec, metrics: dict, name: str,
                 values: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> dict:
    """Record a masked batch of values into a histogram lane's fixed bins.
    Bin index is ``sum(v > edges)`` over the lane's static edges; counts are
    int32 scatter-adds (exact, order-independent)."""
    ln = spec.lane(name)
    if ln.kind != HISTOGRAM:
        raise ValueError(f"{name!r} is not a histogram lane")
    edges = jnp.asarray(lane_edges(ln), jnp.float32)
    v = jnp.asarray(values).astype(jnp.float32).reshape(-1)
    idx = jnp.sum(v[:, None] > edges[None, :], axis=-1)
    m = (jnp.ones(v.shape, jnp.int32) if mask is None
         else jnp.asarray(mask).reshape(-1).astype(jnp.int32))
    counts = jnp.zeros((ln.bins,), jnp.int32).at[idx].add(m)
    return {**metrics, name: metrics[name] + counts}


def categorical_counts(values: jnp.ndarray, bins: int,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """(bins,) int32 masked counts of integer codes — the decision-histogram
    primitive shared by the engines' post-scan aggregates and the registry's
    categorical lanes (one implementation, two views)."""
    oh = jax.nn.one_hot(values, bins, dtype=jnp.int32)
    if mask is not None:
        oh = oh * jnp.asarray(mask)[..., None].astype(jnp.int32)
    return jnp.sum(oh, axis=tuple(range(oh.ndim - 1)))


def metrics_psum(spec: MetricsSpec, metrics: dict, axis_names) -> dict:
    """Component-wise ``psum`` of every lane across shards, counters
    re-normalized afterwards (per-shard pairs are canonical, so their digit
    sums stay exact for any realistic shard count)."""
    out = {}
    for ln in spec.lanes:
        summed = jax.lax.psum(metrics[ln.name], axis_names)
        out[ln.name] = _norm_pair(summed) if ln.kind == COUNTER else summed
    return out


def metrics_merge(spec: MetricsSpec, a: dict | None, b: dict) -> dict:
    """Combine two lane pytrees: counters add exactly (re-normalized),
    histograms add, gauges take ``b``'s level (the later segment).  This is
    the streamed driver's resume rule — merging per-segment metrics is
    bitwise-equal to one long run."""
    if a is None:
        return b
    out = {}
    for ln in spec.lanes:
        if ln.kind == COUNTER:
            out[ln.name] = _norm_pair(a[ln.name] + b[ln.name])
        elif ln.kind == GAUGE:
            out[ln.name] = b[ln.name]
        else:
            out[ln.name] = a[ln.name] + b[ln.name]
    return out


def counter_value(metrics: dict, name: str) -> int:
    """Host-side exact value of a counter lane."""
    return int_pair_total(metrics[name])


def percentile_from_hist(counts, edges, q: float) -> float:
    """Host-side percentile (``q`` in [0, 100]) from fixed-bin counts.

    Finds the bin where the cumulative count crosses ``q% `` of the total and
    interpolates linearly inside it (bin 0 spans ``[0, edges[0]]``; the
    overflow bin reports its lower edge — the histogram cannot resolve
    beyond its top edge, and the conservative answer is "at least hi").
    Returns ``nan`` on an empty histogram."""
    counts = np.asarray(counts, dtype=np.int64)
    edges = np.asarray(edges, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return float("nan")
    target = max(q / 100.0 * total, 1e-12)
    cum = np.cumsum(counts)
    idx = int(np.searchsorted(cum, target, side="left"))
    if idx >= len(edges):                       # overflow bin
        return float(edges[-1])
    lo = 0.0 if idx == 0 else float(edges[idx - 1])
    hi = float(edges[idx])
    inside = target - (0 if idx == 0 else cum[idx - 1])
    frac = inside / max(counts[idx], 1)
    return lo + (hi - lo) * min(frac, 1.0)


def metrics_summary(spec: MetricsSpec, metrics: dict) -> dict:
    """Host-side human/JSON view: counters as exact ints, gauges as ints,
    histograms as ``{counts, edges, p50, p95, p99}``."""
    out = {}
    for ln in spec.lanes:
        if ln.kind == COUNTER:
            out[ln.name] = counter_value(metrics, ln.name)
        elif ln.kind == GAUGE:
            out[ln.name] = int(metrics[ln.name])
        else:
            counts = np.asarray(metrics[ln.name]).tolist()
            edges = list(lane_edges(ln))
            out[ln.name] = {
                "counts": counts, "edges": edges, "unit": ln.unit,
                "p50": percentile_from_hist(counts, edges, 50.0),
                "p95": percentile_from_hist(counts, edges, 95.0),
                "p99": percentile_from_hist(counts, edges, 99.0),
            }
    return out
