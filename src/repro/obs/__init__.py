"""Fleet-wide observability: metrics registry, span tracer, compile guard.

One telemetry substrate for every engine and the host tier (ISSUE 8):

* :mod:`repro.obs.registry` — declared-once counter/gauge/histogram lanes on
  a jit-friendly pytree; exact int accounting, psum-able, resume-exact;
* :mod:`repro.obs.trace` — wall-clock spans with the ``block_until_ready``
  flush idiom, exported as Chrome-trace/Perfetto JSON;
* :mod:`repro.obs.compile_guard` — (re)trace events as a tracked,
  budget-guarded metric (the generalized ``serve_trace_count`` probe).
"""
from . import trace  # noqa: F401
from .compile_guard import (  # noqa: F401
    CompileBudgetError, compile_count, compile_counts, compile_event,
    compile_guard, compile_key_counts, reset_compile_counts,
)
from .registry import (  # noqa: F401
    Lane, MetricsSpec, categorical_counts, counter, counter_add,
    counter_value, gauge, gauge_set, hist_observe, histogram, int_pair_sum,
    int_pair_total, lane_edges, metrics_init, metrics_merge, metrics_psum,
    metrics_summary, percentile_from_hist, spec_union,
)
