"""Wall-clock span tracer with Chrome-trace/Perfetto JSON export.

The registry (:mod:`repro.obs.registry`) measures *what the simulation did*;
this module measures *where the wall-clock went* — edge-scan segments, host
microbatches, jit (re)traces, benchmark phases.  Spans are recorded with
:func:`span`, a context manager that can **flush async dispatch** before
stamping the end time: jax returns futures, so a naive ``perf_counter``
around a jitted call times the dispatch, not the work.  Pass the result
arrays (or a callable producing them) as ``flush=`` and the span blocks via
``jax.block_until_ready`` before closing — the honest-timing idiom the
benchmarks already use, made structural.

Tracing is **off by default** and the disabled path does nothing at all (no
clock reads, no flush), so instrumented library code — the streamed fleet
driver, the host serve loop — is perturbation-free unless a tool opts in
with :func:`enable`.

Export (:func:`export_chrome_trace`) writes the Chrome trace-event JSON
format (``{"traceEvents": [...]}``, ``ph: "X"`` complete events in µs),
loadable directly in Perfetto / ``chrome://tracing``; CI uploads the file
per PR next to the BENCH artifacts.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

import jax

__all__ = ["enable", "enabled", "clear", "span", "instant", "events",
           "export_chrome_trace"]

_LOCK = threading.Lock()
_ENABLED = False
_EVENTS: list[dict] = []
_T0_NS = time.perf_counter_ns()


def enable(on: bool = True) -> None:
    """Globally switch span recording on/off (off = zero-overhead no-ops)."""
    global _ENABLED
    _ENABLED = on


def enabled() -> bool:
    return _ENABLED


def clear() -> None:
    """Drop all recorded events (the buffer is process-global)."""
    with _LOCK:
        _EVENTS.clear()


def _now_us() -> float:
    return (time.perf_counter_ns() - _T0_NS) / 1e3


def _record(ev: dict) -> None:
    with _LOCK:
        _EVENTS.append(ev)


@contextlib.contextmanager
def span(name: str, cat: str = "repro", args: dict | None = None,
         flush=None):
    """Record a wall-clock span around a block.

    ``flush``: jax arrays (any pytree) or a zero-arg callable returning
    them — ``jax.block_until_ready`` runs on them before the end timestamp,
    so asynchronously-dispatched device work is *inside* the span instead of
    leaking into whatever is timed next.  When tracing is disabled the body
    runs untouched: no clock, no flush, no event.
    """
    if not _ENABLED:
        yield
        return
    t0 = _now_us()
    try:
        yield
    finally:
        if flush is not None:
            jax.block_until_ready(flush() if callable(flush) else flush)
        _record({"name": name, "cat": cat, "ph": "X", "ts": t0,
                 "dur": _now_us() - t0, "pid": os.getpid(),
                 "tid": threading.get_ident(),
                 **({"args": args} if args else {})})


def instant(name: str, cat: str = "repro",
            args: dict | None = None) -> None:
    """Record a zero-duration instant event (e.g. a jit retrace — called
    from traced-function bodies, which only run at trace time)."""
    if not _ENABLED:
        return
    _record({"name": name, "cat": cat, "ph": "i", "s": "p",
             "ts": _now_us(), "pid": os.getpid(),
             "tid": threading.get_ident(),
             **({"args": args} if args else {})})


def events() -> list[dict]:
    """Snapshot of the recorded events (copies; safe to mutate)."""
    with _LOCK:
        return [dict(e) for e in _EVENTS]


def export_chrome_trace(path: str) -> int:
    """Write the recorded events as Chrome-trace JSON (Perfetto-loadable);
    returns the number of events written."""
    evs = events()
    with open(path, "w") as f:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
    return len(evs)
