"""Fixed-capacity, jit-friendly payload queue for the host tier.

The host ingests wire-format coreset payloads from an intermittently-powered
fleet: arrivals are bursty (nodes wake when their supercapacitor allows,
Gobieski et al.), and every payload carries a QoS deadline — the slot by
which the host must have answered for the result to still matter (Seeker's
host-side latency bound).  This module is the buffering layer between the
radio and the scheduler:

* **ring-buffer storage** — a static-capacity slot array with a wrapping
  write cursor; every operation is pure jnp on fixed shapes, so pushes and
  pops trace once and live inside the host's jitted serve step;
* **payload-agnostic** — the queue stores an arbitrary pytree of per-entry
  arrays (the host server uses :class:`repro.host.server.HostPayload`), so
  the same buffer works for cluster payloads, sampling payloads, or both;
* **EDF-consistent overflow** — a push into a full queue discards the
  *latest-deadline* entry (incoming or resident, whichever can wait least
  usefully) and increments ``drops_overflow``, so pressure never evicts work
  the scheduler would have run first.

Deadline *expiry* (entries whose deadline has passed) is the scheduler's
concern — see :func:`repro.host.scheduler.expire_deadlines`.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PayloadQueue", "queue_init", "queue_push", "queue_push_batch",
           "queue_occupancy", "NO_DEADLINE"]

# deadline key for empty slots: sorts after every real deadline
NO_DEADLINE = jnp.iinfo(jnp.int32).max


class PayloadQueue(NamedTuple):
    """Slot-array queue; every leaf has leading ``capacity`` axis."""

    payload: Any               # pytree of (cap, ...) arrays
    node_id: jnp.ndarray       # (cap,) int32 — originating fleet node
    arrival: jnp.ndarray       # (cap,) int32 — slot the payload arrived
    deadline: jnp.ndarray      # (cap,) int32 — QoS deadline slot (inclusive)
    valid: jnp.ndarray         # (cap,) bool
    cursor: jnp.ndarray        # () int32 — ring write cursor
    drops_overflow: jnp.ndarray  # () int32 — payloads discarded by overflow


def queue_init(example_payload: Any, capacity: int) -> PayloadQueue:
    """Empty queue whose payload slots mirror ``example_payload`` (one
    UNBATCHED entry pytree; each leaf gains a leading capacity axis)."""
    payload = jax.tree_util.tree_map(
        lambda a: jnp.zeros((capacity,) + jnp.shape(a), jnp.asarray(a).dtype),
        example_payload)
    return PayloadQueue(
        payload=payload,
        node_id=jnp.zeros((capacity,), jnp.int32),
        arrival=jnp.zeros((capacity,), jnp.int32),
        deadline=jnp.full((capacity,), NO_DEADLINE, jnp.int32),
        valid=jnp.zeros((capacity,), bool),
        cursor=jnp.zeros((), jnp.int32),
        drops_overflow=jnp.zeros((), jnp.int32))


def queue_occupancy(q: PayloadQueue) -> jnp.ndarray:
    """() int32 — number of live entries."""
    return jnp.sum(q.valid.astype(jnp.int32))


def queue_wait_slots(q: PayloadQueue, now: jnp.ndarray) -> jnp.ndarray:
    """(cap,) int32 — how long each entry has been waiting at slot ``now``
    (0 for entries that arrived this slot; garbage where ``q.valid`` is
    False — mask with it).  The backlog-age observable of the telemetry
    lanes."""
    return jnp.where(q.valid, now - q.arrival, 0).astype(jnp.int32)


def queue_push(q: PayloadQueue, payload: Any, node_id: jnp.ndarray,
               arrival: jnp.ndarray, deadline: jnp.ndarray,
               mask: jnp.ndarray | bool = True
               ) -> tuple[PayloadQueue, jnp.ndarray]:
    """Insert one entry; returns ``(queue, dropped)``.

    The entry lands in the first free slot at/after the ring cursor.  When
    the queue is full, the latest-deadline entry loses: an incoming payload
    with an earlier deadline evicts the worst resident; otherwise the
    incoming payload itself is discarded.  Either way exactly one payload is
    dropped and ``drops_overflow`` increments.  ``mask=False`` makes the push
    a no-op (inert padding rows in a batched ingest).
    """
    cap = q.valid.shape[0]
    mask = jnp.asarray(mask, bool)
    deadline = jnp.asarray(deadline, jnp.int32)

    # first free slot in ring order from the cursor (cap == "no free slot")
    ring_order = (jnp.arange(cap, dtype=jnp.int32) - q.cursor) % cap
    free_order = jnp.where(q.valid, cap, ring_order)
    free_slot = jnp.argmin(free_order).astype(jnp.int32)
    has_free = jnp.any(~q.valid)

    # overflow: victim = resident with the latest deadline (ties: lowest
    # slot); an incoming deadline >= the victim's keeps the resident
    victim = jnp.argmax(jnp.where(q.valid, q.deadline, -1)).astype(jnp.int32)
    evict = q.deadline[victim] > deadline

    write = mask & (has_free | evict)
    widx = jnp.where(has_free, free_slot, victim)

    def put(buf, val):
        row = jnp.where(write, jnp.asarray(val, buf.dtype), buf[widx])
        return buf.at[widx].set(row)

    dropped = mask & ~has_free
    return PayloadQueue(
        payload=jax.tree_util.tree_map(put, q.payload, payload),
        node_id=put(q.node_id, node_id),
        arrival=put(q.arrival, arrival),
        deadline=put(q.deadline, deadline),
        valid=q.valid.at[widx].set(jnp.where(write, True, q.valid[widx])),
        cursor=jnp.where(write, (widx + 1) % cap, q.cursor),
        drops_overflow=q.drops_overflow + dropped.astype(jnp.int32),
    ), dropped


def _bulk_insert(q: PayloadQueue, payloads: Any, node_ids: jnp.ndarray,
                 arrivals: jnp.ndarray, deadlines: jnp.ndarray,
                 mask: jnp.ndarray) -> tuple[PayloadQueue, jnp.ndarray]:
    """No-overflow fast path: the i-th masked entry lands in the i-th free
    slot in ring order — one vectorized scatter per leaf instead of A
    sequential pushes.  Bitwise-equal (slots, cursor) to the sequential path
    whenever every masked entry fits."""
    cap = q.valid.shape[0]
    ring_order = (jnp.arange(cap, dtype=jnp.int32) - q.cursor) % cap
    # free slots first, in ring order (stable sort; occupied pushed to back)
    slot_rank = jnp.argsort(jnp.where(q.valid, cap + ring_order, ring_order))
    entry_rank = jnp.cumsum(mask.astype(jnp.int32)) - 1        # (A,)
    # masked-out rows scatter out of bounds -> dropped by mode="drop"
    target = jnp.where(mask, slot_rank[jnp.clip(entry_rank, 0, cap - 1)],
                       cap)

    def put(buf, vals):
        return buf.at[target].set(vals.astype(buf.dtype), mode="drop")

    n_pushed = entry_rank[-1] + 1
    last = target[jnp.argmax(jnp.where(mask, jnp.arange(mask.shape[0]), -1))]
    return PayloadQueue(
        payload=jax.tree_util.tree_map(put, q.payload, payloads),
        node_id=put(q.node_id, node_ids),
        arrival=put(q.arrival, arrivals),
        deadline=put(q.deadline, deadlines.astype(jnp.int32)),
        valid=q.valid.at[target].set(True, mode="drop"),
        cursor=jnp.where(n_pushed > 0, (last + 1) % cap, q.cursor),
        drops_overflow=q.drops_overflow,
    ), jnp.zeros((), jnp.int32)


def queue_push_batch(q: PayloadQueue, payloads: Any, node_ids: jnp.ndarray,
                     arrivals: jnp.ndarray, deadlines: jnp.ndarray,
                     mask: jnp.ndarray
                     ) -> tuple[PayloadQueue, jnp.ndarray]:
    """Push ``A`` stamped entries (leaves have leading axis A) in order;
    returns ``(queue, n_dropped)``.  Rows with ``mask=False`` are skipped —
    the fixed-width ingest lane of a churny fleet slot.

    When every masked entry fits in the free slots (the common serving case)
    a single vectorized scatter does the whole insert; only a lane that
    might overflow falls back to the sequential per-entry walk with its
    latest-deadline drop policy.  Both paths leave identical queues when no
    overflow occurs.
    """
    mask = jnp.asarray(mask, bool)

    def body(carry, inp):
        payload, nid, arr, dl, m = inp
        qq, dropped = queue_push(carry, payload, nid, arr, dl, m)
        return qq, dropped

    def sequential(args):
        qq, pl, nid, arr, dl, m = args
        qq, dropped = jax.lax.scan(body, qq, (pl, nid, arr, dl, m))
        return qq, jnp.sum(dropped.astype(jnp.int32))

    n_free = jnp.sum((~q.valid).astype(jnp.int32))
    n_in = jnp.sum(mask.astype(jnp.int32))
    return jax.lax.cond(n_in <= n_free,
                        lambda a: _bulk_insert(*a),
                        sequential,
                        (q, payloads, node_ids, arrivals, deadlines, mask))
