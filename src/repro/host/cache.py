"""Signature-keyed recovery cache — the paper's D0 memoization, host side.

On the node, D0 skips inference when a window correlates with a stored
signature.  The host sees the same repetition one hop later: periodic
activities make nodes re-transmit *byte-identical* quantized payloads, and
recovering + re-inferring them wastes exactly the work D0 saves on the node.
This cache closes the loop: each payload is keyed by a 64-bit hash of its
quantized code tensors (two independent 32-bit mixes — the codes are already
integers, so equal payloads hash equal and the lookup is exact-match), and a
hit returns the *bitwise-cached* logits.

Bitwise equivalence with recomputation holds because the host server derives
each payload's recovery PRNG key from this same signature
(:func:`jax.random.fold_in`), so recomputing a payload reproduces the cached
logits bit for bit — the cache is a pure memo, never an approximation.

Eviction is FIFO via a ring cursor; all operations are fixed-shape jnp so
lookups and inserts run inside the jitted serve slot.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["RecoveryCache", "cache_init", "cache_stats",
           "payload_signature", "cache_lookup_batch",
           "cache_insert_batch"]

# Knuth/FNV-flavoured odd constants for the two independent 32-bit mixes
_MIX_SEEDS = (jnp.uint32(2654435761), jnp.uint32(2246822519))


class RecoveryCache(NamedTuple):
    sig: jnp.ndarray       # (cap, 2) uint32 — 64-bit payload signature
    logits: jnp.ndarray    # (cap, L) float32 — memoized host logits
    valid: jnp.ndarray     # (cap,) bool
    cursor: jnp.ndarray    # () int32 — FIFO insert position
    hits: jnp.ndarray      # () int32
    misses: jnp.ndarray    # () int32


def cache_init(capacity: int, n_classes: int) -> RecoveryCache:
    return RecoveryCache(
        sig=jnp.zeros((capacity, 2), jnp.uint32),
        logits=jnp.zeros((capacity, n_classes), jnp.float32),
        valid=jnp.zeros((capacity,), bool),
        cursor=jnp.zeros((), jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        misses=jnp.zeros((), jnp.int32))


def _leaf_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Flatten a payload leaf to uint32 words, bit-exactly: float leaves are
    bitcast (so -0.0 != 0.0 is preserved), integer leaves two's-complement
    wrapped."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(
            x.astype(jnp.float32), jnp.uint32).reshape(-1)
    return x.astype(jnp.uint32).reshape(-1)


def _mix(words: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """One 32-bit hash of a uint32 word vector: xorshifted words times a
    per-position multiplier stream, wrap-summed, then avalanched.

    The multiplier stream is an *avalanched* (nonlinear) function of
    (position, seed) — NOT ``seed * f(position)`` — so the two seeds yield
    genuinely independent linear combinations of the words: a word delta
    that cancels one seed's sum does not cancel the other's, keeping the
    paired signature at ~64 collision bits rather than 32."""
    idx = jnp.arange(words.shape[0], dtype=jnp.uint32)
    mult = idx * jnp.uint32(2654435761) + seed
    mult = (mult ^ (mult >> 15)) * jnp.uint32(2246822519)
    mult = (mult ^ (mult >> 13)) | jnp.uint32(1)          # odd multipliers
    h = jnp.sum((words ^ (words >> 16)) * mult, dtype=jnp.uint32)
    h = (h ^ (h >> 15)) * jnp.uint32(2246822519)
    return h ^ (h >> 13)


def payload_signature(payload: Any) -> jnp.ndarray:
    """(2,) uint32 signature of ONE entry's payload pytree.  Equal payloads
    (bit-for-bit, including quantization ranges) get equal signatures; vmap
    over the leading axis for a batch."""
    words = jnp.concatenate(
        [_leaf_u32(leaf) for leaf in jax.tree_util.tree_leaves(payload)])
    return jnp.stack([_mix(words, s) for s in _MIX_SEEDS])


def cache_lookup_batch(cache: RecoveryCache, sigs: jnp.ndarray,
                       valid: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-match lookup of (B, 2) signatures; returns ``(hit (B,) bool,
    logits (B, L))`` — rows that miss carry unspecified logits (callers
    select on ``hit``).  Rows with ``valid=False`` never hit."""
    match = cache.valid[None, :] & jnp.all(
        sigs[:, None, :] == cache.sig[None, :, :], axis=-1)   # (B, cap)
    hit = jnp.any(match, axis=1) & valid
    idx = jnp.argmax(match, axis=1)
    return hit, cache.logits[idx]


def cache_insert_batch(cache: RecoveryCache, sigs: jnp.ndarray,
                       logits: jnp.ndarray, insert: jnp.ndarray
                       ) -> RecoveryCache:
    """FIFO-insert the rows with ``insert=True`` (typically ``valid & ~hit``)
    at the ring cursor.  Duplicate signatures within one batch insert twice —
    harmless: later lookups match the first copy."""
    cap = cache.valid.shape[0]

    def body(c, inp):
        sig, lg, ins = inp
        pos = c.cursor % cap
        return RecoveryCache(
            sig=c.sig.at[pos].set(jnp.where(ins, sig, c.sig[pos])),
            logits=c.logits.at[pos].set(jnp.where(ins, lg, c.logits[pos])),
            valid=c.valid.at[pos].set(jnp.where(ins, True, c.valid[pos])),
            cursor=c.cursor + ins.astype(jnp.int32),
            hits=c.hits, misses=c.misses), None

    cache, _ = jax.lax.scan(body, cache, (sigs, logits, insert))
    return cache


def cache_stats(cache: RecoveryCache) -> dict:
    """Hit/miss counters as python numbers (one sync; off the hot path) —
    the single accounting view shared by ``host_server_stats`` and the
    ``host.cache_*`` telemetry lanes."""
    hits, misses = int(cache.hits), int(cache.misses)
    return {"cache_hits": hits, "cache_misses": misses,
            "cache_hit_rate": hits / max(hits + misses, 1)}
