"""Earliest-deadline-first microbatch assembly for the host DNN.

The host's latency bound is per *payload* (QoS deadline slots), but its
throughput comes from *batching* the recovery + full-precision DNN.  The
scheduler reconciles the two:

* **EDF order** — each pop takes the ``batch_size`` live entries with the
  earliest deadlines (stable tie-break on slot index), so under pressure the
  work closest to its bound runs first;
* **fixed-shape batches** — ``batch_size`` is static, partial batches are
  padded rows with ``valid=False``, and every pop has the exact same tensor
  shapes regardless of fleet churn.  The host DNN therefore hits XLA's
  compile cache on every slot instead of recompiling per occupancy — the
  whole point of running a queue in front of the model;
* **explicit drop accounting** — entries whose deadline has passed are
  expired *before* assembly and counted (``deadline misses``), never
  silently served late; overflow drops are counted by the queue.

A deadline is *inclusive*: an entry popped at ``now == deadline`` is on
time; ``deadline < now`` is a miss.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .queue import NO_DEADLINE, PayloadQueue

__all__ = ["MicroBatch", "batch_task_counts", "batch_wait_slots",
           "expire_deadlines", "edf_pop_batch"]


class MicroBatch(NamedTuple):
    """A fixed-shape batch of queue entries (leading axis ``batch_size``).
    Padding rows (queue held fewer live entries) have ``valid=False``."""

    payload: Any               # pytree of (B, ...) rows
    node_id: jnp.ndarray       # (B,) int32
    arrival: jnp.ndarray       # (B,) int32
    deadline: jnp.ndarray      # (B,) int32
    valid: jnp.ndarray         # (B,) bool


def expire_deadlines(q: PayloadQueue, now: jnp.ndarray
                     ) -> tuple[PayloadQueue, jnp.ndarray]:
    """Invalidate entries whose deadline has passed (``deadline < now``);
    returns ``(queue, n_missed)`` — the deadline-miss accounting."""
    missed = q.valid & (q.deadline < now)
    return q._replace(valid=q.valid & ~missed), \
        jnp.sum(missed.astype(jnp.int32))


def edf_pop_batch(q: PayloadQueue, batch_size: int,
                  now: jnp.ndarray | None = None
                  ) -> tuple[PayloadQueue, MicroBatch, jnp.ndarray]:
    """Pop the ``batch_size`` earliest-deadline live entries as one
    fixed-shape :class:`MicroBatch`.

    With ``now`` given, already-late entries are expired (and counted) first,
    so a batch never contains a missed deadline.  Returns
    ``(queue, batch, n_missed)``.
    """
    missed = jnp.zeros((), jnp.int32)
    if now is not None:
        q, missed = expire_deadlines(q, now)

    keys = jnp.where(q.valid, q.deadline, NO_DEADLINE)
    order = jnp.argsort(keys)                 # stable: ties by slot index
    take = order[:batch_size]                 # distinct slots by construction
    taken_valid = q.valid[take]

    batch = MicroBatch(
        payload=jax.tree_util.tree_map(lambda a: a[take], q.payload),
        node_id=q.node_id[take],
        arrival=q.arrival[take],
        deadline=q.deadline[take],
        valid=taken_valid)
    return q._replace(valid=q.valid.at[take].set(False)), batch, missed


def batch_task_counts(batch: MicroBatch, n_tasks: int) -> jnp.ndarray:
    """(n_tasks,) int32 — how many valid rows of this microbatch belong to
    each workload (the mixed-fleet service observable: which task is drawing
    host capacity under EDF pressure).  Payloads without a ``task`` leaf
    count as task 0; exact integer sums, so per-slot counts accumulate and
    psum like every other counter."""
    task = getattr(batch.payload, "task", None)
    if task is None:
        task = jnp.zeros(batch.valid.shape, jnp.int32)
    oh = jax.nn.one_hot(jnp.clip(task.astype(jnp.int32), 0, n_tasks - 1),
                        n_tasks, dtype=jnp.int32)
    return jnp.sum(oh * batch.valid[:, None].astype(jnp.int32), axis=0)


def batch_wait_slots(batch: MicroBatch, now: jnp.ndarray) -> jnp.ndarray:
    """(B,) int32 queue sojourn of each batch row at service time ``now``
    (0 = served the slot it arrived; garbage on padding rows — mask with
    ``batch.valid``).  The QoS-percentile observable: its histogram is what
    p50/p95/p99 queue-wait is extracted from."""
    return jnp.where(batch.valid, now - batch.arrival, 0).astype(jnp.int32)
