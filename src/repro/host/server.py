"""The host serve loop: queue -> EDF scheduler -> batched recovery -> DNN.

This is the host half of the paper's system, as a real serving subsystem
instead of per-payload inline work: wire-format payloads from the fleet are
stamped with QoS deadlines and pushed into the :mod:`repro.host.queue` ring
buffer; each serve slot the :mod:`repro.host.scheduler` assembles one or
more **fixed-shape** EDF microbatches; the batch is decoded
(:func:`repro.serving.edge_host.decode_wire_coresets` /
``decode_wire_samples``), recovered (cluster-ball resynthesis or the
GAN generator, selected per entry), and run through the full-precision HAR
DNN in one batched ``har_apply``; per-node results accumulate into a mean-
logit ensemble and a majority-vote histogram (the paper's multi-sensor
host ensemble).

Design points:

* **compile-cache stability** — every tensor entering the jitted slot has a
  shape fixed by :class:`HostServeConfig` (batch size, queue capacity,
  ingest width), never by fleet occupancy, so a churny trace compiles the
  slot ONCE.  :func:`serve_trace_count` exposes the trace counter the tests
  pin (acceptance: <= 2 distinct compiled shapes over a churny trace).
* **payload-deterministic recovery PRNG** — each payload's recovery key is
  ``fold_in(base_key, signature)``, so identical payloads recover
  identically and the :mod:`repro.host.cache` memo is *bitwise* equal to
  recomputation.  A batch whose live entries all hit skips recovery + DNN
  entirely (``lax.cond``), mirroring D0's skip on the node.
* **resumable carry** — :class:`HostServerState` is an explicit pytree
  carry, exactly like the fleet engine's ``state0``/``final_state``:
  chaining ``host_serve_slot``/``host_serve_trace`` calls continues the
  clock, queue backlog, cache and ensemble where the last call stopped.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.coreset import ClusterCoreset, SamplingCoreset
from ..core.recovery import (GeneratorParams, recover_cluster_window,
                             recover_sampling_window)
from ..models.har import har_apply
from ..obs import (MetricsSpec, compile_event, counter, counter_add,
                   gauge, gauge_set, hist_observe, histogram, metrics_init,
                   metrics_summary)
from ..obs import trace as obs_trace
from ..obs.compile_guard import compile_key_counts
from ..serving.edge_host import (WirePayload, WireSamplePayload,
                                 decode_wire_coresets, decode_wire_samples)
from .cache import (RecoveryCache, cache_init, cache_insert_batch,
                    cache_lookup_batch, cache_stats, payload_signature)
from .queue import (PayloadQueue, queue_init, queue_occupancy,
                    queue_push_batch, queue_wait_slots)
from .scheduler import batch_wait_slots, edf_pop_batch

__all__ = ["HostServeConfig", "HostPayload", "HostServerState", "SlotOutput",
           "host_payload_example", "cluster_entries", "sampling_entries",
           "host_server_init", "host_server_init_stacked", "host_serve_slot",
           "host_serve_trace", "host_telemetry_spec", "serve_fleet_payloads",
           "recover_infer_batch", "host_server_stats", "host_ensemble",
           "serve_trace_count"]

CLUSTER_KIND = 0    # D3 payload: quantized cluster coreset
SAMPLING_KIND = 1   # D4 payload: quantized importance samples + moments


@dataclasses.dataclass(frozen=True)
class HostServeConfig:
    """Static shape/QoS configuration of one host server (hashable: it keys
    the compile cache — one executable per config, reused across slots)."""

    channels: int               # sensor channels C
    k: int                      # clusters per channel (cluster payloads)
    m: int                      # samples per window (sampling payloads)
    t: int                      # window length the host recovers to
    n_classes: int
    n_nodes: int                # fleet size for the per-node ensemble
    batch_size: int = 64        # EDF microbatch rows (fixed shape)
    queue_capacity: int = 256   # ring-buffer slots (>= ingest width per slot)
    cache_capacity: int = 256   # recovery-memo entries
    qos_slots: int = 4          # deadline = arrival + qos_slots (inclusive)
    batches_per_slot: int = 1   # host service rate per slot
    telemetry: bool = False     # registry lanes + latency histograms in-slot
    n_tasks: int = 1            # mixed fleets: stacked per-task host DNNs

    def __post_init__(self):
        """Reject configurations that would silently corrupt service.

        ``batch_size > queue_capacity`` is the nasty one: ``edf_pop_batch``
        takes ``order[:batch_size]`` over the capacity-long slot array, so
        the batch clamps to ``queue_capacity`` rows and the configured
        service rate is a lie — every slot quietly serves fewer payloads
        than the config promises.  (An ingest lane wider than the capacity
        is the call-time analogue: the lane can overflow every slot — see
        :func:`host_serve_slot`.)"""
        for field in ("channels", "k", "m", "t", "n_classes", "n_nodes",
                      "batch_size", "queue_capacity", "cache_capacity",
                      "n_tasks"):
            v = getattr(self, field)
            if v < 1:
                raise ValueError(
                    f"HostServeConfig.{field} must be >= 1, got {v}")
        # qos_slots=0 is serve-this-slot-or-miss; batches_per_slot=0 is the
        # normalized compile-probe key (serve_trace_count) — both legal
        for field in ("qos_slots", "batches_per_slot"):
            v = getattr(self, field)
            if v < 0:
                raise ValueError(
                    f"HostServeConfig.{field} must be >= 0, got {v}")
        if self.batch_size > self.queue_capacity:
            raise ValueError(
                f"HostServeConfig.batch_size={self.batch_size} exceeds "
                f"queue_capacity={self.queue_capacity}: edf_pop_batch can "
                f"only assemble queue_capacity rows, so the extra "
                f"{self.batch_size - self.queue_capacity} batch rows would "
                f"silently never be filled — raise queue_capacity or lower "
                f"batch_size")


class HostPayload(NamedTuple):
    """One queue entry's payload: the union of the two wire formats, with a
    ``kind`` discriminator (all branches traced, selection by mask — the
    repo-wide pattern for static shapes).  Unused half is zeros.

    ``task`` selects which workload's host DNN serves the entry in a
    mixed-task fleet (``HostServeConfig.n_tasks > 1``); homogeneous
    deployments leave it 0.  It is an ordinary payload leaf, so
    :func:`repro.host.cache.payload_signature` hashes it with everything
    else — the same coreset from a HAR node and a bearing node can never
    collide in the recovery memo."""

    kind: jnp.ndarray       # () int8 — CLUSTER_KIND | SAMPLING_KIND
    # D3: quantized cluster coreset (codes + dequantization ranges)
    c_codes: jnp.ndarray    # (C, k, 2) int16
    r_codes: jnp.ndarray    # (C, k) int8
    n_codes: jnp.ndarray    # (C, k) int8
    c_lo: jnp.ndarray       # () float32
    c_hi: jnp.ndarray       # () float32
    c_rhi: jnp.ndarray      # () float32
    # D4: quantized importance samples + GAN conditioning moments
    s_idx: jnp.ndarray      # (m,) int8
    s_codes: jnp.ndarray    # (m, C) int16
    s_lo: jnp.ndarray       # () float32
    s_hi: jnp.ndarray       # () float32
    s_mean: jnp.ndarray     # (C,) float32
    s_var: jnp.ndarray      # (C,) float32
    # heterogeneous fleets: which workload's DNN answers this entry
    task: jnp.ndarray       # () int8 — index into stacked per-task params


class SlotOutput(NamedTuple):
    """Per-slot served results: ``batches_per_slot * batch_size`` rows in
    EDF service order; padding rows have ``valid=False``."""

    node_id: jnp.ndarray    # (Bq,) int32
    logits: jnp.ndarray     # (Bq, L) float32
    deadline: jnp.ndarray   # (Bq,) int32
    cache_hit: jnp.ndarray  # (Bq,) bool
    valid: jnp.ndarray      # (Bq,) bool


class HostServerState(NamedTuple):
    """The resumable serve-loop carry (cf. the fleet engine's state0)."""

    queue: PayloadQueue
    cache: RecoveryCache
    slot: jnp.ndarray             # () int32 — host clock
    served: jnp.ndarray           # () int32 — payloads answered in time
    deadline_misses: jnp.ndarray  # () int32 — expired before service
    ensemble_logits: jnp.ndarray  # (n_nodes, L) float32 — summed logits
    ensemble_votes: jnp.ndarray   # (n_nodes, L) int32 — argmax histogram
    # registry lanes (cfg.telemetry=True; None = untelemetered, an empty
    # pytree node, so every legacy positional construction still works)
    metrics: Any = None


def host_payload_example(cfg: HostServeConfig) -> HostPayload:
    """Zero entry pytree defining the queue's slot shapes."""
    c, k, m = cfg.channels, cfg.k, cfg.m
    z = jnp.zeros
    return HostPayload(
        kind=z((), jnp.int8),
        c_codes=z((c, k, 2), jnp.int16), r_codes=z((c, k), jnp.int8),
        n_codes=z((c, k), jnp.int8), c_lo=z(()), c_hi=z(()), c_rhi=z(()),
        s_idx=z((m,), jnp.int8), s_codes=z((m, c), jnp.int16),
        s_lo=z(()), s_hi=z(()), s_mean=z((c,)), s_var=z((c,)),
        task=z((), jnp.int8))


def _entry_tasks(tasks, b: int) -> jnp.ndarray:
    """(B,) int8 task column for a batch of entries; ``None`` = task 0."""
    if tasks is None:
        return jnp.zeros((b,), jnp.int8)
    return jnp.asarray(tasks).reshape(b).astype(jnp.int8)


def cluster_entries(wire: WirePayload, m: int,
                    tasks: jnp.ndarray | None = None) -> HostPayload:
    """Batched D3 entries from a quantized cluster wire payload (the tensors
    :func:`repro.serving.edge_host.fleet_serve_step` gathers).  ``tasks`` is
    the optional (B,) per-entry task id of a mixed fleet."""
    b, c, _, _ = wire.c_codes.shape
    z = jnp.zeros
    return HostPayload(
        kind=z((b,), jnp.int8),
        c_codes=wire.c_codes, r_codes=wire.r_codes, n_codes=wire.n_codes,
        c_lo=wire.lo.reshape(b), c_hi=wire.hi.reshape(b),
        c_rhi=wire.rhi.reshape(b),
        s_idx=z((b, m), jnp.int8), s_codes=z((b, m, c), jnp.int16),
        s_lo=z((b,)), s_hi=z((b,)), s_mean=z((b, c)), s_var=z((b, c)),
        task=_entry_tasks(tasks, b))


def sampling_entries(swire: WireSamplePayload, k: int,
                     tasks: jnp.ndarray | None = None) -> HostPayload:
    """Batched D4 entries from a quantized sampling wire payload."""
    b, m = swire.idx.shape
    c = swire.v_codes.shape[-1]
    z = jnp.zeros
    return HostPayload(
        kind=jnp.full((b,), SAMPLING_KIND, jnp.int8),
        c_codes=z((b, c, k, 2), jnp.int16), r_codes=z((b, c, k), jnp.int8),
        n_codes=z((b, c, k), jnp.int8), c_lo=z((b,)), c_hi=z((b,)),
        c_rhi=z((b,)),
        s_idx=swire.idx, s_codes=swire.v_codes,
        s_lo=swire.lo.reshape(b), s_hi=swire.hi.reshape(b),
        s_mean=swire.mean, s_var=swire.var,
        task=_entry_tasks(tasks, b))


@functools.lru_cache(maxsize=32)
def _host_spec(qos_slots: int) -> MetricsSpec:
    # sojourn of a SERVED payload is 0..qos_slots (later pops expire first);
    # end-to-end latency (arrival -> result available) is sojourn + 1.  Small
    # deadline windows get exact per-slot categorical bins; large ones fall
    # back to 16 log-spaced bins over the feasible span.
    span = qos_slots + 1
    if span + 2 <= 18:
        lat = functools.partial(histogram, bins=span + 2, log=False,
                                unit="slots")
    else:
        lat = functools.partial(histogram, bins=16, lo=1.0, hi=float(span),
                                unit="slots")
    return MetricsSpec((
        counter("host.served", "payloads"),
        counter("host.deadline_misses", "payloads"),
        counter("host.drops_overflow", "payloads"),
        counter("host.cache_hits", "lookups"),
        counter("host.cache_misses", "lookups"),
        gauge("host.backlog", "payloads"),
        lat("host.sojourn_slots"),
        lat("host.e2e_slots"),
        lat("host.sojourn_slots.cluster"),
        lat("host.sojourn_slots.sampling"),
        lat("host.backlog_age_slots"),
    ))


def host_telemetry_spec(cfg: HostServeConfig) -> MetricsSpec:
    """The host tier's registry lanes: QoS counters (served / misses /
    drops / cache), a backlog gauge, and the fixed-bin latency histograms
    QoS percentiles are extracted from — per-payload queue sojourn,
    end-to-end slot latency, per-payload-class sojourn breakdown, and the
    age profile of the waiting backlog.  Pure function of ``cfg.qos_slots``
    (the only field that shapes the bins), so service-rate variants of one
    config share the spec instance."""
    return _host_spec(cfg.qos_slots)


def host_server_init(cfg: HostServeConfig) -> HostServerState:
    return HostServerState(
        queue=queue_init(host_payload_example(cfg), cfg.queue_capacity),
        cache=cache_init(cfg.cache_capacity, cfg.n_classes),
        slot=jnp.zeros((), jnp.int32),
        served=jnp.zeros((), jnp.int32),
        deadline_misses=jnp.zeros((), jnp.int32),
        ensemble_logits=jnp.zeros((cfg.n_nodes, cfg.n_classes), jnp.float32),
        ensemble_votes=jnp.zeros((cfg.n_nodes, cfg.n_classes), jnp.int32),
        metrics=(metrics_init(host_telemetry_spec(cfg)) if cfg.telemetry
                 else None))


def host_server_init_stacked(cfg: HostServeConfig,
                             n_hosts: int) -> HostServerState:
    """``n_hosts`` independent server states stacked on a leading axis —
    the carry of :func:`repro.serving.edge_host.fleet_serve_step`'s
    per-shard host mode (one host server per node shard, the ROADMAP
    multi-host shape on one process)."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    one = host_server_init(cfg)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_hosts,) + a.shape).copy(), one)


# ---------------------------------------------------------------------------
# Batched recovery + inference (the host DNN path)
# ---------------------------------------------------------------------------

def recover_infer_batch(payload: WirePayload, host_params: dict,
                        keys: jax.Array, t: int) -> jnp.ndarray:
    """Dequantize a cluster wire-payload batch, recover windows, run the
    full-precision DNN -> (B, n_classes) logits.  The batched host compute
    shared by the serving tiers (:func:`edge_host_serve_step`,
    :func:`fleet_serve_step`) and this server's miss path."""
    centers, radii, counts = decode_wire_coresets(payload)
    wins = jax.vmap(lambda c, r, n, kk: recover_cluster_window(
        ClusterCoreset(c, r, n), kk, t))(centers, radii, counts, keys)
    return har_apply(host_params, wins)


def _entry_windows(p: HostPayload, gen_params: GeneratorParams,
                   keys: jax.Array, t: int, valid: jnp.ndarray
                   ) -> jnp.ndarray:
    """Recover a (B, T, C) window batch from mixed-kind entries.

    Both recovery paths are traced, but a ``lax.switch`` on the batch's kind
    mix runs only what the batch needs at runtime: a single-kind microbatch
    (the common case — a fleet round is all-D3) skips the other recovery
    entirely; only genuinely mixed batches compute both and select per
    entry.  Rows with ``valid=False`` count as neither kind.
    """
    b = p.kind.shape[0]

    def cluster_windows(_):
        wire = WirePayload(p.c_codes, p.r_codes, p.n_codes,
                           p.c_lo.reshape(b, 1, 1, 1),
                           p.c_hi.reshape(b, 1, 1, 1),
                           p.c_rhi.reshape(b, 1, 1))
        centers, radii, counts = decode_wire_coresets(wire)
        return jax.vmap(lambda c, r, n, kk: recover_cluster_window(
            ClusterCoreset(c, r, n), kk, t))(centers, radii, counts, keys)

    def sampling_windows(_):
        swire = WireSamplePayload(p.s_idx, p.s_codes, p.s_lo.reshape(b, 1, 1),
                                  p.s_hi.reshape(b, 1, 1), p.s_mean, p.s_var)
        idx, vals, mean, var = decode_wire_samples(swire)
        return jax.vmap(
            lambda i, v, mu, va, kk: recover_sampling_window(
                gen_params,
                SamplingCoreset(i, v, jnp.ones_like(i, jnp.float32), mu, va),
                kk, t))(idx, vals, mean, var, keys)

    def mixed(_):
        return jnp.where((p.kind == CLUSTER_KIND)[:, None, None],
                         cluster_windows(None), sampling_windows(None))

    has_sampling = jnp.any(valid & (p.kind == SAMPLING_KIND))
    has_cluster = jnp.any(valid & (p.kind == CLUSTER_KIND))
    branch = jnp.where(has_sampling & has_cluster, 2,
                       jnp.where(has_sampling, 1, 0))
    return jax.lax.switch(branch, [cluster_windows, sampling_windows, mixed],
                          None)


def _check_lane_width(cfg: HostServeConfig, width: int) -> None:
    """An ingest lane wider than the ring would overflow EVERY slot — even
    an empty queue cannot hold the arrivals, so the excess is guaranteed
    drops by construction, not by load.  Rejected at the entry points
    (static shape, so a python check; the config-level analogue lives in
    :meth:`HostServeConfig.__post_init__`)."""
    if width > cfg.queue_capacity:
        raise ValueError(
            f"ingest lane of {width} entries exceeds queue_capacity="
            f"{cfg.queue_capacity}: even an empty queue would overflow on "
            f"every slot — raise HostServeConfig.queue_capacity or narrow "
            f"the lane")


# ---------------------------------------------------------------------------
# The jitted serve slot
# ---------------------------------------------------------------------------

# the compile-cache acceptance probe rides the generalized trace-event
# accounting of repro.obs.compile_guard: serve builders emit
# compile_event("host.serve", (cfg, tag)) at trace time — once per distinct
# compiled shape — and serve_trace_count groups those keys the way the
# host tests have always pinned them
_SERVE_COMPONENT = "host.serve"


def serve_trace_count(cfg: HostServeConfig | None = None) -> int:
    """How many times serve functions were traced (== compiled shapes).

    With ``cfg``, counts every trace for that config *including* its
    service-rate variants (``batches_per_slot`` differences — e.g. the
    config :func:`serve_fleet_payloads` derives per fleet round): a variant
    is a distinct compiled shape and must show up in the probe.  Without
    ``cfg``, the global total."""
    counts = compile_key_counts(_SERVE_COMPONENT)
    if cfg is not None:
        key = dataclasses.replace(cfg, batches_per_slot=0)
        return sum(
            n for (c, _), n in counts.items()
            if dataclasses.replace(c, batches_per_slot=0) == key)
    return sum(counts.values())


def _slot_body(cfg: HostServeConfig, state: HostServerState,
               entries: HostPayload, node_ids: jnp.ndarray,
               mask: jnp.ndarray, host_params: dict,
               gen_params: GeneratorParams, base_key: jax.Array
               ) -> tuple[HostServerState, SlotOutput]:
    """One serve slot: ingest stamped arrivals, then run
    ``cfg.batches_per_slot`` EDF microbatches through cache + recovery +
    DNN.  Pure function of fixed-shape inputs."""
    tel = host_telemetry_spec(cfg) if cfg.telemetry else None
    metrics = state.metrics
    if tel is not None and metrics is None:
        raise ValueError(
            "cfg.telemetry=True but the server state has no metrics lanes — "
            "build the state with host_server_init(cfg) using the SAME "
            "telemetry setting (the lanes are part of the resumable carry)")
    arrival = jnp.broadcast_to(state.slot, node_ids.shape)
    deadline = arrival + cfg.qos_slots
    queue, _ = queue_push_batch(state.queue, entries, node_ids, arrival,
                                deadline, mask)
    if tel is not None:
        metrics = counter_add(
            tel, metrics, "host.drops_overflow",
            queue.drops_overflow - state.queue.drops_overflow)

    cache = state.cache
    served, missed_total = state.served, state.deadline_misses
    ens_l, ens_v = state.ensemble_logits, state.ensemble_votes
    outs = []
    for _ in range(cfg.batches_per_slot):
        queue, batch, missed = edf_pop_batch(queue, cfg.batch_size,
                                             now=state.slot)
        missed_total = missed_total + missed
        if tel is not None:
            # QoS observables at service time: queue sojourn of every row
            # served this batch (and its +1-slot end-to-end latency), with a
            # per-payload-class breakdown — the histograms the p50/p95/p99
            # extraction reads
            sojourn = batch_wait_slots(batch, state.slot)
            is_cluster = batch.valid & (batch.payload.kind == CLUSTER_KIND)
            is_sampling = batch.valid & (batch.payload.kind == SAMPLING_KIND)
            metrics = hist_observe(tel, metrics, "host.sojourn_slots",
                                   sojourn, batch.valid)
            metrics = hist_observe(tel, metrics, "host.e2e_slots",
                                   sojourn + 1, batch.valid)
            metrics = hist_observe(tel, metrics, "host.sojourn_slots.cluster",
                                   sojourn, is_cluster)
            metrics = hist_observe(tel, metrics,
                                   "host.sojourn_slots.sampling",
                                   sojourn, is_sampling)
            metrics = counter_add(tel, metrics, "host.served", batch.valid)
            metrics = counter_add(tel, metrics, "host.deadline_misses",
                                  missed)

        sigs = jax.vmap(payload_signature)(batch.payload)        # (B, 2)
        hit, cached = cache_lookup_batch(cache, sigs, batch.valid)
        keys = jax.vmap(
            lambda s: jax.random.fold_in(jax.random.fold_in(base_key, s[0]),
                                         s[1]))(sigs)

        def compute(_):
            wins = _entry_windows(batch.payload, gen_params, keys, cfg.t,
                                  batch.valid)
            if cfg.n_tasks == 1:
                return har_apply(host_params, wins)
            # mixed fleets: run every task's DNN over the batch at fixed
            # shape (host_params arrives stacked leaf-wise, leading axis
            # n_tasks — the kind-switch pattern, one level up), then gather
            # each entry's own task row
            per_task = jax.vmap(lambda p: har_apply(p, wins))(host_params)
            tid = jnp.clip(batch.payload.task.astype(jnp.int32),
                           0, cfg.n_tasks - 1)
            return per_task[tid, jnp.arange(tid.shape[0])]

        # a fully-memoized batch skips recovery + DNN (the host-side D0 skip)
        all_hit = jnp.all(hit | ~batch.valid)
        logits = jax.lax.cond(all_hit, lambda _: cached, compute, None)
        logits = jnp.where(hit[:, None], cached, logits)

        fresh = batch.valid & ~hit
        cache = cache_insert_batch(cache, sigs, logits, fresh)
        cache = cache._replace(
            hits=cache.hits + jnp.sum(hit.astype(jnp.int32)),
            misses=cache.misses + jnp.sum(fresh.astype(jnp.int32)))
        served = served + jnp.sum(batch.valid.astype(jnp.int32))
        if tel is not None:
            metrics = counter_add(tel, metrics, "host.cache_hits", hit)
            metrics = counter_add(tel, metrics, "host.cache_misses", fresh)

        # per-node ensemble: mean-logit sum + majority-vote histogram
        nid = jnp.clip(jnp.where(batch.valid, batch.node_id, 0),
                       0, cfg.n_nodes - 1)
        w = batch.valid.astype(jnp.float32)[:, None]
        ens_l = ens_l.at[nid].add(logits * w)
        votes = (jax.nn.one_hot(jnp.argmax(logits, axis=-1), cfg.n_classes,
                                dtype=jnp.int32)
                 * batch.valid[:, None].astype(jnp.int32))
        ens_v = ens_v.at[nid].add(votes)
        outs.append(SlotOutput(batch.node_id, logits, batch.deadline,
                               hit, batch.valid))

    out = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, axis=0),
                                 *outs)
    if tel is not None:
        # backlog level and age profile AFTER this slot's service — what is
        # still waiting, and for how long it has waited
        metrics = gauge_set(tel, metrics, "host.backlog",
                            queue_occupancy(queue))
        metrics = hist_observe(tel, metrics, "host.backlog_age_slots",
                               queue_wait_slots(queue, state.slot),
                               queue.valid)
    new_state = HostServerState(queue, cache, state.slot + 1, served,
                                missed_total, ens_l, ens_v, metrics)
    return new_state, out


@functools.lru_cache(maxsize=32)
def _build_serve_slot(cfg: HostServeConfig, donate: bool):
    def slot(state, entries, node_ids, mask, host_params, gen_params,
             base_key):
        compile_event(_SERVE_COMPONENT, (cfg, "slot"))    # trace-time only
        obs_trace.instant("compile:host.serve_slot")
        return _slot_body(cfg, state, entries, node_ids, mask, host_params,
                          gen_params, base_key)
    return jax.jit(slot, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=32)
def _build_serve_trace(cfg: HostServeConfig, donate: bool):
    def trace(state, entries, node_ids, masks, host_params, gen_params,
              base_key):
        compile_event(_SERVE_COMPONENT, (cfg, "trace"))   # trace-time only
        obs_trace.instant("compile:host.serve_trace")

        def step(carry, inp):
            e, nid, m = inp
            return _slot_body(cfg, carry, e, nid, m, host_params, gen_params,
                              base_key)

        return jax.lax.scan(step, state, (entries, node_ids, masks))
    return jax.jit(trace, donate_argnums=(0,) if donate else ())


def host_serve_slot(state: HostServerState, entries: HostPayload,
                    node_ids: jnp.ndarray, mask: jnp.ndarray, *,
                    cfg: HostServeConfig, host_params: dict,
                    gen_params: GeneratorParams, base_key: jax.Array,
                    donate: bool = False
                    ) -> tuple[HostServerState, SlotOutput]:
    """Streaming entry point: one serve slot over a fixed-width ingest lane.

    ``entries`` leaves have leading axis A (the lane width — pad a churny
    slot's arrivals up to a FIXED A and mask the padding; a varying A would
    recompile).  Returns ``(state', SlotOutput)``; feed ``state'`` back in —
    backlog, cache, clock and ensemble all carry over."""
    _check_lane_width(cfg, entries.kind.shape[0])
    run = _build_serve_slot(cfg, donate)
    return run(state, entries, jnp.asarray(node_ids, jnp.int32),
               jnp.asarray(mask, bool), host_params, gen_params, base_key)


def host_serve_trace(state: HostServerState, entries: HostPayload,
                     node_ids: jnp.ndarray, masks: jnp.ndarray, *,
                     cfg: HostServeConfig, host_params: dict,
                     gen_params: GeneratorParams, base_key: jax.Array,
                     donate: bool = False
                     ) -> tuple[HostServerState, SlotOutput]:
    """Whole-trace entry point: ``lax.scan`` of the serve slot over S slots
    (entry leaves (S, A, ...), masks (S, A)) in ONE compiled program.
    Resumable exactly like the fleet engine: chaining two traces through the
    returned state equals one long trace."""
    _check_lane_width(cfg, entries.kind.shape[1])
    run = _build_serve_trace(cfg, donate)
    return run(state, entries, jnp.asarray(node_ids, jnp.int32),
               jnp.asarray(masks, bool), host_params, gen_params, base_key)


def serve_fleet_payloads(state: HostServerState, wire: WirePayload,
                         node_ids: jnp.ndarray, *, cfg: HostServeConfig,
                         host_params: dict, gen_params: GeneratorParams,
                         base_key: jax.Array,
                         mask: jnp.ndarray | None = None,
                         node_tasks: jnp.ndarray | None = None,
                         donate: bool = False
                         ) -> tuple[HostServerState, SlotOutput]:
    """Ingest one fleet round of gathered cluster payloads (what
    :func:`repro.serving.edge_host.fleet_serve_step` all_gathers) and serve
    enough EDF microbatches to cover them at the configured batch size.

    ``mask`` is the round's alive mask (B,) — a churny fleet's dead nodes
    produce no radio frame, so their lane rows never enqueue (the lane stays
    at the FIXED fleet width; only the mask varies, which never re-traces).

    ``node_tasks`` is the round's (B,) per-node task ids for a mixed fleet
    (``cfg.n_tasks > 1`` + stacked ``host_params``): each payload is served
    by its own workload's recovery DNN.
    """
    entries = cluster_entries(wire, cfg.m, tasks=node_tasks)
    b = entries.kind.shape[0]
    if b > cfg.queue_capacity:
        raise ValueError(
            f"fleet round of {b} payloads exceeds queue capacity "
            f"{cfg.queue_capacity}; raise HostServeConfig.queue_capacity")
    n_batches = -(-b // cfg.batch_size)
    cfg = dataclasses.replace(cfg, batches_per_slot=n_batches)
    mask = jnp.ones((b,), bool) if mask is None else jnp.asarray(mask, bool)
    return host_serve_slot(state, entries, node_ids, mask, cfg=cfg,
                           host_params=host_params, gen_params=gen_params,
                           base_key=base_key, donate=donate)


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def host_server_stats(state: HostServerState,
                      cfg: HostServeConfig | None = None) -> dict:
    """QoS counters as python numbers (one sync; call off the hot path).

    With ``cfg`` (and a state whose carry holds telemetry lanes), the dict
    additionally reports the QoS percentiles the ROADMAP asks for —
    ``sojourn_p50/p95/p99`` and ``e2e_p50/p95/p99`` slot latencies extracted
    from the in-slot histograms — plus the full
    :func:`repro.obs.metrics_summary` under ``"telemetry"``."""
    served = int(state.served)
    missed = int(state.deadline_misses)
    dropped = int(state.queue.drops_overflow)
    total = served + missed + dropped
    out = {
        "slot": int(state.slot),
        "served": served,
        "deadline_misses": missed,
        "drops_overflow": dropped,
        "backlog": int(queue_occupancy(state.queue)),
        "deadline_miss_rate": missed / max(total, 1),
        "qos_fail_rate": (missed + dropped) / max(total, 1),  # misses + drops
        **cache_stats(state.cache),
    }
    if cfg is not None and cfg.telemetry and state.metrics is not None:
        spec = host_telemetry_spec(cfg)
        summary = metrics_summary(spec, state.metrics)
        out["telemetry"] = summary
        for key, lane in (("sojourn", "host.sojourn_slots"),
                          ("e2e", "host.e2e_slots")):
            for q in (50, 95, 99):
                out[f"{key}_p{q}"] = summary[lane][f"p{q}"]
    return out


def host_ensemble(state: HostServerState) -> dict:
    """Per-node ensemble answers from the accumulated serve history:
    ``pred_mean`` (argmax of summed logits — the paper's logit ensemble) and
    ``pred_vote`` (majority vote over per-payload argmaxes), plus per-node
    served counts.  Nodes never served predict class 0 with count 0."""
    counts = jnp.sum(state.ensemble_votes, axis=-1)            # (N,)
    mean_logits = state.ensemble_logits \
        / jnp.maximum(counts, 1)[:, None].astype(jnp.float32)
    return {
        "counts": counts,
        "mean_logits": mean_logits,
        "pred_mean": jnp.argmax(mean_logits, axis=-1),
        "pred_vote": jnp.argmax(state.ensemble_votes, axis=-1),
    }
