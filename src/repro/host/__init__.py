"""Host-tier serving subsystem: QoS-deadline payload queue, EDF fixed-shape
microbatch scheduler, signature-keyed recovery cache, and the host serve
loop (queue -> scheduler -> batched recovery -> DNN -> per-node ensemble)."""
from .queue import (  # noqa: F401
    NO_DEADLINE, PayloadQueue, queue_init, queue_occupancy, queue_push,
    queue_push_batch, queue_wait_slots,
)
from .scheduler import (  # noqa: F401
    MicroBatch, batch_task_counts, batch_wait_slots, edf_pop_batch,
    expire_deadlines,
)
from .cache import (  # noqa: F401
    RecoveryCache, cache_init, cache_insert_batch, cache_lookup_batch,
    cache_stats, payload_signature,
)
from .server import (  # noqa: F401
    CLUSTER_KIND, SAMPLING_KIND, HostPayload, HostServeConfig,
    HostServerState, SlotOutput, cluster_entries, host_ensemble,
    host_payload_example, host_serve_slot, host_serve_trace,
    host_server_init, host_server_init_stacked, host_server_stats,
    host_telemetry_spec, recover_infer_batch, sampling_entries,
    serve_fleet_payloads, serve_trace_count,
)
