"""Host-tier serving throughput: batched queue/EDF/recovery path vs the
per-payload loop the repo used to inline (ISSUE 3 acceptance benchmark).

``PYTHONPATH=src python -m benchmarks.host_throughput`` (or via
benchmarks.run)

A pool of quantized cluster wire-payloads is pushed through three host-side
execution models:

* ``per_payload`` — the pre-subsystem baseline: one jitted
  decode -> recover -> DNN call *per payload* (batch 1), a Python loop over
  the pool — per-call dispatch plus unbatched compute;
* ``batched_direct`` — :func:`repro.host.server.recover_infer_batch` on the
  whole pool at once (no queue): the raw batching headroom;
* ``host_server/b{B}_q{Q}`` — the full subsystem: ring-queue ingest, EDF
  assembly into fixed-(B,) microbatches, signature cache, batched recovery +
  DNN — swept over batch size B and queue depth Q.

Reported: payloads/second and ``speedup_x`` over the per-payload baseline.
Acceptance: the batched host path is >= 5x the per-payload loop at batch 64
on CPU.  ``quick=True`` (CI bench-smoke) shrinks the pool and sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import HAR
from repro.core.coreset import channel_cluster_coresets
from repro.core.recovery import init_generator
from repro.data.sensors import har_stream
from repro.host import (HostServeConfig, host_server_init,
                        host_server_stats, recover_infer_batch,
                        serve_fleet_payloads)
from repro.models.har import har_init
from repro.serving import WirePayload, encode_wire_coresets

from .common import timeit_us

N_PAYLOADS = 256
BATCH_SIZES = (8, 64)
QUEUE_DEPTHS = (256, 1024)
QUICK_N = 16
QUICK_BATCH_SIZES = (4,)
QUICK_QUEUE_DEPTHS = (32,)


def _payload_pool(n: int) -> WirePayload:
    wins, _ = har_stream(jax.random.PRNGKey(0), n)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=12, iters=4))(wins)
    return encode_wire_coresets(centers, radii, counts)


def run(quick: bool = False) -> list[dict]:
    n = QUICK_N if quick else N_PAYLOADS
    batches = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    depths = QUICK_QUEUE_DEPTHS if quick else QUEUE_DEPTHS
    key = jax.random.PRNGKey(0)
    # untrained weights: identical FLOPs to trained ones (cf. fleet_scale)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    pool = _payload_pool(n)
    t = HAR.window
    rows = []

    # --- baseline: one payload per call, Python loop over the pool ---------
    one = jax.tree_util.tree_map(lambda a: a[:1], pool)
    per_payload = jax.jit(functools.partial(recover_infer_batch, t=t))
    keys = jax.random.split(key, n)

    def loop():
        out = None
        for i in range(n):
            out = per_payload(one, params, keys[i:i + 1])
        return out

    base_us = timeit_us(loop, iters=1 if quick else 3, warmup=1)
    base_rate = n / (base_us / 1e6)
    rows.append({"name": "host_throughput/per_payload",
                 "us_per_call": base_us, "payloads_per_s": base_rate,
                 "n_payloads": n, "speedup_x": 1.0})

    # --- batched direct (no queue): the raw batching headroom --------------
    direct = jax.jit(functools.partial(recover_infer_batch, t=t))
    all_keys = jax.random.split(key, n)
    us = timeit_us(lambda: direct(pool, params, all_keys),
                   iters=1 if quick else 10, warmup=1)
    rows.append({"name": "host_throughput/batched_direct",
                 "us_per_call": us, "payloads_per_s": n / (us / 1e6),
                 "n_payloads": n, "speedup_x": base_us / us})

    # --- the full subsystem: queue -> EDF -> cache -> batched DNN ----------
    node_ids = jnp.arange(n, dtype=jnp.int32)
    for depth in depths:
        for batch in batches:
            cfg = HostServeConfig(
                channels=HAR.channels, k=12, m=20, t=t,
                n_classes=HAR.n_classes, n_nodes=n, batch_size=batch,
                queue_capacity=max(depth, n), cache_capacity=depth,
                qos_slots=8)
            iters = 1 if quick else 5
            # fresh (cold-cache) states pre-built OUTSIDE the timed region —
            # this measures the cold serve path, not state allocation
            states = iter([host_server_init(cfg)
                           for _ in range(iters + 2)])

            def serve():
                _, out = serve_fleet_payloads(
                    next(states), pool, node_ids, cfg=cfg,
                    host_params=params, gen_params=gen, base_key=key)
                return out.logits

            us = timeit_us(serve, iters=iters, warmup=1)
            rows.append({
                "name": f"host_throughput/host_server_b{batch}_q{depth}",
                "us_per_call": us,
                "payloads_per_s": n / (us / 1e6),
                "n_payloads": n,
                "batch_size": batch,
                "queue_depth": depth,
                "speedup_x": base_us / us,
            })

    # --- telemetry-on QoS row: the same serve path with registry lanes -----
    # (sojourn/e2e percentiles extracted from the jit-resident histograms;
    # the timing delta vs the matching telemetry=off row above is the lane
    # overhead the OBSERVABILITY doc quotes)
    cfg = HostServeConfig(
        channels=HAR.channels, k=12, m=20, t=t, n_classes=HAR.n_classes,
        n_nodes=n, batch_size=batches[-1],
        queue_capacity=max(depths[-1], n), cache_capacity=depths[-1],
        qos_slots=8, telemetry=True)
    iters = 1 if quick else 5
    states = iter([host_server_init(cfg) for _ in range(iters + 2)])
    final = {}

    def serve_tel():
        final["state"], out = serve_fleet_payloads(
            next(states), pool, node_ids, cfg=cfg,
            host_params=params, gen_params=gen, base_key=key)
        return out.logits

    us = timeit_us(serve_tel, iters=iters, warmup=1)
    stats = host_server_stats(final["state"], cfg)
    rows.append({
        "name": f"host_throughput/host_server_telemetry_b{batches[-1]}"
                f"_q{depths[-1]}",
        "us_per_call": us,
        "payloads_per_s": n / (us / 1e6),
        "n_payloads": n,
        "speedup_x": base_us / us,
        "sojourn_p50": stats["sojourn_p50"],
        "sojourn_p99": stats["sojourn_p99"],
        "e2e_p50": stats["e2e_p50"],
        "e2e_p99": stats["e2e_p99"],
        "served": stats["served"],
        "deadline_misses": stats["deadline_misses"],
    })
    return rows


if __name__ == "__main__":
    for row in run():
        extra = ""
        if "batch_size" in row:
            extra = (f"  (batch {row['batch_size']}, "
                     f"queue {row['queue_depth']})")
        print(f"{row['name']:>42s}  {row['payloads_per_s']:>10.0f} "
              f"payloads/s  {row['speedup_x']:>6.1f}x vs per-payload{extra}")
