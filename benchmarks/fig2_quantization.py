"""Paper Fig. 2c: accuracy as a function of post-training quantization."""
from __future__ import annotations

from repro.models.har import har_apply_quantized

from .common import accuracy, trained_har


def run() -> list[dict]:
    params, x, y = trained_har()
    rows = [{"name": "fig2c/float32", "us_per_call": 0.0,
             "acc": accuracy(params, x, y), "bits": 32}]
    for bits in (16, 12, 10, 8, 6, 4):
        rows.append({
            "name": f"fig2c/int{bits}",
            "us_per_call": 0.0,
            "bits": bits,
            "acc": accuracy(params, x, y, har_apply_quantized, bits=bits),
        })
    return rows
