"""Fleet-scale Seeker throughput: batched scan vs fleet size, single-device
and sharded.

``PYTHONPATH=src python -m benchmarks.fleet_scale`` (or via benchmarks.run)

Sweeps N ∈ {3, 30, 300, 3000} independent EH nodes with heterogeneous
harvest traces through :func:`repro.serving.seeker_fleet_simulate` and
reports simulated windows/second and bytes-on-wire vs the raw-transmission
baseline — the fleet-engine scaling story on top of the paper's per-node
communication reduction.  The same sweep then runs through
:func:`repro.serving.seeker_fleet_simulate_sharded` with the node axis split
over every visible device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a CPU mesh), so
the sharded-vs-single-device trajectory accumulates alongside it.

``quick=True`` (the CI bench-smoke job) shrinks to SLOTS=2 and tiny fleets —
including a non-divisible N to keep the pad-to-quantum path exercised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import HAR
from repro.core import DEFER, fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate, seeker_fleet_simulate_sharded
from repro.sharding import make_mesh_compat

from .common import timeit_us

SLOTS = 8
FLEET_SIZES = (3, 30, 300, 3000)
QUICK_SLOTS = 2
QUICK_FLEET_SIZES = (3, 13)     # 13: non-divisible N -> pad/mask path


def run(quick: bool = False) -> list[dict]:
    slots = QUICK_SLOTS if quick else SLOTS
    sizes = QUICK_FLEET_SIZES if quick else FLEET_SIZES
    key = jax.random.PRNGKey(0)
    # untrained weights: identical FLOPs/bytes to trained ones, and this
    # benchmark measures engine throughput, not accuracy
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, _ = har_stream(key, slots)
    mesh = make_mesh_compat((jax.device_count(),), ("data",))

    rows = []
    for sharded in (False, True):
        for n in sizes:
            harvest = fleet_harvest_traces(key, n, slots)
            last = {}

            def sim():
                if sharded:
                    last["res"] = seeker_fleet_simulate_sharded(
                        wins, harvest, signatures=sigs, qdnn_params=params,
                        host_params=params, gen_params=gen, har_cfg=HAR,
                        mesh=mesh)
                else:
                    last["res"] = seeker_fleet_simulate(
                        wins, harvest, signatures=sigs, qdnn_params=params,
                        host_params=params, gen_params=gen, har_cfg=HAR)
                return last["res"]["decisions"]

            iters = 1 if (quick or n > 300) else 3
            us = timeit_us(sim, iters=iters, warmup=1)
            res = last["res"]
            n_windows = n * slots
            sent = int(jnp.sum(res["decisions"] != DEFER))
            wire = float(res["bytes_on_wire"])
            raw = sent * float(res["raw_bytes_per_window"])
            row = {
                "name": f"fleet_scale/{'sharded_' if sharded else ''}n{n}",
                "us_per_call": us,
                "windows_per_s": n_windows / (us / 1e6),
                "bytes_on_wire": wire,
                "raw_bytes_equiv": float(raw),
                "reduction_x": raw / max(wire, 1e-9),
                "completed_frac": sent / n_windows,
            }
            if sharded:
                row["devices"] = jax.device_count()
                row["padded_nodes"] = res["padded_nodes"]
            rows.append(row)
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']:>26s}  {row['windows_per_s']:>10.0f} win/s  "
              f"{row['bytes_on_wire']:>12.0f} B on wire  "
              f"({row['reduction_x']:.1f}x under raw, "
              f"{100 * row['completed_frac']:.0f}% completed)")
