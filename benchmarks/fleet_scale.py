"""Fleet-scale Seeker throughput: one batched scan vs fleet size.

``PYTHONPATH=src python -m benchmarks.fleet_scale`` (or via benchmarks.run)

Sweeps N ∈ {3, 30, 300, 3000} independent EH nodes with heterogeneous
harvest traces through :func:`repro.serving.seeker_fleet_simulate` and
reports simulated windows/second and bytes-on-wire vs the raw-transmission
baseline — the fleet-engine scaling story on top of the paper's per-node
communication reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import HAR
from repro.core import DEFER, fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate

from .common import timeit_us

SLOTS = 8
FLEET_SIZES = (3, 30, 300, 3000)


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    # untrained weights: identical FLOPs/bytes to trained ones, and this
    # benchmark measures engine throughput, not accuracy
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, _ = har_stream(key, SLOTS)

    rows = []
    for n in FLEET_SIZES:
        harvest = fleet_harvest_traces(key, n, SLOTS)
        last = {}

        def sim():
            last["res"] = seeker_fleet_simulate(
                wins, harvest, signatures=sigs, qdnn_params=params,
                host_params=params, gen_params=gen, har_cfg=HAR)
            return last["res"]["decisions"]

        iters = 3 if n <= 300 else 1
        us = timeit_us(sim, iters=iters, warmup=1)
        res = last["res"]
        n_windows = n * SLOTS
        sent = int(jnp.sum(res["decisions"] != DEFER))
        wire = float(res["bytes_on_wire"])
        raw = sent * float(res["raw_bytes_per_window"])
        rows.append({
            "name": f"fleet_scale/n{n}",
            "us_per_call": us,
            "windows_per_s": n_windows / (us / 1e6),
            "bytes_on_wire": wire,
            "raw_bytes_equiv": float(raw),
            "reduction_x": raw / max(wire, 1e-9),
            "completed_frac": sent / n_windows,
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']:>18s}  {row['windows_per_s']:>10.0f} win/s  "
              f"{row['bytes_on_wire']:>12.0f} B on wire  "
              f"({row['reduction_x']:.1f}x under raw, "
              f"{100 * row['completed_frac']:.0f}% completed)")
