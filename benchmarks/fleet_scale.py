"""Fleet-scale Seeker throughput: batched scan vs fleet size, single-device
and sharded, plus the streaming-vs-materialized memory story.

``PYTHONPATH=src python -m benchmarks.fleet_scale`` (or via benchmarks.run)

Sweeps N ∈ {3, 30, 300, 3000} independent EH nodes with heterogeneous
harvest traces through :func:`repro.serving.seeker_fleet_simulate` and
reports simulated windows/second and bytes-on-wire vs the raw-transmission
baseline — the fleet-engine scaling story on top of the paper's per-node
communication reduction.  The same sweep then runs through
:func:`repro.serving.seeker_fleet_simulate_sharded` with the node axis split
over every visible device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a CPU mesh), so
the sharded-vs-single-device trajectory accumulates alongside it.

The streaming entry pits :func:`seeker_fleet_simulate_streamed` against the
materialized engine at N=3000 with PER-NODE window streams — the shape
where the (N, S, T, C) input tensor is what kills you, not the compute.
The materialized path must allocate all N·S windows before the scan starts;
the streamed path materializes one N·chunk segment at a time through a
window *callable*, so its peak window footprint is S/chunk times smaller
(``headroom_x`` in the row; the driver is bitwise-equal to the one-shot
run, asserted in the bench).

``quick=True`` (the CI bench-smoke job) shrinks to SLOTS=2 and tiny fleets —
including a non-divisible N to keep the pad-to-quantum path exercised — and
a shorter streaming stream at the same N=3000, chunk=S/4.
"""
from __future__ import annotations

import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.seeker_har import HAR
from repro.core import (DEFER, EH_SOURCES, BrownoutConfig, D6_PARTIAL,
                        IntermittentConfig, fleet_harvest_traces,
                        fleet_source_assignment)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_aux_init, har_init
from repro.serving import (TaskLaneConfig, seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)
from repro.sharding import make_mesh_compat

from .common import timeit_us

SLOTS = 8
FLEET_SIZES = (3, 30, 300, 3000)
QUICK_SLOTS = 2
QUICK_FLEET_SIZES = (3, 13)     # 13: non-divisible N -> pad/mask path

STREAM_N = 3000                 # the acceptance point: N=3000 on CPU
STREAM_SLOTS, STREAM_CHUNK = 32, 4              # 8x window-memory headroom
QUICK_STREAM_SLOTS, QUICK_STREAM_CHUNK = 8, 2   # 4x, CI-sized

BROWNOUT_N = 3000               # realism row: brown-out fraction at N=3000
BROWNOUT_SLOTS, QUICK_BROWNOUT_SLOTS = 32, 4
# thresholds tuned so scant-µW modalities actually brown out: nodes boot at
# 12 µJ, power down under 6 µJ, reboot at 30 µJ
BROWNOUT_CFG = BrownoutConfig(off_uj=6.0, restart_uj=30.0)
BROWNOUT_INITIAL_UJ = 12.0

INTERMITTENT_N, QUICK_INTERMITTENT_N = 3000, 300
INTERMITTENT_SLOTS, QUICK_INTERMITTENT_SLOTS = 32, 8
# scarce-harvest regime: income scaled so a typical slot affords one or two
# inference STAGES but almost never a whole ladder decision — the setting
# where freeze-and-lose DEFER throws work away and staged progress pays
INTERMITTENT_SCARCITY = 0.04
INTERMITTENT_CFG = IntermittentConfig(min_exit_stage=1, exit_threshold=0.0)

# staged-lane early-exit threshold sweep: 0.0 exits whenever affordable,
# 1.01 disables early exit entirely (full-depth-only lane) — the knee
# between them is the confidence/completion trade the lane exposes
EXIT_THRESHOLD_SWEEP = (0.0, 0.35, 0.7, 1.01)

# mixed HAR + bearing-vibration fleet (the heterogeneous-task lane):
# round-robin task assignment, bearing nodes pay the scaled ladder
MIXED_TASK_CFG = TaskLaneConfig()


def run(quick: bool = False) -> list[dict]:
    slots = QUICK_SLOTS if quick else SLOTS
    sizes = QUICK_FLEET_SIZES if quick else FLEET_SIZES
    key = jax.random.PRNGKey(0)
    # untrained weights: identical FLOPs/bytes to trained ones, and this
    # benchmark measures engine throughput, not accuracy
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, _ = har_stream(key, slots)
    mesh = make_mesh_compat((jax.device_count(),), ("data",))

    rows = []
    for sharded in (False, True):
        for n in sizes:
            harvest = fleet_harvest_traces(key, n, slots)
            last = {}

            def sim():
                if sharded:
                    last["res"] = seeker_fleet_simulate_sharded(
                        wins, harvest, signatures=sigs, qdnn_params=params,
                        host_params=params, gen_params=gen, har_cfg=HAR,
                        mesh=mesh)
                else:
                    last["res"] = seeker_fleet_simulate(
                        wins, harvest, signatures=sigs, qdnn_params=params,
                        host_params=params, gen_params=gen, har_cfg=HAR)
                return last["res"]["decisions"]

            iters = 1 if (quick or n > 300) else 3
            us = timeit_us(sim, iters=iters, warmup=1)
            res = last["res"]
            n_windows = n * slots
            sent = int(jnp.sum(res["decisions"] != DEFER))
            wire = float(wire_bytes_exact(res))
            raw = sent * float(res["raw_bytes_per_window"])
            row = {
                "name": f"fleet_scale/{'sharded_' if sharded else ''}n{n}",
                "us_per_call": us,
                "windows_per_s": n_windows / (us / 1e6),
                "bytes_on_wire": wire,
                "raw_bytes_equiv": float(raw),
                "reduction_x": raw / max(wire, 1e-9),
                "completed_frac": sent / n_windows,
            }
            if sharded:
                row["devices"] = jax.device_count()
                row["padded_nodes"] = res["padded_nodes"]
            rows.append(row)
    rows.extend(_streaming_rows(key, params, gen, sigs, quick))
    rows.extend(_brownout_rows(key, params, gen, sigs, quick))
    rows.extend(_intermittent_rows(key, params, gen, sigs, quick))
    rows.extend(_exit_threshold_rows(key, params, gen, sigs, quick))
    rows.extend(_mixed_fleet_rows(key, params, gen, sigs, quick))
    return rows


def _streaming_rows(key, params, gen, sigs, quick: bool) -> list[dict]:
    """Materialized vs streamed per-node window streams at N=3000.

    The window *content* is identical in both paths (a shared base stream
    plus a deterministic per-node offset), but the materialized path builds
    the whole (N, S, T, C) tensor before simulating while the streamed path
    only ever holds one (N, chunk, T, C) segment — the ``peak_window_mb``
    accounting below is exactly those tensor sizes.  RSS is reported too,
    but on CPU the allocator reuses freed segments, so the tensor-size
    accounting is the honest headroom metric.
    """
    n = STREAM_N
    s = QUICK_STREAM_SLOTS if quick else STREAM_SLOTS
    chunk = QUICK_STREAM_CHUNK if quick else STREAM_CHUNK
    t, c = HAR.window, HAR.channels
    shared, _ = har_stream(key, s)
    harvest = fleet_harvest_traces(key, n, s)
    bias = 1e-3 * jnp.arange(n, dtype=jnp.float32)[:, None, None, None]

    def node_windows(a, b):
        """(N, b-a, T, C) — one segment of the fleet's per-node streams."""
        return jnp.broadcast_to(shared[a:b][None],
                                (n, b - a, t, c)) + bias

    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR)

    def rss_mb():
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

    rows = []
    win_bytes = 4 * n * t * c
    results = {}
    for name, fn, peak_mb in (
            ("materialized",
             lambda: seeker_fleet_simulate(node_windows(0, s), harvest, **kw),
             s * win_bytes / 2**20),
            (f"streamed_chunk{chunk}",
             lambda: seeker_fleet_simulate_streamed(node_windows, harvest,
                                                    chunk=chunk, **kw),
             chunk * win_bytes / 2**20)):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res["decisions"])
        wall = time.perf_counter() - t0
        results[name] = np.asarray(res["decisions"])
        rows.append({
            "name": f"fleet_scale/stream_n{n}_{name}",
            "us_per_call": wall * 1e6,
            "windows_per_s": n * s / wall,
            "peak_window_mb": round(peak_mb, 2),
            "rss_mb": round(rss_mb(), 1),
            "slots": s,
        })
    rows[-1]["headroom_x"] = s / chunk      # the acceptance metric: >= 4x
    rows[-1]["bitwise_equal"] = bool(
        np.array_equal(results["materialized"],
                       results[f"streamed_chunk{chunk}"]))
    assert rows[-1]["bitwise_equal"], \
        "streamed fleet diverged from the materialized run"
    assert rows[-1]["headroom_x"] >= 4.0, \
        f"streaming config gives only {rows[-1]['headroom_x']}x " \
        f"peak-window-memory headroom; the acceptance bar is 4x"
    return rows


def _brownout_rows(key, params, gen, sigs, quick: bool) -> list[dict]:
    """Brown-out realism at N=3000: fraction of slots the supercap
    hysteresis suppressed, split by harvest modality.

    With endogenous churn the fleet's availability is an OUTPUT of the
    simulated physics, so this row tracks how each modality's income
    profile translates into downtime — RF/WiFi's scant microwatts should
    brown out far more than solar's milliwatt income.  The engine-level
    conservation law (alive + browned-out slots = every scheduled slot) is
    asserted on the way.
    """
    n = BROWNOUT_N
    s = QUICK_BROWNOUT_SLOTS if quick else BROWNOUT_SLOTS
    wins, _ = har_stream(key, s)
    harvest = fleet_harvest_traces(key, n, s)

    t0 = time.perf_counter()
    res = seeker_fleet_simulate(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR,
        brownout=BROWNOUT_CFG, initial_uj=BROWNOUT_INITIAL_UJ)
    jax.block_until_ready(res["decisions"])
    wall = time.perf_counter() - t0

    bo = np.asarray(res["brownout"])                          # (S, N)
    assert int(res["alive_slots"]) + int(res["brownout_slots"]) == n * s, \
        "alive/brown-out slot conservation violated"
    src = fleet_source_assignment(n)
    rows = [{
        "name": f"fleet_scale/brownout_n{n}",
        "us_per_call": wall * 1e6,
        "windows_per_s": n * s / wall,
        "brownout_frac": float(bo.mean()),
        "brownout_events": int(res["brownout_events"]),
        "completed_frac": float(res["completed_frac"]),
        "off_uj": BROWNOUT_CFG.off_uj,
        "restart_uj": BROWNOUT_CFG.restart_uj,
        "slots": s,
    }]
    for si, name in enumerate(EH_SOURCES):
        sel = src == si
        rows.append({
            "name": f"fleet_scale/brownout_n{n}_{name}",
            "us_per_call": 0.0,
            "brownout_frac": float(bo[:, sel].mean()),
            "nodes": int(sel.sum()),
        })
    return rows


def _intermittent_rows(key, params, gen, sigs, quick: bool) -> list[dict]:
    """Intermittent inference vs freeze-and-lose under scarce harvest.

    Both runs share the same scarce harvest traces, brown-out physics and
    windows; the baseline is the PR 5 strict ladder alone (a slot that
    cannot afford a whole decision DEFERs and the work is lost), the
    treatment adds the staged-inference lane (DEFER slots accumulate
    stages across slots and brown-outs, completing at full depth or via a
    confidence-tagged early exit).  The acceptance metric is the
    completed-inference fraction — completions / scheduled windows, where
    the lane's D6 suspensions do NOT count as completions — which must be
    STRICTLY above the baseline, with the accuracy breakdown
    (ladder / early-exit / full-depth) alongside.
    """
    n = QUICK_INTERMITTENT_N if quick else INTERMITTENT_N
    s = QUICK_INTERMITTENT_SLOTS if quick else INTERMITTENT_SLOTS
    wins, labels = har_stream(key, s)
    harvest = fleet_harvest_traces(key, n, s) * INTERMITTENT_SCARCITY
    aux = har_aux_init(jax.random.fold_in(key, 7), HAR)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, labels=labels,
              brownout=BROWNOUT_CFG, initial_uj=BROWNOUT_INITIAL_UJ)

    rows = []
    results = {}
    for name, extra in (("baseline", {}),
                        ("staged", dict(intermittent=INTERMITTENT_CFG,
                                        aux_params=aux))):
        t0 = time.perf_counter()
        res = seeker_fleet_simulate(wins, harvest, **kw, **extra)
        jax.block_until_ready(res["decisions"])
        wall = time.perf_counter() - t0
        results[name] = res
        row = {
            "name": f"fleet_scale/intermittent_n{n}_{name}",
            "us_per_call": wall * 1e6,
            "windows_per_s": n * s / wall,
            "completed_frac": float(res["completed"]) / (n * s),
            "fleet_accuracy": float(res["fleet_accuracy"]),
            "bytes_on_wire": float(wire_bytes_exact(res)),
            "slots": s,
            "scarcity": INTERMITTENT_SCARCITY,
        }
        if extra:
            row.update({
                "it_full": int(res["it_full"]),
                "it_early": int(res["it_early"]),
                "suspended_slots": int(jnp.sum(
                    (res["decisions"] == D6_PARTIAL) & res["alive"])),
                "correct_ladder": int(res["correct_ladder"]),
                "it_correct_full": int(res["it_correct_full"]),
                "it_correct_early": int(res["it_correct_early"]),
                "exit_threshold": INTERMITTENT_CFG.exit_threshold,
            })
        rows.append(row)
    base, staged = (rows[0]["completed_frac"], rows[1]["completed_frac"])
    rows[-1]["baseline_completed_frac"] = base
    rows[-1]["completed_gain_x"] = staged / max(base, 1e-9)
    assert staged > base, \
        f"intermittent lane must STRICTLY beat freeze-and-lose under " \
        f"scarce harvest: staged {staged:.4f} <= baseline {base:.4f}"
    return rows


def _exit_threshold_rows(key, params, gen, sigs, quick: bool) -> list[dict]:
    """Early-exit confidence threshold vs completion/accuracy (PR 7's open
    sweep).

    Same scarce-harvest regime as the intermittent rows; only
    ``exit_threshold`` varies.  Raising it converts early exits into either
    full-depth completions (the inference keeps accumulating stages) or
    losses (the node never gathers the energy), so ``completed_frac`` can
    only fall while per-emission confidence rises — the knee of that trade
    is the deployment knob this sweep documents.  The >1.0 row is the
    degenerate full-depth-only lane and must emit zero early exits.
    """
    n = QUICK_INTERMITTENT_N if quick else INTERMITTENT_N
    s = QUICK_INTERMITTENT_SLOTS if quick else INTERMITTENT_SLOTS
    wins, labels = har_stream(key, s)
    harvest = fleet_harvest_traces(key, n, s) * INTERMITTENT_SCARCITY
    aux = har_aux_init(jax.random.fold_in(key, 7), HAR)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, labels=labels,
              brownout=BROWNOUT_CFG, initial_uj=BROWNOUT_INITIAL_UJ,
              aux_params=aux)

    rows = []
    for thr in EXIT_THRESHOLD_SWEEP:
        cfg = IntermittentConfig(
            min_exit_stage=INTERMITTENT_CFG.min_exit_stage,
            exit_threshold=thr)
        t0 = time.perf_counter()
        res = seeker_fleet_simulate(wins, harvest, intermittent=cfg, **kw)
        jax.block_until_ready(res["decisions"])
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"fleet_scale/exit_threshold_n{n}_t{thr:g}",
            "us_per_call": wall * 1e6,
            "windows_per_s": n * s / wall,
            "exit_threshold": thr,
            "completed_frac": float(res["completed"]) / (n * s),
            "fleet_accuracy": float(res["fleet_accuracy"]),
            "it_full": int(res["it_full"]),
            "it_early": int(res["it_early"]),
            "it_correct_early": int(res["it_correct_early"]),
            "slots": s,
            "scarcity": INTERMITTENT_SCARCITY,
        })
    assert rows[-1]["it_early"] == 0, \
        f"exit_threshold {EXIT_THRESHOLD_SWEEP[-1]} > 1.0 must disable " \
        f"early exit, got {rows[-1]['it_early']} early emissions"
    assert all(a["it_early"] >= b["it_early"]
               for a, b in zip(rows, rows[1:])), \
        "raising exit_threshold must monotonically suppress early exits"
    return rows


def _mixed_fleet_rows(key, params, gen, sigs, quick: bool) -> list[dict]:
    """Heterogeneous multi-workload fleet: HAR wearables + bearing-vibration
    monitors through ONE engine run (the task lane, ISSUE 9).

    Round-robin task assignment over the fleet; bearing nodes pay the
    scaled decision ladder (:data:`repro.core.energy.BEARING_COST_SCALE`),
    so under the same harvest they complete fewer windows — the per-task
    completion/deadline-miss/accuracy splits the row reports are the psum-
    exact registry aggregates, and their sums must equal the fleet totals
    (asserted: the split is an exact partition, not an estimate).
    """
    n = QUICK_INTERMITTENT_N if quick else INTERMITTENT_N
    s = QUICK_INTERMITTENT_SLOTS if quick else INTERMITTENT_SLOTS
    wins, labels = har_stream(key, s)
    harvest = fleet_harvest_traces(key, n, s)

    t0 = time.perf_counter()
    res = seeker_fleet_simulate(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR, labels=labels,
        task=MIXED_TASK_CFG)
    jax.block_until_ready(res["decisions"])
    wall = time.perf_counter() - t0

    completed_bt = [int(x) for x in np.asarray(res["completed_by_task"])]
    miss_bt = [int(x) for x in np.asarray(res["deadline_miss_by_task"])]
    assert sum(completed_bt) == int(res["completed"]), \
        "per-task completions must partition the fleet total"
    assert sum(completed_bt) + sum(miss_bt) == int(res["alive_slots"]), \
        "per-task completions + misses must partition the alive slots"
    return [{
        "name": f"fleet_scale/mixed_har_bearing_n{n}",
        "us_per_call": wall * 1e6,
        "windows_per_s": n * s / wall,
        "task_names": list(res["task_names"]),
        "completed_by_task": completed_bt,
        "deadline_miss_by_task": miss_bt,
        "accuracy_by_task": [round(float(x), 6)
                             for x in np.asarray(res["accuracy_by_task"])],
        "completed_frac": float(res["completed_frac"]),
        "fleet_accuracy": float(res["fleet_accuracy"]),
        "bytes_on_wire": float(wire_bytes_exact(res)),
        "slots": s,
    }]


if __name__ == "__main__":
    for row in run():
        if "scarcity" in row:
            extra = (f"  ({row['it_full']} full + {row['it_early']} early "
                     f"lane completions)" if "it_full" in row else "")
            print(f"{row['name']:>34s}  "
                  f"{100 * row['completed_frac']:>5.1f}% completed  "
                  f"acc {row['fleet_accuracy']:.3f}{extra}")
        elif "reduction_x" in row:
            print(f"{row['name']:>26s}  {row['windows_per_s']:>10.0f} win/s  "
                  f"{row['bytes_on_wire']:>12.0f} B on wire  "
                  f"({row['reduction_x']:.1f}x under raw, "
                  f"{100 * row['completed_frac']:.0f}% completed)")
        elif "task_names" in row:
            split = ", ".join(
                f"{t}: {c} done / {m} missed (acc {a:.3f})"
                for t, c, m, a in zip(row["task_names"],
                                      row["completed_by_task"],
                                      row["deadline_miss_by_task"],
                                      row["accuracy_by_task"]))
            print(f"{row['name']:>34s}  {split}")
        elif "brownout_frac" in row:
            print(f"{row['name']:>26s}  "
                  f"{100 * row['brownout_frac']:>5.1f}% slots browned out")
        else:                                    # streaming memory rows
            print(f"{row['name']:>26s}  {row['windows_per_s']:>10.0f} win/s  "
                  f"{row['peak_window_mb']:>8.1f} MB peak windows")
