"""Paper Fig. 11 (a) data volume with dynamic (activity-aware) coresets,
(b) fraction of inferences completed per EH source, (c) compute breakdown
across components — the full-system simulation."""
from __future__ import annotations

import collections
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.seeker_har import HAR
from repro.core import EH_SOURCES, harvest_trace, make_aac_table
from repro.core.coreset import cluster_payload_bytes, raw_payload_bytes
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.serving import seeker_simulate

from .common import (trained_generator, trained_har,
                     trained_host_recovered)
from .fig6_clusters import AAC_TABLE_PATH


def _aac_table():
    if os.path.exists(AAC_TABLE_PATH):
        with open(AAC_TABLE_PATH) as f:
            d = json.load(f)
        return make_aac_table(jnp.asarray(d["acc"]), d["ks"])
    return None


def run() -> list[dict]:
    params, _, _ = trained_har()
    host = trained_host_recovered()
    gen = trained_generator()
    key = jax.random.PRNGKey(0)
    sigs = class_signatures()
    wins, labels = har_stream(key, 128)
    t = wins.shape[1]
    c = wins.shape[2]
    raw = raw_payload_bytes(t) * c          # 3-channel window on the wire
    rows = []

    # (a) data volume: fixed-k clustering vs activity-aware (3-channel wire
    # bytes on both sides)
    for k in (8, 12, 16):
        payload = cluster_payload_bytes(k) * c
        rows.append({"name": f"fig11a/fixed_k{k}", "us_per_call": 0.0,
                     "volume_frac": payload / raw,
                     "reduction_x": raw / payload})
    aac = _aac_table()
    res = seeker_simulate(wins, labels, harvest_trace(key, 128, "wifi"),
                          signatures=sigs, qdnn_params=params,
                          host_params=host, gen_params=gen, har_cfg=HAR,
                          aac_table=aac)
    d3 = np.asarray(res["decisions"]) == 3
    if d3.any():
        aac_bytes = float(np.mean(np.asarray(res["payload_bytes"])[d3]))
        rows.append({"name": "fig11a/activity_aware", "us_per_call": 0.0,
                     "volume_frac": aac_bytes / raw,
                     "reduction_x": raw / aac_bytes})

    # (b) completion fraction + (c) component breakdown per EH source
    for src in EH_SOURCES:
        res = seeker_simulate(wins, labels, harvest_trace(key, 128, src),
                              signatures=sigs, qdnn_params=params,
                              host_params=host, gen_params=gen,
                              har_cfg=HAR, aac_table=aac)
        dec = collections.Counter(np.asarray(res["decisions"]).tolist())
        n = len(labels)
        rows.append({
            "name": f"fig11b/{src}", "us_per_call": 0.0,
            "completed_frac": float(res["completed_frac"]),
            "acc_completed": float(res["accuracy_completed"]),
            "memo_frac": dec.get(0, 0) / n,
            "edge_dnn_frac": (dec.get(1, 0) + dec.get(2, 0)) / n,
            "offload_frac": (dec.get(3, 0) + dec.get(4, 0)) / n,
            "defer_frac": dec.get(5, 0) / n,
        })
    return rows
