"""Paper Table 1: accuracy trade-off of compression techniques.

Fourier / DCT / DWT at compression ratios 3-6x vs clustering coresets —
inference accuracy loss on the (synthetic) MHEALTH analogue, classifier
trained on raw windows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.classical import (classical_payload_bytes, dct_compress,
                                  dwt_compress, fourier_compress)
from repro.core.coreset import cluster_payload_bytes, raw_payload_bytes

from .common import (accuracy, finetune_on, recover_cluster_batch, timeit_us,
                     trained_har, trained_host_recovered)
from repro.data.sensors import har_dataset


def run() -> list[dict]:
    params, x, y = trained_har()
    acc_raw = accuracy(params, x, y)
    t = x.shape[1]
    raw_bytes = raw_payload_bytes(t)
    xs_tr, ys_tr = har_dataset(jax.random.PRNGKey(9), 768)
    rows = []

    # Classical baselines — evaluated BOTH with the raw-trained net (the
    # paper's Table-1 protocol) and with a net fine-tuned on the compressed
    # representation (a stronger baseline than the paper grants them).
    for m in (10, 16, 20):
        payload = classical_payload_bytes(m)
        for mname, fn in (("fourier", fourier_compress), ("dct", dct_compress),
                          ("dwt", dwt_compress)):
            jfn = jax.jit(jax.vmap(lambda w, m=m, fn=fn: fn(w, m)))
            xr = jfn(x)
            acc = accuracy(params, xr, y)
            ft = finetune_on(params, jfn(xs_tr), ys_tr)
            rows.append({
                "name": f"table1/{mname}_m{m}",
                "us_per_call": timeit_us(jfn, x, iters=3),
                "ratio": raw_bytes / payload,
                "acc": acc,
                "acc_finetuned": accuracy(ft, xr, y),
                "acc_loss_pct": (acc_raw - acc) * 100,
            })

    # Recoverable clustering coresets (per-channel, host net fine-tuned on
    # recovered data — the paper's protocol for coresets)
    host = trained_host_recovered()
    for k in (8, 12, 16):
        xr = recover_cluster_batch(x, k=k)
        acc = accuracy(host, xr, y)
        rows.append({
            "name": f"table1/coreset_k{k}",
            "us_per_call": 0.0,
            "ratio": raw_bytes / cluster_payload_bytes(k),
            "acc": acc,
            "acc_loss_pct": (acc_raw - acc) * 100,
        })
    rows.append({"name": "table1/raw", "us_per_call": 0.0, "ratio": 1.0,
                 "acc": acc_raw, "acc_loss_pct": 0.0})
    return rows
