"""Paper Fig. 10: Seeker's recoverable codecs vs raw / DCT / DWT on
commercial hardware — compression ratio, recovered accuracy, and codec
latency (the CotS deployment of §5.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import importance_coreset
from repro.core.classical import (classical_payload_bytes, dct_compress,
                                  dwt_compress)
from repro.core.coreset import (cluster_payload_bytes, raw_payload_bytes,
                                sampling_payload_bytes)
from repro.core.recovery import recover_sampling_window

from .common import (accuracy, recover_cluster_batch, timeit_us,
                     trained_generator, trained_har, trained_host_recovered)


def run() -> list[dict]:
    params, x, y = trained_har()
    gen = trained_generator()
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, x.shape[0])
    t = x.shape[1]
    raw = raw_payload_bytes(t)
    rows = [{"name": "fig10/raw", "us_per_call": 0.0, "ratio": 1.0,
             "acc": accuracy(params, x, y)}]

    for name, fn, payload in [
        ("dct", lambda w: dct_compress(w, 14), classical_payload_bytes(14)),
        ("dwt", lambda w: dwt_compress(w, 14), classical_payload_bytes(14)),
    ]:
        jfn = jax.jit(jax.vmap(fn))
        rows.append({"name": f"fig10/{name}", "ratio": raw / payload,
                     "acc": accuracy(params, jfn(x), y),
                     "us_per_call": timeit_us(jfn, x, iters=3)})

    host = trained_host_recovered()
    rows.append({"name": "fig10/seeker_recoverable_cluster",
                 "ratio": raw / cluster_payload_bytes(12),
                 "acc": accuracy(host, recover_cluster_batch(x, 12), y),
                 "us_per_call": 0.0})

    def rec_sampling(w, kk):
        sc = importance_coreset(w, 20, kk)
        return recover_sampling_window(gen, sc, kk, t)

    jfn = jax.jit(jax.vmap(rec_sampling))
    rows.append({"name": "fig10/seeker_recoverable_sampling",
                 "ratio": raw / sampling_payload_bytes(20, channels=3),
                 "acc": accuracy(host, jfn(x, keys), y),
                 "us_per_call": timeit_us(jfn, x, keys, iters=3)})
    return rows
