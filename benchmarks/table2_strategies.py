"""Paper Table 2: energy breakdown + average accuracy of strategies D0-D4.

Energy from the calibrated cost model; accuracy measured by actually
executing each strategy's compute path over the test set (quantized DNN for
D1/D2, recovered coresets for D3/D4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import SYSTEM
from repro.core import TABLE2_COSTS, importance_coreset
from repro.core.decision import decision_energy
from repro.core.recovery import recover_sampling_window
from repro.models.har import har_apply_quantized

from .common import (accuracy, recover_cluster_batch, trained_generator,
                     trained_har, trained_host_recovered)


def run() -> list[dict]:
    params, x, y = trained_har()
    gen = trained_generator()
    key = jax.random.PRNGKey(1)
    t = x.shape[1]
    e = decision_energy(TABLE2_COSTS)
    c = TABLE2_COSTS
    rows = []

    # D1: full-precision DNN on node
    acc = accuracy(params, x, y)
    rows.append({"name": "table2/D1_full_dnn", "us_per_call": 0.0,
                 "sensor_uj": c.dnn_full, "comm_uj": c.tx_result,
                 "total_uj": float(e[1]), "acc": acc})
    # D2: quantized DNN on node
    acc16 = accuracy(params, x, y, har_apply_quantized, bits=16)
    rows.append({"name": "table2/D2_quant_dnn", "us_per_call": 0.0,
                 "sensor_uj": c.dnn16, "comm_uj": c.tx_result,
                 "total_uj": float(e[2]), "acc": acc16})
    # D3: clustering coreset offload + host recovery (host net fine-tuned on
    # recovered data — the paper's protocol)
    host = trained_host_recovered()
    keys = jax.random.split(key, x.shape[0])
    acc3 = accuracy(host, recover_cluster_batch(x, SYSTEM.default_clusters), y)
    rows.append({"name": "table2/D3_cluster_coreset", "us_per_call": 0.0,
                 "sensor_uj": c.sense + c.coreset_cluster,
                 "comm_uj": c.tx_coreset, "total_uj": float(e[3]), "acc": acc3})
    # D4: sampling coreset offload + generator recovery
    def rec4(w, kk):
        sc = importance_coreset(w, SYSTEM.sampling_points, kk)
        return recover_sampling_window(gen, sc, kk, t)

    acc4 = accuracy(host, jax.jit(jax.vmap(rec4))(x, keys), y)
    rows.append({"name": "table2/D4_sampling_coreset", "us_per_call": 0.0,
                 "sensor_uj": c.sense + c.coreset_sampling,
                 "comm_uj": c.tx_coreset, "total_uj": float(e[4]), "acc": acc4})
    # raw offload
    rows.append({"name": "table2/raw_offload", "us_per_call": 0.0,
                 "sensor_uj": 0.0, "comm_uj": c.tx_raw,
                 "total_uj": c.tx_raw, "acc": acc})
    return rows
