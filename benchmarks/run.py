"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick] [--out F]
[--emit-metrics F] [--trace-out F]``

Prints ``name,us_per_call,derived`` CSV (derived = the module's headline
metric per row) followed by human-readable tables, and writes the raw rows
to experiments/bench_results.json (or ``--out``).

``--quick`` runs tiny shapes (the CI bench-smoke job: crash detection + a
perf-trajectory artifact, not a measurement) on every module whose ``run``
accepts a ``quick`` kwarg.  Any benchmark that raises marks the whole run
failed: the harness still executes the remaining modules, then exits
non-zero so CI surfaces the breakage instead of swallowing it.

``--trace-out F`` wraps every module in a :func:`repro.obs.trace.span` and
writes the run as Chrome-trace/Perfetto JSON (load it at ui.perfetto.dev);
compile events fired by the engines appear as instant markers on the same
timeline.  ``--emit-metrics F`` dumps a JSON sidecar with the per-module
wall times and the process-wide compile counts from
:mod:`repro.obs.compile_guard` — the "did this PR add a retrace?" artifact.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

MODULES = [
    "table1_compression",
    "table2_strategies",
    "fig2_quantization",
    "fig6_clusters",
    "fig10_commercial",
    "fig11_system",
    "fig12_endtoend",
    "fig13_bearing",
    "comm_volume",
    "fleet_scale",
    "host_throughput",
]


def _derived(row: dict) -> str:
    for k in ("acc", "acc_scheduled", "total_uj", "windows_per_s",
              "payloads_per_s", "reduction_x", "completed_frac",
              "wire_bytes_per_dev", "volume_frac"):
        if k in row:
            return f"{k}={row[k]:.4f}" if isinstance(row[k], float) \
                else f"{k}={row[k]}"
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single module (substring match)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes for CI smoke (modules whose run() "
                         "takes a quick kwarg)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="result JSON path (default: "
                         "experiments/bench_results.json)")
    ap.add_argument("--emit-metrics", default=None, metavar="FILE",
                    help="also write a JSON metrics dump (per-module wall "
                         "times + repro.obs compile counts)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record a Chrome-trace/Perfetto JSON of the run "
                         "(one span per benchmark module)")
    args = ap.parse_args()

    from repro.obs import compile_counts, trace as obs_trace
    if args.trace_out:
        obs_trace.enable()

    import importlib
    all_rows: list[dict] = []
    failed: list[str] = []
    wall: dict[str, float] = {}
    print("name,us_per_call,derived")
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        t0 = time.time()
        try:
            with obs_trace.span(f"bench.{modname}", cat="bench",
                                args={"quick": args.quick}):
                mod = importlib.import_module(f"benchmarks.{modname}")
                if args.quick and \
                        "quick" in inspect.signature(mod.run).parameters:
                    rows = mod.run(quick=True)
                else:
                    rows = mod.run()
        except Exception as e:  # keep the harness alive per-module ...
            print(f"{modname}/ERROR,0,{type(e).__name__}:{e}")
            failed.append(modname)          # ... but fail the run at the end
            continue
        for row in rows:
            print(f"{row['name']},{row.get('us_per_call', 0.0):.1f},"
                  f"{_derived(row)}")
        all_rows.extend(rows)
        wall[modname] = round(time.time() - t0, 1)
        all_rows.append({"name": f"_meta/{modname}",
                         "wall_s": wall[modname]})

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "experiments", "bench_results.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# wrote {os.path.normpath(out)}")

    if args.trace_out:
        obs_trace.export_chrome_trace(args.trace_out)
        print(f"# wrote {args.trace_out} (load at ui.perfetto.dev)")
    if args.emit_metrics:
        dump = {"wall_s": wall, "compile_counts": compile_counts(),
                "quick": args.quick, "failed": failed}
        os.makedirs(os.path.dirname(os.path.abspath(args.emit_metrics)),
                    exist_ok=True)
        with open(args.emit_metrics, "w") as f:
            json.dump(dump, f, indent=1)
        print(f"# wrote {args.emit_metrics}")
    if failed:
        sys.exit(f"benchmarks raised: {', '.join(failed)}")


if __name__ == "__main__":
    main()
