"""Paper Fig. 12/17: end-to-end accuracy of Seeker vs the baselines.

Baseline-1: full-precision DNN, fully powered (upper bound).
Baseline-2 (EAP): power-aware quantized DNN, fully powered.
Baseline-3 (Origin-like): EH store-and-execute WITHOUT coreset offload
   (unfinished inferences are dropped — the paper's [47]).
Seeker: full decision flow with coreset offload + recovery + ensemble.

Scheduled-accuracy = correct / all scheduled windows (drops count against).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.seeker_har import HAR
from repro.core import TABLE2_COSTS, harvest_trace
from repro.core.decision import decision_energy
from repro.core.energy import supercap_step
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_apply, har_apply_quantized
from repro.serving import seeker_simulate

from .common import (accuracy, trained_generator, trained_har,
                     trained_host_recovered)


def _origin_like(wins, labels, harvest):
    """EH baseline: quantized DNN on-node when affordable, else DROP."""
    params, _, _ = trained_har()
    e_dnn = float(decision_energy(TABLE2_COSTS)[2])
    stored = 50.0
    correct = 0
    for i in range(len(labels)):
        stored = float(supercap_step(jnp.asarray(stored), harvest[i], 0.0))
        if stored >= e_dnn:
            stored -= e_dnn
            pred = int(jnp.argmax(har_apply_quantized(
                params, wins[i:i + 1], 16)[0]))
            correct += int(pred == int(labels[i]))
    return correct / len(labels)


def run() -> list[dict]:
    params, x, y = trained_har()
    host = trained_host_recovered()
    gen = trained_generator()
    key = jax.random.PRNGKey(0)
    wins, labels = har_stream(key, 128)
    harvest = harvest_trace(key, 128, "rf")
    rows = [
        {"name": "fig12/baseline1_full_dnn_full_power", "us_per_call": 0.0,
         "acc_scheduled": accuracy(params, x, y)},
        {"name": "fig12/baseline2_eap_full_power", "us_per_call": 0.0,
         "acc_scheduled": accuracy(params, x, y, har_apply_quantized,
                                   bits=12)},
        {"name": "fig12/baseline3_origin_like_EH", "us_per_call": 0.0,
         "acc_scheduled": _origin_like(wins, labels, harvest)},
    ]
    res = seeker_simulate(wins, labels, harvest, signatures=class_signatures(),
                          qdnn_params=params, host_params=host,
                          gen_params=gen, har_cfg=HAR)
    rows.append({"name": "fig12/seeker_EH", "us_per_call": 0.0,
                 "acc_scheduled": float(res["accuracy_scheduled"]),
                 "acc_completed": float(res["accuracy_completed"]),
                 "completed_frac": float(res["completed_frac"])})
    return rows
