"""Paper Fig. 6: accuracy vs number of clusters (k); also emits the
per-class AAC table used by activity-aware construction (§5.2)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.har import har_apply

from .common import recover_cluster_batch, trained_har, trained_host_recovered

KS = (4, 6, 8, 10, 12, 16)
AAC_TABLE_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                              "aac_table.json")


def run() -> list[dict]:
    _params, x, y = trained_har()
    host = trained_host_recovered()
    rows = []
    per_class = np.zeros((12, len(KS)))
    for ki, k in enumerate(KS):
        xr = recover_cluster_batch(x, k=k)
        preds = jnp.argmax(har_apply(host, xr), -1)
        acc = float(jnp.mean(preds == y))
        for cl in range(12):
            mask = np.asarray(y == cl)
            if mask.sum():
                per_class[cl, ki] = float(np.mean(np.asarray(preds == y)[mask]))
        rows.append({"name": f"fig6/k{k}", "us_per_call": 0.0, "k": k,
                     "acc": acc})
    # persist the AAC lookup table (used by repro.core.aac at runtime)
    os.makedirs(os.path.dirname(AAC_TABLE_PATH), exist_ok=True)
    with open(AAC_TABLE_PATH, "w") as f:
        json.dump({"ks": list(KS), "acc": per_class.tolist()}, f)
    return rows
