"""Shared benchmark plumbing: the trained HAR/bearing classifiers, trained
recovery generator, timing helper, and CSV emission.

Every benchmark module exposes ``run() -> list[dict]`` rows; benchmarks/run.py
aggregates them into the ``name,us_per_call,derived`` CSV contract plus a
human-readable table per paper artifact.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import BEARING, HAR
from repro.core.recovery import (DiscriminatorParams, GeneratorParams,
                                 discriminator_apply, generator_apply,
                                 init_discriminator, init_generator)
from repro.data.sensors import (bearing_dataset, class_signatures,
                                har_dataset)
from repro.models.har import HARConfig, har_apply, har_init

__all__ = ["trained_har", "trained_bearing", "trained_generator", "timeit_us",
           "accuracy", "train_classifier"]


def train_classifier(cfg: HARConfig, dataset_fn, steps: int = 400,
                     n: int = 1536, lr: float = 3e-2, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = har_init(key, cfg)
    xs, ys = dataset_fn(jax.random.fold_in(key, 1), n)

    def loss_fn(p, x, y):
        logp = jax.nn.log_softmax(har_apply(p, x))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        idx = jax.random.randint(jax.random.fold_in(key, 100 + i), (64,),
                                 0, xs.shape[0])
        params, _ = step(params, xs[idx], ys[idx])
    return params


@functools.lru_cache(maxsize=None)
def trained_har():
    params = train_classifier(HAR, har_dataset)
    x, y = har_dataset(jax.random.PRNGKey(2), 512)
    return params, x, y


@functools.lru_cache(maxsize=None)
def trained_bearing():
    fn = lambda k, n: bearing_dataset(k, n, t=BEARING.window)
    params = train_classifier(BEARING, fn, steps=500)
    x, y = bearing_dataset(jax.random.PRNGKey(2), 512, t=BEARING.window)
    return params, x, y


@functools.lru_cache(maxsize=None)
def trained_generator(t: int = 60, channels: int = 3, steps: int = 300):
    """Adversarially train the recovery generator (paper A.1) on HAR data."""
    key = jax.random.PRNGKey(0)
    gen = init_generator(key, t, channels)
    disc = init_discriminator(key, t, channels)
    xs, _ = har_dataset(jax.random.fold_in(key, 1), 512, t=t,
                        channels=channels)

    def gen_windows(g, k, n):
        noise = jax.random.normal(k, (n, 16))
        mean = jnp.mean(xs[:n], axis=1)
        var = jnp.var(xs[:n], axis=1)
        return jax.vmap(lambda nz, m, v: generator_apply(g, nz, m, v))(
            noise, mean, var)

    def d_loss(d, g, k, n=64):
        fake = gen_windows(g, k, n)
        real = xs[jax.random.randint(k, (n,), 0, xs.shape[0])]
        ls_real = discriminator_apply(d, real)
        ls_fake = discriminator_apply(d, fake)
        return (jnp.mean(jax.nn.softplus(-ls_real))
                + jnp.mean(jax.nn.softplus(ls_fake)))

    def g_loss(g, d, k, n=64):
        fake = gen_windows(g, k, n)
        # non-saturating GAN loss + moment matching stabilizer
        adv = jnp.mean(jax.nn.softplus(-discriminator_apply(d, fake)))
        mm = jnp.mean((jnp.mean(fake, 1) - jnp.mean(xs[:n], 1)) ** 2)
        return adv + 10.0 * mm

    @jax.jit
    def step(g, d, k):
        k1, k2 = jax.random.split(k)
        dl, dg = jax.value_and_grad(d_loss)(d, g, k1)
        d = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, d, dg)
        gl, gg = jax.value_and_grad(g_loss)(g, d, k2)
        g = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, g, gg)
        return g, d

    for i in range(steps):
        gen, disc = step(gen, disc, jax.random.fold_in(key, i))
    return gen


def recover_cluster_batch(x, k: int = 12, seed: int = 0):
    """Per-channel cluster coresets + recovery for a window batch."""
    from repro.core.coreset import channel_cluster_coresets
    from repro.core.recovery import recover_cluster_window
    keys = jax.random.split(jax.random.PRNGKey(seed), x.shape[0])

    def rec(w, kk):
        cs = channel_cluster_coresets(w, k=k, iters=4)
        return recover_cluster_window(cs, kk, x.shape[1])

    return jax.jit(jax.vmap(rec))(x, keys)


def finetune_on(params, xs, ys, steps: int = 150, lr: float = 2e-2,
                seed: int = 7):
    """Fine-tune a classifier on a transformed window set (the paper's
    'retrain the DNN models to recognize the compressed representation')."""
    key = jax.random.PRNGKey(seed)

    def loss_fn(p, x, y):
        logp = jax.nn.log_softmax(har_apply(p, x))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)

    for i in range(steps):
        idx = jax.random.randint(jax.random.fold_in(key, i), (64,),
                                 0, xs.shape[0])
        params = step(params, xs[idx], ys[idx])
    return params


@functools.lru_cache(maxsize=None)
def trained_host_recovered(k: int = 12):
    """Host-side classifier fine-tuned on recovered-coreset windows
    (cluster + generator recoveries mixed), starting from the raw net."""
    params, _, _ = trained_har()
    key = jax.random.PRNGKey(11)
    xs, ys = har_dataset(key, 1024)
    x_cluster = recover_cluster_batch(xs, k=k)
    gen = trained_generator()
    from repro.core.coreset import importance_coreset
    from repro.core.recovery import recover_sampling_window
    keys = jax.random.split(key, xs.shape[0])

    def rec_s(w, kk):
        sc = importance_coreset(w, 20, kk)
        return recover_sampling_window(gen, sc, kk, xs.shape[1])

    x_sampling = jax.jit(jax.vmap(rec_s))(xs, keys)
    x_mix = jnp.concatenate([x_cluster, x_sampling, xs], axis=0)
    y_mix = jnp.concatenate([ys, ys, ys], axis=0)
    return finetune_on(params, x_mix, y_mix)


def timeit_us(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def accuracy(params, x, y, apply=har_apply, **kw) -> float:
    return float(jnp.mean(jnp.argmax(apply(params, x, **kw), -1) == y))
