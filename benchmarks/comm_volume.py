"""Distributed comm-volume bench (ours): coreset codecs on the collectives.

(1) Gradient DP all-reduce: dense psum vs Seeker top-k coreset payload —
    wire bytes from the lowered HLO of both train steps on an 8-way DP mesh
    (subprocess; this process stays single-device).
(2) Edge->host activation offload: raw windows vs quantized cluster-coreset
    payload bytes through collective_permute (analytic + codec roundtrip).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.core.compression import (CompressionConfig, compress_activation,
                                    decompress_activation,
                                    wire_bytes_dense_psum,
                                    wire_bytes_kmeans1d,
                                    wire_bytes_topk_allgather)

_SUBPROC = """
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import sharding as shd
from repro.core.compression import CompressionConfig
from repro.data.lm import LMTask, lm_batches
from repro.launch.hlo_analysis import analyze_hlo
from repro.models.config import ModelConfig
from repro.train import (TrainHyper, init_train_state,
                         make_compressed_train_step, make_train_step)

from repro.sharding import make_mesh_compat

mesh = make_mesh_compat((8,), ("data",))
cfg = ModelConfig(name="t", vocab=256, d_model=128, n_layers=4, n_heads=8,
                  n_kv=4, d_ff=512, dtype=jnp.float32)
hyper = TrainHyper()
ccfg = CompressionConfig(topk_ratio=1/64, min_size=1024)
task = LMTask(vocab=256, seq_len=128, batch=16)
batch = lm_batches(task, 0)
with shd.use_sharding(mesh, shd.DP_TP_RULES):
    state = jax.eval_shape(lambda: init_train_state(
        jax.random.PRNGKey(0), cfg, hyper, ccfg))
    sh_state = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), state)
    sh_batch = {"tokens": NamedSharding(mesh, P("data"))}
    dense = make_train_step(cfg, hyper)
    state_d = {k: v for k, v in state.items() if k != "ef"}
    sh_d = {k: v for k, v in sh_state.items() if k != "ef"}
    l_dense = jax.jit(dense, in_shardings=(sh_d, sh_batch)).lower(state_d, batch)
    comp = make_compressed_train_step(cfg, hyper, ccfg, mesh, ("data",))
    l_comp = jax.jit(comp).lower(state, batch)
a = analyze_hlo(l_dense.compile().as_text())
b = analyze_hlo(l_comp.compile().as_text())
print(json.dumps({"dense": a.collective_bytes, "comp": b.collective_bytes,
                  "dense_total": a.total_collective_bytes,
                  "comp_total": b.total_collective_bytes}))
"""


def _grad_rows() -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_SUBPROC)],
                         capture_output=True, text=True, timeout=560, env=env)
    if out.returncode != 0:
        return [{"name": "comm/grad_compression_ERROR", "us_per_call": 0.0,
                 "error": out.stderr[-400:]}]
    d = json.loads(out.stdout.strip().splitlines()[-1])
    return [
        {"name": "comm/grad_dense_psum", "us_per_call": 0.0,
         "wire_bytes_per_dev": d["dense_total"]},
        {"name": "comm/grad_coreset_topk", "us_per_call": 0.0,
         "wire_bytes_per_dev": d["comp_total"],
         "reduction_x": d["dense_total"] / max(d["comp_total"], 1)},
    ]


def run() -> list[dict]:
    rows = _grad_rows()

    # analytic accounting at fleet scale (tinyllama grads over 32-way DP)
    n = 1_100_048_384
    rows.append({"name": "comm/fleet_dense_psum_1.1B_dp32", "us_per_call": 0.0,
                 "wire_bytes_per_dev": wire_bytes_dense_psum(n, 32)})
    rows.append({"name": "comm/fleet_topk64_1.1B_dp32", "us_per_call": 0.0,
                 "wire_bytes_per_dev": wire_bytes_topk_allgather(n, 32, 1 / 64),
                 "reduction_x": wire_bytes_dense_psum(n, 32)
                 / wire_bytes_topk_allgather(n, 32, 1 / 64)})

    # edge->host activation offload codec (paper C1/C2 on the pod axis)
    key = jax.random.PRNGKey(0)
    act = jax.random.normal(key, (64, 60, 3))
    ccfg = CompressionConfig()
    cs = compress_activation(act, ccfg)
    rec = decompress_activation(cs, act.shape)
    err = float(jnp.mean(jnp.abs(rec - act)) / jnp.std(act))
    raw_bytes = act.size * 2   # bf16 wire
    km = wire_bytes_kmeans1d(act.size)
    rows.append({"name": "comm/edge_host_activation_kmeans", "us_per_call": 0.0,
                 "wire_bytes": km, "raw_bytes": raw_bytes,
                 "reduction_x": raw_bytes / km, "rel_err": err})
    return rows
