"""Paper Fig. 13/15 + A.2: predictive maintenance (bearing fault).

Energy-aware-only AAC (no class conditioning), wider windows, more clusters
(paper: 15-20 for the 48 kHz CWRU data).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import BEARING, SYSTEM
from repro.core.coreset import cluster_payload_bytes, raw_payload_bytes
from repro.data.sensors import bearing_dataset
from repro.models.har import har_apply_quantized

from .common import (accuracy, finetune_on, recover_cluster_batch,
                     trained_bearing)


def run() -> list[dict]:
    params, x, y = trained_bearing()
    t = x.shape[1]
    acc_full = accuracy(params, x, y)
    rows = [{"name": "fig13/full_power", "us_per_call": 0.0, "acc": acc_full}]
    rows.append({"name": "fig13/quant16_edge", "us_per_call": 0.0,
                 "acc": accuracy(params, x, y, har_apply_quantized, bits=16)})
    # host net fine-tuned on recovered bearing windows (paper A.2: the
    # bearing data needs 15-20 clusters)
    xs_tr, ys_tr = bearing_dataset(jax.random.PRNGKey(9), 768, t=t)
    for k in (12, SYSTEM.bearing_clusters, 24):
        host = finetune_on(params, recover_cluster_batch(xs_tr, k=k), ys_tr)
        xr = recover_cluster_batch(x, k=k, seed=1)
        rows.append({
            "name": f"fig13/recovered_coreset_k{k}", "us_per_call": 0.0,
            "acc": accuracy(host, xr, y),
            "reduction_x": raw_payload_bytes(t) / cluster_payload_bytes(k),
        })
    return rows
