"""Compare a benchmark run against the checked-in baseline.

``PYTHONPATH=src python -m benchmarks.compare CURRENT [--baseline F]
[--rtol R] [--timing-rtol R]``

Reads two ``benchmarks.run`` result JSONs (lists of row dicts keyed by
``name``) and exits non-zero when the current run regresses:

* **deterministic metrics** (``completed_frac``, ``reduction_x``,
  ``fleet_accuracy``, byte counts, lane totals, ``bitwise_equal`` ...) must
  match the baseline within ``--rtol`` (default 1e-6) — these are pure
  functions of the seeded simulation, so any drift is a real behaviour
  change, not noise; numeric LISTS (the mixed-fleet per-task splits such as
  ``completed_by_task``/``accuracy_by_task``) are compared element-wise at
  the same tolerance;
* **timing metrics** (``us_per_call``, ``windows_per_s``,
  ``payloads_per_s``, ``speedup_x``, ``wall_s``) are noisy and only checked
  *directionally*: a slowdown beyond ``--timing-rtol`` (default 0.5, i.e.
  50%) fails; getting faster never does;
* a baseline row whose ``name`` is missing from the current run is a
  regression (a benchmark silently disappeared); NEW rows in the current
  run are fine — they become baseline the next time it is regenerated.

Regenerate the baseline after an intentional change with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
      PYTHONPATH=src python -m benchmarks.run --quick \
      --out benchmarks/BENCH_baseline.json

and commit the diff alongside the change that explains it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Noisy wall-clock observables: direction-aware, loose tolerance.  "Bigger
# is better" for rates/speedups, "smaller is better" for times.
TIMING_BIGGER_BETTER = {"windows_per_s", "payloads_per_s", "speedup_x",
                        "completed_gain_x"}
TIMING_SMALLER_BETTER = {"us_per_call", "wall_s"}
# Machine-/run-dependent context fields: reported, never compared.
SKIP = {"name", "rss_mb", "devices", "nodes", "n_payloads"}

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "BENCH_baseline.json")


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        return {row["name"]: row for row in json.load(f)}


def compare(current: dict[str, dict], baseline: dict[str, dict],
            rtol: float, timing_rtol: float) -> list[str]:
    """Returns the list of regression messages (empty = pass)."""
    problems = []
    for name, base_row in sorted(baseline.items()):
        cur_row = current.get(name)
        if cur_row is None:
            problems.append(f"{name}: row missing from current run")
            continue
        for key, base in base_row.items():
            if key in SKIP or key not in cur_row:
                continue
            cur = cur_row[key]
            if isinstance(base, bool) or isinstance(cur, bool):
                if bool(cur) != bool(base):
                    problems.append(f"{name}.{key}: {cur} != {base}")
                continue
            if (isinstance(base, list)
                    and all(isinstance(x, (int, float))
                            and not isinstance(x, bool) for x in base)):
                # per-task vectors (completed_by_task, accuracy_by_task, ...)
                # compare element-wise at the deterministic tolerance
                if not isinstance(cur, list) or len(cur) != len(base):
                    problems.append(
                        f"{name}.{key}: shape changed, {cur!r} vs {base!r}")
                    continue
                for i, (c, b) in enumerate(zip(cur, base)):
                    tol = rtol * max(abs(b), 1.0)
                    if abs(c - b) > tol:
                        problems.append(
                            f"{name}.{key}[{i}]: {c!r} != baseline {b!r} "
                            f"(|diff| {abs(c - b):.4g} > rtol {rtol:g})")
                continue
            if not isinstance(base, (int, float)):
                if cur != base:
                    problems.append(f"{name}.{key}: {cur!r} != {base!r}")
                continue
            if key in TIMING_BIGGER_BETTER:
                if cur < base * (1.0 - timing_rtol):
                    problems.append(
                        f"{name}.{key}: {cur:.4g} < {base:.4g} "
                        f"(-{100 * (1 - cur / base):.0f}%, "
                        f"allowed -{100 * timing_rtol:.0f}%)")
            elif key in TIMING_SMALLER_BETTER:
                if base > 0 and cur > base * (1.0 + timing_rtol):
                    problems.append(
                        f"{name}.{key}: {cur:.4g} > {base:.4g} "
                        f"(+{100 * (cur / base - 1):.0f}%, "
                        f"allowed +{100 * timing_rtol:.0f}%)")
            else:                       # deterministic: tight relative match
                tol = rtol * max(abs(base), 1.0)
                if abs(cur - base) > tol:
                    problems.append(
                        f"{name}.{key}: {cur!r} != baseline {base!r} "
                        f"(|diff| {abs(cur - base):.4g} > rtol {rtol:g})")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="bench_results.json of the current run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON (default: benchmarks/"
                         "BENCH_baseline.json)")
    ap.add_argument("--rtol", type=float, default=1e-6,
                    help="relative tolerance for deterministic metrics")
    ap.add_argument("--timing-rtol", type=float, default=0.5,
                    help="allowed fractional slowdown for timing metrics")
    args = ap.parse_args()

    current = _rows(args.current)
    baseline = _rows(args.baseline)
    problems = compare(current, baseline, args.rtol, args.timing_rtol)

    new = sorted(set(current) - set(baseline))
    if new:
        print(f"# {len(new)} new row(s) not in baseline: "
              + ", ".join(new[:8]) + ("..." if len(new) > 8 else ""))
    if problems:
        print(f"REGRESSION: {len(problems)} metric(s) regressed vs "
              f"{os.path.basename(args.baseline)}")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"OK: {len(baseline)} baseline row(s) matched "
          f"({len(current)} current)")


if __name__ == "__main__":
    main()
