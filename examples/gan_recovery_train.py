"""Train the importance-sampling recovery GAN (paper §3.2.2 + appendix A.1).

    PYTHONPATH=src python examples/gan_recovery_train.py [--steps 400]

Generator g(noise, mean, var) synthesizes the samples that importance
sampling dropped; the discriminator drives realism; transmitted samples are
written back verbatim.  Reports the paper's A.1 metric: correlation of the
recovered signal with the original (paper: >=0.9 typical, ~0.6 worst-case).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.seeker_har import HAR
from repro.core import importance_coreset, pearson
from repro.core.recovery import (discriminator_apply, generator_apply,
                                 init_discriminator, init_generator,
                                 recover_sampling_window)
from repro.data.sensors import har_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    key = jax.random.PRNGKey(0)
    t, c = HAR.window, HAR.channels
    xs, _ = har_dataset(jax.random.fold_in(key, 1), 768)

    gen = init_generator(key, t, c)
    disc = init_discriminator(key, t, c)
    n_gen = sum(p.size for p in jax.tree_util.tree_leaves(gen))
    print(f"generator: {n_gen/1e3:.0f}k params "
          f"(paper: 'few hundred thousand')")

    def synth(g, k, n=64):
        noise = jax.random.normal(k, (n, 16))
        batch = xs[jax.random.randint(k, (n,), 0, xs.shape[0])]
        mean, var = jnp.mean(batch, 1), jnp.var(batch, 1)
        fake = jax.vmap(lambda nz, m, v: generator_apply(g, nz, m, v))(
            noise, mean, var)
        return fake, batch

    def d_loss(d, g, k):
        fake, real = synth(g, k)
        return (jnp.mean(jax.nn.softplus(-discriminator_apply(d, real)))
                + jnp.mean(jax.nn.softplus(discriminator_apply(d, fake))))

    def g_loss(g, d, k):
        fake, real = synth(g, k)
        adv = jnp.mean(jax.nn.softplus(-discriminator_apply(d, fake)))
        # moment + spectrum matching stabilizers (paper: conditioning on
        # first/second order moments of the signal)
        mm = jnp.mean((jnp.mean(fake, 1) - jnp.mean(real, 1)) ** 2)
        sm = jnp.mean((jnp.abs(jnp.fft.rfft(fake, axis=1))
                       - jnp.abs(jnp.fft.rfft(real, axis=1))) ** 2)
        return adv + 10.0 * mm + 0.5 * sm

    @jax.jit
    def step(g, d, k):
        k1, k2 = jax.random.split(k)
        dl, dg = jax.value_and_grad(d_loss)(d, g, k1)
        d = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, d, dg)
        gl, gg = jax.value_and_grad(g_loss)(g, d, k2)
        g = jax.tree_util.tree_map(lambda a, b: a - 1e-3 * b, g, gg)
        return g, d, dl, gl

    for i in range(args.steps):
        gen, disc, dl, gl = step(gen, disc, jax.random.fold_in(key, i))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:4d}  d_loss {float(dl):.3f}  g_loss {float(gl):.3f}")

    # evaluate: recover windows and measure correlation with the original
    test, _ = har_dataset(jax.random.fold_in(key, 2), 64)
    corrs = []
    for i in range(64):
        kk = jax.random.fold_in(key, 1000 + i)
        sc = importance_coreset(test[i], 20, kk)
        rec = recover_sampling_window(gen, sc, kk, t)
        corrs.append(float(jnp.mean(jax.vmap(
            lambda a, b: pearson(a, b), in_axes=1)(rec, test[i]))))
    corrs = jnp.asarray(corrs)
    print(f"\nrecovered-vs-original correlation: median "
          f"{float(jnp.median(corrs)):.3f}, worst {float(jnp.min(corrs)):.3f}"
          f"  (paper A.1: >=0.9 typical, ~0.6 worst)")


if __name__ == "__main__":
    main()
