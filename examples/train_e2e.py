"""End-to-end training driver (deliverable b): trains a ~100M-param LM for a
few hundred steps on CPU with the full production stack — sharded train step,
checkpointing, simulated preemption + restart, and (optionally) Seeker
gradient-coreset compression over the DP axis.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--compress]

The model is a width-reduced tinyllama-family config (~large enough to be a
real training run, small enough for CPU).  Loss on the synthetic-template LM
task drops from ~ln(V) to well below it within a couple hundred steps.
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig
from repro.data.lm import LMTask, lm_batches
from repro.models.config import ModelConfig
from repro.train import (TrainHyper, TrainLoopConfig, init_train_state,
                         make_compressed_train_step, make_train_step,
                         run_training)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--compress", action="store_true",
                    help="Seeker coreset gradient compression (needs >1 dev)")
    ap.add_argument("--params-m", type=int, default=100,
                    help="target model size in millions")
    args = ap.parse_args()

    # ~100M params: 12L x 512d x 8H, 32k vocab, llama-style
    d = 512 if args.params_m >= 50 else 256
    cfg = ModelConfig(name="e2e-100m", vocab=32_000, d_model=d, n_layers=12,
                      n_heads=8, n_kv=4, d_ff=4 * d, mlp="swiglu",
                      dtype=jnp.float32, tie_embeddings=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    hyper = TrainHyper(peak_lr=1e-3, warmup=20, total_steps=args.steps)
    # CPU-sized token budget; on accelerators raise seq/batch freely
    task = LMTask(vocab=cfg.vocab, seq_len=128, batch=4)
    ccfg = CompressionConfig() if args.compress else None
    state = init_train_state(jax.random.PRNGKey(0), cfg, hyper, ccfg)

    if args.compress:
        from repro.sharding import make_mesh_compat
        mesh = make_mesh_compat((jax.device_count(),), ("data",))
        step = jax.jit(make_compressed_train_step(cfg, hyper, ccfg, mesh,
                                                  dp_axes=("data",)))
    else:
        step = jax.jit(make_train_step(cfg, hyper))

    ckpt_dir = os.path.join(tempfile.gettempdir(), "seeker_e2e_ckpt")
    loop = TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=ckpt_dir,
        ckpt_every=max(args.steps // 4, 10),
        log_every=max(args.steps // 20, 1),
        preempt_at=(args.steps // 2,),         # simulated preemption mid-run
    )
    t0 = time.time()
    state, log = run_training(state, step, lambda s: lm_batches(task, s), loop)
    dt = time.time() - t0
    losses = [(m["step"], m["loss"]) for m in log if "loss" in m]
    events = [m for m in log if "event" in m]
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps * task.batch * task.seq_len / dt:.0f} tok/s)")
    print(f"loss: {losses[0][1]:.3f} (step {losses[0][0]}) -> "
          f"{losses[-1][1]:.3f} (step {losses[-1][0]})")
    print(f"fault-tolerance events: {events}")
    assert losses[-1][1] < losses[0][1], "loss did not decrease!"


if __name__ == "__main__":
    main()
