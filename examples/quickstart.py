"""Quickstart: Seeker's coreset pipeline in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds both coreset kinds from a sensor window, shows the wire payloads
(the paper's 240 B -> 42 B arithmetic), recovers the window on the "host",
and runs the energy-aware decision flow over a harvested-energy trace.
"""
import jax
import jax.numpy as jnp

from repro.core import (TABLE2_COSTS, choose_decision, cluster_payload_bytes,
                        harvest_trace, importance_coreset, memo_decision,
                        predictor_forecast, predictor_init, predictor_update,
                        raw_payload_bytes, sampling_payload_bytes,
                        supercap_step)
from repro.core.coreset import channel_cluster_coresets
from repro.core.recovery import recover_cluster_window
from repro.data.sensors import class_signatures, har_window
from repro.kernels import kmeans_coreset_op, signature_corr_op


def main():
    key = jax.random.PRNGKey(0)

    # --- a sensing window (60 samples @ 50 Hz x 3 IMU channels) ------------
    window = har_window(key, jnp.asarray(4))
    print(f"window: {window.shape}, raw payload/channel = "
          f"{raw_payload_bytes(window.shape[0])} B")

    # --- clustering coreset (paper D3): 12 clusters/channel ----------------
    cs = channel_cluster_coresets(window, k=12, iters=4)
    print(f"cluster coreset: centers {cs.centers.shape}, "
          f"payload/channel = {cluster_payload_bytes(12)} B "
          f"({raw_payload_bytes(60) / cluster_payload_bytes(12):.1f}x smaller)")
    recovered = recover_cluster_window(cs, key, window.shape[0])
    err = float(jnp.mean(jnp.abs(recovered - window)) / jnp.std(window))
    print(f"host recovery (2r-approx): rel err = {err:.3f}")

    # --- importance-sampling coreset (paper D4) -----------------------------
    sc = importance_coreset(window, m=20, key=key)
    print(f"sampling coreset: {sc.indices.shape[0]} points, payload = "
          f"{sampling_payload_bytes(20, channels=3)} B")

    # --- memoization (paper D0) ---------------------------------------------
    memo = memo_decision(window, class_signatures(), threshold=0.95)
    print(f"memoization: hit={bool(memo.hit)} label={int(memo.label)} "
          f"corr={float(memo.max_corr):.3f}")

    # --- the Pallas kernels (paper's coreset engine, interpret mode) --------
    pts = jnp.stack([jnp.linspace(0, 1, 60)[:, None] * 4.0,
                     window[:, :1]], axis=-1).reshape(1, 60, 2)
    centers, radii, counts = kmeans_coreset_op(pts, k=12)
    corr = signature_corr_op(window[None], class_signatures())
    print(f"pallas kmeans engine: {centers.shape}; corr engine: {corr.shape}")

    # --- energy-aware decision flow over an RF harvest trace ---------------
    harvest = harvest_trace(key, 20, "rf")
    stored = jnp.asarray(30.0)
    pred = predictor_init()
    print("\nslot harvest stored decision (0=memo 2=qDNN 3=cluster 4=sample 5=defer)")
    for t in range(10):
        pred = predictor_update(pred, harvest[t])
        out = choose_decision(memo.max_corr * 0.5, stored,
                              predictor_forecast(pred), TABLE2_COSTS)
        stored = supercap_step(stored, harvest[t], out.spend)
        print(f"{t:4d} {float(harvest[t]):7.1f} {float(stored):6.1f}   "
              f"D{int(out.decision)}")


if __name__ == "__main__":
    main()
