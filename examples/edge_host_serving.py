"""The paper's system end-to-end: an EH-WSN of 3 body sensors + host.

    PYTHONPATH=src python examples/edge_host_serving.py [--source rf]
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 64
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/edge_host_serving.py --fleet 64 --sharded
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 64 \
        --churn 0.3 --chunk 32
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 64 \
        --intermittent
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 24 \
        --host-queue
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 64 \
        --emit-metrics metrics.json --trace-out trace.json

Trains the HAR classifier, builds the memoization signature bank, then
streams activity windows through the full Seeker decision flow under a
harvested-energy trace, reporting the Fig.11/12-style metrics: completion
fraction, accuracy, decision mix, and communication volume vs raw.

``--fleet N`` instead simulates N independent nodes with heterogeneous
harvest modalities in one batched scan (the fleet engine), reporting
per-modality completion and fleet-level wire volume.  ``--churn FRAC``
makes the fleet intermittent (duty-cycled per-node alive traces: nodes
brown out, freeze, rejoin); ``--chunk SLOTS`` streams the window stream in
segments through the resume contract instead of one long scan.
``--intermittent`` scales the harvest down to scarcity, turns on the
supercap brown-out hysteresis, and runs the staged intermittent-inference
lane (docs/ENERGY_MODEL.md): DEFER slots become staged progress that
suspends across brown-outs and emits D7 early exits / D8 full-depth
results slots later.

``--host-queue`` streams a *churny* fleet trace — nodes dropping in and out
slot to slot, periodically re-transmitting identical payloads — through the
host-tier serving subsystem (``repro.host``: QoS-deadline ring queue, EDF
fixed-shape microbatch scheduler, signature-keyed recovery cache) and
prints deadline-miss and cache-hit rates plus the compile-shape count.

``--emit-metrics FILE`` turns on the ``repro.obs`` telemetry lanes for the
fleet/host run and writes the metric summary JSON (with the host tier:
queue-sojourn and end-to-end QoS percentiles); ``--trace-out FILE`` records
wall-clock spans as Chrome-trace/Perfetto JSON (docs/OBSERVABILITY.md).
"""
import argparse
import collections
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.seeker_har import HAR
from repro.core import (D6_PARTIAL, DEFER, EH_SOURCES, fleet_harvest_traces,
                        fleet_source_assignment, harvest_trace)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_dataset, har_stream
from repro.models.har import har_apply, har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded, seeker_simulate)
from repro.sharding import make_mesh_compat


def train_classifier(key):
    params = har_init(key, HAR)
    xs, ys = har_dataset(jax.random.fold_in(key, 1), 1024)

    def loss_fn(p, x, y):
        logp = jax.nn.log_softmax(har_apply(p, x))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - 3e-2 * b, p, g)

    for i in range(300):
        idx = jax.random.randint(jax.random.fold_in(key, 100 + i), (64,),
                                 0, xs.shape[0])
        params = step(params, xs[idx], ys[idx])
    return params


def fleet_demo(key, params, gen, wins, labels, n_nodes: int,
               sharded: bool = False, churn: float = 0.0, chunk: int = 0,
               intermittent: bool = False, emit_metrics: str | None = None):
    """N heterogeneous nodes in one batched scan: the fleet engine.

    ``sharded`` splits the node axis over every visible device (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get a CPU
    mesh) — same traces, fleet aggregates psum-ed across shards.
    ``churn`` > 0 runs the churny fleet: each node follows a
    duty-cycled alive trace (duty = 1 - churn) and browns out/rejoins
    mid-deployment.  ``chunk`` > 0 streams the windows in chunk-slot
    segments instead of one long scan (bitwise-identical results).
    ``intermittent`` scales the harvest down to scarcity, enables the
    supercap brown-out hysteresis, and runs the staged inference lane
    (D6 suspend / D7 early exit / D8 full depth).
    """
    import time

    from repro.core import (BrownoutConfig, IntermittentConfig,
                            fleet_alive_traces)
    from repro.models.har import har_aux_init
    from repro.serving import seeker_fleet_simulate_streamed

    s = wins.shape[0]
    harvest = fleet_harvest_traces(key, n_nodes, s)
    alive = None
    if churn > 0:
        alive = fleet_alive_traces(key, n_nodes, s, duty=1.0 - churn)
    kw = dict(signatures=class_signatures(), qdnn_params=params,
              host_params=params, gen_params=gen, har_cfg=HAR,
              labels=labels, alive=alive)
    if intermittent:
        harvest = harvest * 0.15          # scarcity: make DEFER the norm
        kw.update(brownout=BrownoutConfig(),
                  intermittent=IntermittentConfig(),
                  aux_params=har_aux_init(jax.random.fold_in(key, 7), HAR))
    if sharded:
        kw["mesh"] = make_mesh_compat((jax.device_count(),), ("data",))
    if emit_metrics:
        kw["telemetry"] = True
    t0 = time.time()
    if chunk > 0:
        res = seeker_fleet_simulate_streamed(wins, harvest, chunk=chunk,
                                             **kw)
    elif sharded:
        res = seeker_fleet_simulate_sharded(wins, harvest, **kw)
    else:
        res = seeker_fleet_simulate(wins, harvest, **kw)
    jax.block_until_ready(res["decisions"])
    dt = time.time() - t0

    decisions = np.asarray(res["decisions"])              # (S, N)
    # a D6 suspension put nothing on the wire yet: not completed
    completed = (decisions != DEFER) & (decisions != D6_PARTIAL)
    correct = (np.asarray(res["preds"]) == np.asarray(labels)[:, None]) \
        & completed & (decisions <= 5)
    print(f"\nfleet of {n_nodes} nodes x {s} slots in {dt:.2f}s "
          f"({n_nodes * s / dt:.0f} windows/sec incl. compile)")
    if chunk > 0:
        print(f"streamed in {res['n_chunks']} chunks of {chunk} slots "
              f"(peak window memory {min(chunk, s) / s:.2f}x one long scan)")
    if alive is not None:
        up = int(res["alive_slots"])
        print(f"churn: nodes up {100 * up / (n_nodes * s):.0f}% of slots "
              f"(duty {1 - churn:.2f}); dead slots DEFER with frozen state "
              f"and rejoin in place")
    if sharded:
        print(f"node axis sharded over {jax.device_count()} devices "
              f"(mesh axes {res['node_axes']}, {res['padded_nodes']} inert "
              f"pad nodes)")
    print(f"decision histogram {np.asarray(res['decision_histogram']).tolist()}"
          f" (alive slots only), fleet accuracy "
          f"{100 * float(res['fleet_accuracy']):.1f}%, completed "
          f"{100 * float(res['completed_frac']):.1f}%")
    if intermittent:
        it_final = res["final_intermittent"]
        print(f"intermittent lane (scarce harvest x0.15, brown-out "
              f"hysteresis on): {int(res['it_full'])} staged full-depth "
              f"(D8), {int(res['it_early'])} early exits (D7), "
              f"{int(np.asarray(it_final.active).sum())} inferences still "
              f"suspended in the carry at end of run; "
              f"{int(res['brownout_slots'])} browned-out slots survived "
              f"with progress frozen in place")
    print("per-modality stats (nodes cycle rf/wifi/piezo/solar):")
    node_src = fleet_source_assignment(n_nodes)
    ladder_comp = completed & (decisions <= 5)
    suffix = " (ladder path)" if intermittent else ""
    for si, src in enumerate(EH_SOURCES):
        sel = node_src == si
        if sel.any():
            n_comp = ladder_comp[:, sel].sum()
            acc = correct[:, sel].sum() / max(n_comp, 1)
            print(f"  {src:6s} {100 * completed[:, sel].mean():5.1f}% "
                  f"completed, {100 * acc:5.1f}% accurate when "
                  f"completed{suffix}")
    wire = float(res["bytes_on_wire"])
    raw = completed.sum() * float(res["raw_bytes_per_window"])
    print(f"bytes on wire: {wire:.0f} vs {raw:.0f} raw-equivalent "
          f"({raw / max(wire, 1e-9):.1f}x reduction)")
    if emit_metrics:
        from repro.obs import metrics_summary
        summary = metrics_summary(res["telemetry_spec"], res["telemetry"])
        with open(emit_metrics, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"wrote telemetry lanes to {emit_metrics}")


def host_queue_demo(key, params, gen, wins, n_nodes: int, args,
                    emit_metrics: str | None = None):
    """Churny fleet -> host-tier serving subsystem (queue/EDF/cache).

    Each node follows an on/off duty cycle (intermittent power) and, while
    on, offloads one coreset payload per slot; a node re-transmits the same
    window for a few consecutive slots (periodic activities), so the host's
    signature cache sees D0-style repetition.  Every 4th node ships a D4
    sampling payload (GAN recovery path); the rest ship D3 cluster coresets.
    """
    import time

    import jax.numpy as jnp

    from repro.core.coreset import channel_cluster_coresets, importance_coreset
    from repro.host import (HostServeConfig, cluster_entries, host_ensemble,
                            host_serve_slot, host_server_init,
                            host_server_stats, sampling_entries,
                            serve_trace_count)
    from repro.serving import encode_wire_coresets, encode_wire_samples

    slots, pool = args.windows, min(args.windows, 32)
    cfg = HostServeConfig(
        channels=HAR.channels, k=12, m=20, t=HAR.window,
        n_classes=HAR.n_classes, n_nodes=n_nodes,
        batch_size=args.host_batch, queue_capacity=4 * n_nodes,
        cache_capacity=4 * pool, qos_slots=args.qos,
        telemetry=bool(emit_metrics))

    # pre-encode both payload kinds for the window pool (the edge side)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=cfg.k, iters=4))(wins[:pool])
    c_pool = cluster_entries(encode_wire_coresets(centers, radii, counts),
                             cfg.m)
    sc = jax.vmap(lambda w, k_: importance_coreset(w, cfg.m, k_))(
        wins[:pool], jax.random.split(key, pool))
    s_pool = sampling_entries(
        encode_wire_samples(sc.indices, sc.values, sc.mean, sc.var), cfg.k)

    rng = np.random.RandomState(0)
    duty = rng.uniform(0.3, 0.9, size=n_nodes)        # per-node duty cycle
    phase = rng.randint(0, 8, size=n_nodes)
    node_ids = jnp.arange(n_nodes, dtype=jnp.int32)
    is_sampling = node_ids % 4 == 3                   # D4 senders
    state = host_server_init(cfg)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    t0 = time.time()
    ingested = 0
    for s in range(slots):
        # churn: a node is up when its duty-cycled phase says so
        active = (rng.rand(n_nodes) < duty) \
            & (((s + phase) // 4) % 2 == 0)
        # repetition: a node re-sends the same window for 4 slots
        widx = jnp.asarray((node_ids * 7 + (s // 4)) % pool)
        entries = jax.tree_util.tree_map(
            lambda c, sp: jnp.where(
                jnp.reshape(is_sampling, (-1,) + (1,) * (c.ndim - 1)),
                sp[widx], c[widx]),
            c_pool, s_pool)
        ingested += int(active.sum())
        state, _ = host_serve_slot(state, entries, node_ids,
                                   jnp.asarray(active), **kw)
    # drain the backlog with empty ingest slots
    none = jnp.zeros((n_nodes,), bool)
    empty = jax.tree_util.tree_map(lambda a: a[widx], c_pool)
    while host_server_stats(state)["backlog"] > 0:
        state, _ = host_serve_slot(state, empty, node_ids, none, **kw)
    dt = time.time() - t0

    stats = host_server_stats(state, cfg)
    ens = host_ensemble(state)
    print(f"\nhost queue: {n_nodes} churny nodes x {slots} slots "
          f"({ingested} payloads) in {dt:.2f}s "
          f"({ingested / dt:.0f} payloads/sec incl. compile)")
    print(f"  served {stats['served']}, deadline misses "
          f"{stats['deadline_misses']}, overflow drops "
          f"{stats['drops_overflow']} -> deadline-miss rate "
          f"{100 * stats['deadline_miss_rate']:.1f}%, QoS-fail rate "
          f"{100 * stats['qos_fail_rate']:.1f}% "
          f"(bound {cfg.qos_slots} slots, batch {cfg.batch_size})")
    print(f"  cache: {stats['cache_hits']} hits / {stats['cache_misses']} "
          f"misses -> hit rate {100 * stats['cache_hit_rate']:.1f}% "
          f"(bitwise-identical to recomputation)")
    print(f"  compiled serve shapes: {serve_trace_count(cfg)} "
          f"(fixed-shape EDF microbatches; churn never re-traces)")
    answered = np.asarray(ens["counts"]) > 0
    agree = (np.asarray(ens["pred_mean"]) == np.asarray(ens["pred_vote"]))
    agree_pct = 100 * float(agree[answered].mean()) if answered.any() else 0.0
    print(f"  per-node ensemble: {int(answered.sum())}/{n_nodes} nodes "
          f"answered (mean-logit vs majority-vote agreement "
          f"{agree_pct:.0f}% over answered nodes)")
    if emit_metrics:
        print(f"  queue sojourn p50/p95/p99: {stats['sojourn_p50']:.2f}/"
              f"{stats['sojourn_p95']:.2f}/{stats['sojourn_p99']:.2f} slots; "
              f"end-to-end p99 {stats['e2e_p99']:.2f} slots")
        with open(emit_metrics, "w") as f:
            json.dump(stats["telemetry"], f, indent=1)
        print(f"  wrote telemetry lanes to {emit_metrics}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="rf",
                    choices=["rf", "wifi", "piezo", "solar"])
    ap.add_argument("--windows", type=int, default=128)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="simulate N heterogeneous nodes with the fleet "
                         "engine instead of the 3-sensor ensemble")
    ap.add_argument("--sharded", action="store_true",
                    help="with --fleet: shard the node axis over every "
                         "visible device (CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--churn", type=float, default=0.0, metavar="FRAC",
                    help="with --fleet: intermittent fleet — each node "
                         "follows a duty-cycled alive trace with duty "
                         "1-FRAC, browning out and rejoining mid-run")
    ap.add_argument("--intermittent", action="store_true",
                    help="with --fleet: scarce harvest + brown-out "
                         "hysteresis + the staged intermittent-inference "
                         "lane — DEFER slots advance a staged quantized "
                         "DNN that suspends across brown-outs and emits "
                         "D7 early exits / D8 full-depth results "
                         "(docs/ENERGY_MODEL.md)")
    ap.add_argument("--chunk", type=int, default=0, metavar="SLOTS",
                    help="with --fleet: stream windows in SLOTS-slot "
                         "segments through the resume contract instead of "
                         "one long scan (bitwise-identical)")
    ap.add_argument("--host-queue", action="store_true",
                    help="stream a churny fleet trace through the host-tier "
                         "serving subsystem (QoS queue + EDF scheduler + "
                         "recovery cache) and report deadline-miss / "
                         "cache-hit rates")
    ap.add_argument("--host-batch", type=int, default=8,
                    help="host EDF microbatch size (--host-queue)")
    ap.add_argument("--qos", type=int, default=3,
                    help="QoS deadline in slots after arrival (--host-queue)")
    ap.add_argument("--emit-metrics", default=None, metavar="FILE",
                    help="run with telemetry lanes on and write the "
                         "metric summary JSON (fleet or host-queue modes)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record the run as Chrome-trace/Perfetto JSON")
    args = ap.parse_args()

    from repro.obs import trace as obs_trace
    if args.trace_out:
        obs_trace.enable()

    key = jax.random.PRNGKey(0)
    print("training HAR classifier on synthetic MHEALTH ...")
    with obs_trace.span("example.train", cat="example"):
        params = train_classifier(key)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, args.windows)

    try:
        if args.host_queue:
            with obs_trace.span("example.host_queue", cat="example"):
                host_queue_demo(key, params, gen, wins, args.fleet or 16,
                                args, emit_metrics=args.emit_metrics)
            return

        if args.fleet:
            with obs_trace.span("example.fleet", cat="example"):
                fleet_demo(key, params, gen, wins, labels, args.fleet,
                           sharded=args.sharded, churn=args.churn,
                           chunk=args.chunk,
                           intermittent=args.intermittent,
                           emit_metrics=args.emit_metrics)
            return

        with obs_trace.span("example.single_node", cat="example"):
            _single_node_demo(key, params, gen, wins, labels, args)
    finally:
        if args.trace_out:
            obs_trace.export_chrome_trace(args.trace_out)
            print(f"wrote {args.trace_out} (load at ui.perfetto.dev)")


def _single_node_demo(key, params, gen, wins, labels, args):
    harvest = harvest_trace(key, args.windows, args.source)

    print(f"running Seeker over {args.windows} windows on '{args.source}' "
          f"harvest (mean {float(harvest.mean()):.1f} uJ/slot) ...")
    res = seeker_simulate(wins, labels, harvest,
                          signatures=class_signatures(), qdnn_params=params,
                          host_params=params, gen_params=gen, har_cfg=HAR)

    dec = collections.Counter(np.asarray(res["decisions"]).tolist())
    # NB code 5 is DEFER (sense only); Table 2's D5_RAW is a cost ROW,
    # not a reachable decision — see docs/ENERGY_MODEL.md
    names = {0: "D0 memo", 1: "D1 fullDNN", 2: "D2 qDNN", 3: "D3 cluster",
             4: "D4 sampling", 5: "DEFER", 6: "D6 suspend",
             7: "D7 earlyexit", 8: "D8 stagedfull"}
    print("\ndecision mix:")
    for d, n in sorted(dec.items()):
        print(f"  {names[d]:12s} {n:4d}  ({100*n/args.windows:.1f}%)")
    sent = np.asarray(res["decisions"]) != 5
    payload = float(np.mean(np.asarray(res["payload_bytes"])[sent])) if sent.any() else 0
    raw = float(res["raw_bytes"][0]) * HAR.channels
    print(f"\ncompleted:          {float(res['completed_frac'])*100:.1f}%")
    print(f"accuracy(completed): {float(res['accuracy_completed'])*100:.1f}%")
    print(f"mean payload:       {payload:.1f} B vs raw {raw:.0f} B "
          f"({raw/max(payload,1e-9):.1f}x reduction)")


if __name__ == "__main__":
    main()

