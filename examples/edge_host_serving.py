"""The paper's system end-to-end: an EH-WSN of 3 body sensors + host.

    PYTHONPATH=src python examples/edge_host_serving.py [--source rf]
    PYTHONPATH=src python examples/edge_host_serving.py --fleet 64
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/edge_host_serving.py --fleet 64 --sharded

Trains the HAR classifier, builds the memoization signature bank, then
streams activity windows through the full Seeker decision flow under a
harvested-energy trace, reporting the Fig.11/12-style metrics: completion
fraction, accuracy, decision mix, and communication volume vs raw.

``--fleet N`` instead simulates N independent nodes with heterogeneous
harvest modalities in one batched scan (the fleet engine), reporting
per-modality completion and fleet-level wire volume.
"""
import argparse
import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.seeker_har import HAR
from repro.core import (DEFER, EH_SOURCES, fleet_harvest_traces,
                        fleet_source_assignment, harvest_trace)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_dataset, har_stream
from repro.models.har import har_apply, har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded, seeker_simulate)
from repro.sharding import make_mesh_compat


def train_classifier(key):
    params = har_init(key, HAR)
    xs, ys = har_dataset(jax.random.fold_in(key, 1), 1024)

    def loss_fn(p, x, y):
        logp = jax.nn.log_softmax(har_apply(p, x))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - 3e-2 * b, p, g)

    for i in range(300):
        idx = jax.random.randint(jax.random.fold_in(key, 100 + i), (64,),
                                 0, xs.shape[0])
        params = step(params, xs[idx], ys[idx])
    return params


def fleet_demo(key, params, gen, wins, labels, n_nodes: int,
               sharded: bool = False):
    """N heterogeneous nodes in one batched scan: the fleet engine.

    ``sharded`` splits the node axis over every visible device (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get a CPU
    mesh) — same traces, fleet aggregates psum-ed across shards.
    """
    import time

    s = wins.shape[0]
    harvest = fleet_harvest_traces(key, n_nodes, s)
    t0 = time.time()
    if sharded:
        mesh = make_mesh_compat((jax.device_count(),), ("data",))
        res = seeker_fleet_simulate_sharded(
            wins, harvest, signatures=class_signatures(), qdnn_params=params,
            host_params=params, gen_params=gen, har_cfg=HAR, mesh=mesh,
            labels=labels)
    else:
        res = seeker_fleet_simulate(
            wins, harvest, signatures=class_signatures(), qdnn_params=params,
            host_params=params, gen_params=gen, har_cfg=HAR)
    jax.block_until_ready(res["decisions"])
    dt = time.time() - t0

    decisions = np.asarray(res["decisions"])              # (S, N)
    completed = decisions != DEFER
    correct = (np.asarray(res["preds"]) == np.asarray(labels)[:, None]) \
        & completed
    print(f"\nfleet of {n_nodes} nodes x {s} slots in {dt:.2f}s "
          f"({n_nodes * s / dt:.0f} windows/sec incl. compile)")
    if sharded:
        print(f"node axis sharded over {jax.device_count()} devices "
              f"(mesh axes {res['node_axes']}, {res['padded_nodes']} inert "
              f"pad nodes); decision histogram "
              f"{np.asarray(res['decision_histogram']).tolist()}, "
              f"fleet accuracy {100 * float(res['fleet_accuracy']):.1f}%")
    print("per-modality stats (nodes cycle rf/wifi/piezo/solar):")
    node_src = fleet_source_assignment(n_nodes)
    for si, src in enumerate(EH_SOURCES):
        sel = node_src == si
        if sel.any():
            n_comp = completed[:, sel].sum()
            acc = correct[:, sel].sum() / max(n_comp, 1)
            print(f"  {src:6s} {100 * completed[:, sel].mean():5.1f}% "
                  f"completed, {100 * acc:5.1f}% accurate when completed")
    wire = float(res["bytes_on_wire"])
    raw = completed.sum() * float(res["raw_bytes_per_window"])
    print(f"bytes on wire: {wire:.0f} vs {raw:.0f} raw-equivalent "
          f"({raw / max(wire, 1e-9):.1f}x reduction)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="rf",
                    choices=["rf", "wifi", "piezo", "solar"])
    ap.add_argument("--windows", type=int, default=128)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="simulate N heterogeneous nodes with the fleet "
                         "engine instead of the 3-sensor ensemble")
    ap.add_argument("--sharded", action="store_true",
                    help="with --fleet: shard the node axis over every "
                         "visible device (CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    print("training HAR classifier on synthetic MHEALTH ...")
    params = train_classifier(key)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, args.windows)

    if args.fleet:
        fleet_demo(key, params, gen, wins, labels, args.fleet,
                   sharded=args.sharded)
        return

    harvest = harvest_trace(key, args.windows, args.source)

    print(f"running Seeker over {args.windows} windows on '{args.source}' "
          f"harvest (mean {float(harvest.mean()):.1f} uJ/slot) ...")
    res = seeker_simulate(wins, labels, harvest,
                          signatures=class_signatures(), qdnn_params=params,
                          host_params=params, gen_params=gen, har_cfg=HAR)

    dec = collections.Counter(np.asarray(res["decisions"]).tolist())
    names = {0: "D0 memo", 1: "D1 fullDNN", 2: "D2 qDNN", 3: "D3 cluster",
             4: "D4 sampling", 5: "DEFER"}
    print("\ndecision mix:")
    for d, n in sorted(dec.items()):
        print(f"  {names[d]:12s} {n:4d}  ({100*n/args.windows:.1f}%)")
    sent = np.asarray(res["decisions"]) != 5
    payload = float(np.mean(np.asarray(res["payload_bytes"])[sent])) if sent.any() else 0
    raw = float(res["raw_bytes"][0]) * HAR.channels
    print(f"\ncompleted:          {float(res['completed_frac'])*100:.1f}%")
    print(f"accuracy(completed): {float(res['accuracy_completed'])*100:.1f}%")
    print(f"mean payload:       {payload:.1f} B vs raw {raw:.0f} B "
          f"({raw/max(payload,1e-9):.1f}x reduction)")


if __name__ == "__main__":
    main()
