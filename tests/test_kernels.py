"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle,
across shapes and dtypes (the (c) deliverable's kernel validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (fake_quant_op, importance_select_op,
                           kmeans_coreset_op, signature_corr_op)
from repro.kernels import ref


@pytest.mark.parametrize("b", [1, 7, 8, 24])
@pytest.mark.parametrize("n,d", [(60, 4), (32, 2), (64, 8)])
@pytest.mark.parametrize("k", [4, 12, 16])
def test_kmeans_kernel_matches_ref(b, n, d, k, key):
    pts = jax.random.normal(key, (b, n, d))
    c1, r1, n1 = kmeans_coreset_op(pts, k=k, impl="pallas")
    c2, r2, n2 = ref.kmeans_coreset_ref(pts, k=k)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_kernel_dtypes(dtype, key):
    pts = jax.random.normal(key, (8, 60, 4)).astype(dtype)
    c1, r1, n1 = kmeans_coreset_op(pts, k=12, impl="pallas")
    c2, r2, n2 = ref.kmeans_coreset_ref(pts.astype(jnp.float32), k=12)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,t,c", [(4, 60, 3), (8, 48, 1), (13, 64, 5)])
@pytest.mark.parametrize("m", [8, 20])
def test_importance_kernel_matches_ref(b, t, c, m, key):
    w = jax.random.normal(key, (b, t, c))
    i1, v1, w1 = importance_select_op(w, m=m, impl="pallas")
    i2, v2, w2 = ref.importance_select_ref(w, m=m)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("b,l", [(4, 5), (16, 12), (9, 3)])
def test_corr_kernel_matches_ref(b, l, key):
    w = jax.random.normal(key, (b, 60, 3))
    s = jax.random.normal(jax.random.fold_in(key, 1), (l, 60, 3))
    c1 = signature_corr_op(w, s, impl="pallas")
    c2 = ref.signature_corr_ref(w, s)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(jnp.abs(c1) <= 1.0 + 1e-4))


def test_corr_kernel_self_correlation(key):
    w = jax.random.normal(key, (5, 60, 3))
    c = signature_corr_op(w, w, impl="pallas")
    np.testing.assert_allclose(np.asarray(jnp.diag(c)), 1.0, atol=1e-4)


@pytest.mark.parametrize("bits", [8, 12, 16])
@pytest.mark.parametrize("shape", [(33, 70), (4, 60, 3), (256,), (128, 512)])
@pytest.mark.parametrize("per_channel", [False, True])
def test_quant_kernel_matches_ref(bits, shape, per_channel, key):
    x = jax.random.normal(key, shape) * 3
    q1 = fake_quant_op(x, bits, per_channel=per_channel, impl="pallas")
    if per_channel and x.ndim == 1:
        pytest.skip("per-channel needs >=2 dims")
    x2d = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    q2 = ref.fake_quant_ref(x2d, bits, per_channel=per_channel).reshape(shape)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-5, atol=1e-6)


def test_quant_error_bound(key):
    x = jax.random.normal(key, (64, 64))
    for bits in (8, 12, 16):
        q = fake_quant_op(x, bits, impl="pallas")
        scale = float(jnp.max(jnp.abs(x))) / (2 ** (bits - 1) - 1)
        assert float(jnp.max(jnp.abs(q - x))) <= scale / 2 + 1e-6


# ---------------------------------------------------------------------------
# Backend dispatch (ops.py): default impl resolves per backend, and both
# implementations agree wherever the serving path may pick either.
# ---------------------------------------------------------------------------

def test_default_impl_matches_backend():
    from repro.kernels.ops import default_impl
    expect = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert default_impl() == expect


def test_dispatch_impls_agree_on_corr_and_quant(key):
    w = jax.random.normal(key, (6, 60, 3))
    s = jax.random.normal(jax.random.fold_in(key, 1), (12, 60, 3))
    np.testing.assert_allclose(
        np.asarray(signature_corr_op(w, s, impl="ref")),
        np.asarray(signature_corr_op(w, s, impl="pallas")),
        rtol=1e-4, atol=1e-5)
    x = jax.random.normal(key, (4, 60, 3)) * 3
    np.testing.assert_allclose(
        np.asarray(fake_quant_op(x, 12, impl="ref")),
        np.asarray(fake_quant_op(x, 12, impl="pallas")),
        rtol=1e-5, atol=1e-6)


def test_dispatch_ref_is_vmap_and_scan_safe(key):
    """The fleet engine vmaps the quant path and scans the corr path — the
    dispatched default must survive both transforms (interpret-mode Pallas
    historically has not, which is why ref is the off-TPU default)."""
    w = jax.random.normal(key, (5, 60, 3))
    s = jax.random.normal(jax.random.fold_in(key, 1), (4, 60, 3))
    per = jax.vmap(lambda x: fake_quant_op(x[None], 8)[0])(w)
    assert per.shape == w.shape

    def step(carry, win):
        return carry, signature_corr_op(win[None], s)[0]

    _, corr = jax.lax.scan(step, 0, w)
    assert corr.shape == (5, 4)
    np.testing.assert_allclose(np.asarray(corr),
                               np.asarray(signature_corr_op(w, s)),
                               rtol=1e-5, atol=1e-6)
