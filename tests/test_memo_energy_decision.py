"""Memoization, energy model, and D0-D4 decision-flow tests (paper §3.2.1,
§4.1, Table 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (
    D0_MEMO, D1_DNN_FULL, D2_DNN_QUANT, D3_CLUSTER, D4_SAMPLING, D5_RAW,
    DEFER, EnergyCosts, TABLE2_COSTS, choose_decision, decision_energy,
    harvest_trace, memo_decision, pearson, predictor_forecast, predictor_init,
    predictor_update, signature_correlations, supercap_step,
)
from repro.data.sensors import class_signatures, har_window


# --- memoization ------------------------------------------------------------

def test_pearson_bounds_and_extremes(key):
    x = jax.random.normal(key, (64,))
    assert float(pearson(x, x)) == pytest.approx(1.0, abs=1e-5)
    assert float(pearson(x, -x)) == pytest.approx(-1.0, abs=1e-5)
    y = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    assert -1.0 <= float(pearson(x, y)) <= 1.0


def test_memo_hits_on_same_class(key):
    sigs = class_signatures()
    w = har_window(key, jnp.asarray(3), noise=0.05)
    res = memo_decision(w, sigs, threshold=0.8)
    assert bool(res.hit)
    assert int(res.label) == 3


def test_memo_misses_on_noise(key):
    sigs = class_signatures()
    w = jax.random.normal(key, (60, 3))
    res = memo_decision(w, sigs, threshold=0.95)
    assert not bool(res.hit)


# --- energy model -----------------------------------------------------------

def test_table2_energy_ladder():
    """Paper Table 2 ordering: D0 < D4 < D3 < D2 < D1 < raw."""
    c = TABLE2_COSTS
    e = [c.total(i) for i in range(6)]
    assert e[0] < e[4] < e[3] < e[2] < e[1] < e[5]
    assert e[1] == pytest.approx(37.5, abs=0.01)     # paper row D1
    assert e[5] == pytest.approx(70.16, abs=0.01)    # raw


def test_cost_table_single_source_of_truth():
    """The accounting-disagreement regression (ISSUE 5): ``EnergyCosts.total``
    and ``decision_energy`` used to differ — ``total`` dropped ``sense`` on
    the D3/D4 rows, and its index 5 was raw offload while decision code 5 is
    DEFER.  Both now derive from ``decision_costs()``, with the raw row
    named ``D5_RAW``."""
    c = TABLE2_COSTS
    e = decision_energy(c)
    # Table-2 rows 0..4 ARE the decision ladder's costs, bit for bit
    for d in (D0_MEMO, D1_DNN_FULL, D2_DNN_QUANT, D3_CLUSTER, D4_SAMPLING):
        assert c.total(d) == pytest.approx(float(e[d]), abs=1e-6), d
    # the index-5 distinction: DEFER senses only; D5_RAW is the 70.16 µJ
    # raw-transmission baseline (not a scheduler decision)
    assert float(e[DEFER]) == pytest.approx(c.sense, abs=1e-6)
    assert c.total(D5_RAW) == pytest.approx(70.16, abs=0.01)
    assert D5_RAW == DEFER, "indices collide BY NAME only — keep both names"
    # the full ladder through the decision vector too (not just total):
    # DEFER < D0 < D4 < D3 < D2 < D1 < raw
    assert (float(e[DEFER]) < float(e[D0_MEMO]) < float(e[D4_SAMPLING])
            < float(e[D3_CLUSTER]) < float(e[D2_DNN_QUANT])
            < float(e[D1_DNN_FULL]) < c.total(D5_RAW))
    # D3/D4 include the shared sensing cost (the dropped term)
    assert c.total(D3_CLUSTER) == pytest.approx(
        c.sense + c.coreset_cluster + c.tx_coreset, abs=1e-6)
    assert c.total(D4_SAMPLING) == pytest.approx(
        c.sense + c.coreset_sampling + c.tx_coreset, abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(stored=st.floats(0, 200), harvested=st.floats(0, 500),
       spent=st.floats(0, 300))
def test_supercap_bounds(stored, harvested, spent):
    e = supercap_step(jnp.asarray(stored), jnp.asarray(harvested),
                      jnp.asarray(spent), cap_uj=200.0)
    assert 0.0 <= float(e) <= 200.0


def test_harvest_traces_shapes_and_magnitudes(key):
    for src, lo, hi in [("rf", 1, 200), ("wifi", 1, 400),
                        ("piezo", 10, 400), ("solar", 10, 1500)]:
        tr = harvest_trace(key, 200, src)
        assert tr.shape == (200,)
        assert bool(jnp.all(tr >= 0))
        assert lo < float(tr.mean()) < hi, (src, float(tr.mean()))


def test_predictor_converges_to_mean(key):
    st_ = predictor_init(8)
    for v in [10.0] * 20:
        st_ = predictor_update(st_, jnp.asarray(v))
    assert float(predictor_forecast(st_)) == pytest.approx(10.0, rel=1e-5)


# --- decision flow ----------------------------------------------------------

def test_memo_gate_overrides_everything():
    out = choose_decision(jnp.asarray(0.99), jnp.asarray(0.0),
                          jnp.asarray(0.0), TABLE2_COSTS)
    assert int(out.decision) == D0_MEMO


def test_rich_budget_prefers_local_dnn():
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(100.0),
                          jnp.asarray(0.0), TABLE2_COSTS)
    assert int(out.decision) == D2_DNN_QUANT


def test_poor_budget_offloads_cluster_then_sampling_then_defers():
    c = decision_energy(TABLE2_COSTS)
    mid = float(c[D3_CLUSTER]) + 0.1
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(mid), jnp.asarray(0.0),
                          TABLE2_COSTS)
    assert int(out.decision) == D3_CLUSTER
    low = float(c[D4_SAMPLING]) + 0.05
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(low), jnp.asarray(0.0),
                          TABLE2_COSTS)
    assert int(out.decision) == D4_SAMPLING
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(0.5), jnp.asarray(0.0),
                          TABLE2_COSTS)
    assert int(out.decision) == DEFER


@settings(max_examples=40, deadline=None)
@given(e1=st.floats(0, 120), e2=st.floats(0, 120), corr=st.floats(-1, 0.9))
def test_decision_monotone_in_energy(e1, e2, corr):
    """More energy never degrades the decision quality ladder
    (D2 > D3 > D4 > DEFER preference order, paper Fig. 8)."""
    rank = {D2_DNN_QUANT: 3, D3_CLUSTER: 2, D4_SAMPLING: 1, DEFER: 0,
            D0_MEMO: 4, D1_DNN_FULL: 3}
    lo, hi = sorted([e1, e2])
    d_lo = int(choose_decision(jnp.asarray(corr), jnp.asarray(lo),
                               jnp.asarray(0.0), TABLE2_COSTS).decision)
    d_hi = int(choose_decision(jnp.asarray(corr), jnp.asarray(hi),
                               jnp.asarray(0.0), TABLE2_COSTS).decision)
    assert rank[d_hi] >= rank[d_lo]
