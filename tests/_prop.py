"""Property-testing shim: real hypothesis when installed, a fixed-seed
``pytest.mark.parametrize`` fallback otherwise.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` so the tier-1 suite collects and passes in minimal
environments (the container does not ship hypothesis).  The fallback draws a
deterministic sample of examples per test (seeded by the test name, so runs
are reproducible and order-independent) and parametrizes over them — weaker
than hypothesis' shrinking search, but it executes the same property bodies.
"""
from __future__ import annotations

import random
import zlib

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 8   # examples per property when hypothesis is absent

    class _Strategy:
        """A draw rule: strategy.draw(rng) -> one example value."""

        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(float(min_value), float(max_value)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: rng.choice(opts))

    def settings(**_kwargs):
        """No-op: max_examples/deadline are hypothesis execution knobs."""
        def deco(fn):
            return fn
        return deco

    def given(**strategies_kw):
        """Expand to a fixed-seed parametrize over drawn example tuples."""
        names = sorted(strategies_kw)

        def deco(fn):
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            cases = [tuple(strategies_kw[n].draw(rng) for n in names)
                     for _ in range(_FALLBACK_EXAMPLES)]
            if len(names) == 1:
                cases = [c[0] for c in cases]
            return pytest.mark.parametrize(",".join(names), cases)(fn)
        return deco
