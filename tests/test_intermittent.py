"""Intermittent inference across brown-outs (ISSUE 7).

The contracts pinned here (the sharded mirror lives in
tests/test_fleet_sharded.py's ``_INTERMITTENT_CODE`` subprocess snippet):

* the staged forward pass is the quantized forward pass: running the three
  stages through the activation buffer reproduces ``har_apply_quantized``
  bitwise, so suspending between stages cannot change the answer;
* per-stage strict spend: under ANY (stored, harvested, progress) the
  lane's spend never exceeds ``stored + harvested`` — PR 5 semantics per
  stage, and the brown-out reserve is honoured by everything past sensing;
* the resume contract (docs/RESUME_CONTRACT.md): a manual split run and the
  streamed driver both equal one long run BITWISE, including inferences
  suspended across segment boundaries and brown-outs;
* early exits are confidence-gated and monotone in ``exit_threshold``;
* the per-source-slot accuracy gather matches a numpy recomputation from
  the raw traces;
* half-configured runs raise instead of silently dropping state (the
  ``intermittent=None``-is-bitwise and streamed-driver contracts moved to
  the registry-wide sweep in tests/test_resume_contract.py);
* the acceptance metric: under scarce harvest the staged lane completes
  strictly more inferences than freeze-and-lose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs.seeker_har import HAR
from repro.core import (
    D6_PARTIAL, D7_EARLY_EXIT, D8_STAGED_FULL, DEFER, EnergyCosts,
    N_INTERMITTENT_DECISIONS, BrownoutConfig, IntermittentConfig,
    fleet_harvest_traces,
)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import (har_act_buffer, har_apply_quantized,
                              har_apply_staged, har_aux_init, har_init,
                              quantize_params)
from repro.serving import (IntermittentState, SeekerNodeState,
                           intermittent_fleet_init, intermittent_lane_step,
                           seeker_fleet_simulate,
                           seeker_fleet_simulate_streamed, seeker_node_init)

S, N = 18, 4
SCARCITY = 0.04          # the benchmark's scarce-harvest regime
CFG = IntermittentConfig()
BO = BrownoutConfig()


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    aux = har_aux_init(jax.random.fold_in(key, 7), HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, S)
    harvest = fleet_harvest_traces(key, N, S) * SCARCITY
    kw = dict(signatures=class_signatures(), qdnn_params=params,
              host_params=params, gen_params=gen, har_cfg=HAR, key=key,
              labels=labels, donate=False, initial_uj=12.0, brownout=BO)
    return key, params, aux, wins, labels, harvest, kw


def _it_kw(kw, aux, cfg=CFG):
    out = dict(kw)
    out.update(intermittent=cfg, aux_params=aux)
    return out


# ---------------------------------------------------------------------------
# The staged forward pass
# ---------------------------------------------------------------------------

def test_staged_matches_quantized_bitwise():
    """Cutting the quantized DNN at the pooling boundaries and threading the
    flat activation buffer through reproduces the one-shot pass bitwise —
    suspension points cannot change the classification."""
    key = jax.random.PRNGKey(1)
    params = har_init(key, HAR)
    wins = jax.random.normal(jax.random.fold_in(key, 2),
                             (5, HAR.window, HAR.channels))
    for bits in (16, 12):
        for w in wins:
            ref = har_apply_quantized(params, w[None], bits)[0]
            staged = har_apply_staged(params, w, bits, HAR)
            np.testing.assert_array_equal(np.asarray(ref),
                                          np.asarray(staged))


def test_stage_costs_partition_the_quantized_inference():
    c = EnergyCosts()
    assert np.isclose(sum(c.stage_costs(16)), c.dnn16)
    assert np.isclose(sum(c.stage_costs(12)), c.dnn12)
    assert len(c.decision_costs()) == N_INTERMITTENT_DECISIONS


# ---------------------------------------------------------------------------
# Per-stage strict spend + the brown-out reserve
# ---------------------------------------------------------------------------

def _lane(stored, harvested, stage, active, reserve=0.0,
          cfg=CFG, slot=3):
    key = jax.random.PRNGKey(4)
    params = har_init(key, HAR)
    aux = har_aux_init(jax.random.fold_in(key, 7), HAR)
    qp = quantize_params(params, 16)
    window = jax.random.normal(jax.random.fold_in(key, 5),
                               (HAR.window, HAR.channels))
    state = seeker_node_init(initial_uj=float(stored))
    it = IntermittentState(
        active=jnp.asarray(bool(active)),
        stage=jnp.asarray(int(stage), jnp.int32),
        acts=jnp.abs(jax.random.normal(jax.random.fold_in(key, 6),
                                       (har_act_buffer(HAR),))),
        src_slot=jnp.asarray(1, jnp.int32))
    return intermittent_lane_step(
        window, state, jnp.asarray(float(harvested)), jnp.asarray(DEFER),
        it, jnp.asarray(slot, jnp.int32), qp=qp, aux_params=aux,
        har_cfg=HAR, costs=EnergyCosts(), quant_bits=16, cfg=cfg,
        reserve_uj=reserve)


@settings(max_examples=24, deadline=None)
@given(stored=st.floats(0, 40), harvested=st.floats(0, 20),
       stage=st.integers(0, 3), active=st.integers(0, 1))
def test_lane_strict_spend(stored, harvested, stage, active):
    """The lane's acceptance property: whatever the suspended progress, the
    slot's spend is payable from stored + harvested alone."""
    out = _lane(stored, harvested, stage, active)
    spend = float(out.spend)
    assert 0.0 <= spend <= stored + harvested + 1e-4
    # and the supercap recurrence never hits the clip floor
    assert float(out.stored_uj) >= -1e-5


@settings(max_examples=24, deadline=None)
@given(stored=st.floats(0, 40), harvested=st.floats(0, 20),
       stage=st.integers(0, 3), active=st.integers(0, 1),
       reserve=st.floats(0, 15))
def test_lane_reserve_respected(stored, harvested, stage, active, reserve):
    """Everything past mandatory sensing is gated on leaving the brown-out
    reserve in the supercap: if the lane spent more than ``sense``, the
    budget it left behind is at least the reserve."""
    out = _lane(stored, harvested, stage, active, reserve=reserve)
    spend = float(out.spend)
    sense = EnergyCosts().sense
    if spend > sense + 1e-6:
        assert stored + harvested - spend >= reserve - 1e-4


def test_lane_resume_owns_slot_and_emits_at_depth():
    """An in-flight inference at full depth with an affordable tx emits D8
    scored against its SOURCE slot, not the current one."""
    out = _lane(stored=40.0, harvested=10.0, stage=3, active=True, slot=9)
    assert int(out.decision) == D8_STAGED_FULL
    assert int(out.emit) == 2 and int(out.emit_src) == 1  # src_slot=1, not 9
    assert float(out.payload_bytes) == 2.0
    assert not bool(out.state.active)


def test_lane_suspends_when_broke():
    """Sensing affordable but no stage is: D6 with progress frozen."""
    out = _lane(stored=1.0, harvested=0.0, stage=1, active=True)
    assert int(out.decision) == D6_PARTIAL
    assert int(out.emit) == 0
    assert bool(out.state.active) and int(out.state.stage) == 1


# ---------------------------------------------------------------------------
# Engine integration: None-parity, validation, early exit
# ---------------------------------------------------------------------------

def test_half_configured_runs_raise(setup):
    key, params, aux, wins, labels, harvest, kw = setup
    it0 = intermittent_fleet_init(N, HAR)
    with pytest.raises(ValueError, match="intermittent_state0"):
        seeker_fleet_simulate(wins, harvest, intermittent_state0=it0, **kw)
    with pytest.raises(ValueError, match="aux"):
        seeker_fleet_simulate(wins, harvest, intermittent=CFG, **kw)
    with pytest.raises(ValueError, match="stacked"):
        seeker_fleet_simulate(wins, harvest, intermittent=CFG,
                              aux_params=aux,
                              intermittent_state0=intermittent_fleet_init(
                                  N + 1, HAR), **kw)


def test_early_exit_monotone_in_threshold(setup):
    """Raising exit_threshold can only forbid early exits: the D7 count is
    non-increasing, and a threshold above 1 (max-softmax is <= 1) kills
    them entirely."""
    key, params, aux, wins, labels, harvest, kw = setup
    counts = []
    for thr in (0.0, 0.3, 0.8, 1.5):
        res = seeker_fleet_simulate(
            wins, harvest,
            **_it_kw(kw, aux, IntermittentConfig(exit_threshold=thr)))
        counts.append(int(res["it_early"]))
    assert counts == sorted(counts, reverse=True)
    assert counts[-1] == 0
    assert counts[0] > 0          # the scarce regime does produce D7s


def test_emissions_and_histogram_consistent(setup):
    key, params, aux, wins, labels, harvest, kw = setup
    res = seeker_fleet_simulate(wins, harvest, **_it_kw(kw, aux))
    dec = np.asarray(res["decisions"])
    emit = np.asarray(res["it_emit"])
    alive = np.asarray(res["alive"])
    hist = np.asarray(res["decision_histogram"])
    assert hist.shape == (N_INTERMITTENT_DECISIONS,)
    assert int(res["it_full"]) == int(((emit == 2) & alive).sum()) \
        == hist[D8_STAGED_FULL]
    assert int(res["it_early"]) == int(((emit == 1) & alive).sum()) \
        == hist[D7_EARLY_EXIT]
    # a D6 suspension put nothing on the wire and is not completed
    completed = (dec != DEFER) & (dec != D6_PARTIAL) & alive
    assert int(res["completed"]) == int(completed.sum())
    assert (np.asarray(res["payload_bytes"])[(dec == D6_PARTIAL)] == 0).all()


def test_accuracy_gather_matches_numpy(setup):
    """The engine scores an emission against the label of the SOURCE slot
    via a take-along-axis gather; recompute it in numpy from raw traces."""
    key, params, aux, wins, labels, harvest, kw = setup
    res = seeker_fleet_simulate(wins, harvest, **_it_kw(kw, aux))
    emit = np.asarray(res["it_emit"])
    src = np.asarray(res["it_src"])
    lab = np.asarray(res["it_label"])
    alive = np.asarray(res["alive"])
    y = np.asarray(labels)
    valid = (emit > 0) & alive & (src >= 0)
    ok = valid & (lab == y[np.clip(src, 0, S - 1)])
    assert int(res["it_correct_full"]) == int((ok & (emit == 2)).sum())
    assert int(res["it_correct_early"]) == int((ok & (emit == 1)).sum())
    assert int(res["correct"]) == int(res["correct_ladder"]) \
        + int(res["it_correct_full"]) + int(res["it_correct_early"])


# ---------------------------------------------------------------------------
# The resume contract (docs/RESUME_CONTRACT.md)
# ---------------------------------------------------------------------------

IT_KEYS = ("decisions", "payload_bytes", "stored_uj", "it_emit", "it_label",
           "it_conf", "it_src", "it_stage", "logits")


def test_manual_resume_matches_long_run(setup):
    """The contract exactly as docs/RESUME_CONTRACT.md states it: chain two
    segments by hand through state0/node_keys/brownout_state0/
    intermittent_state0/slot0 and compare bitwise against one long run."""
    key, params, aux, wins, labels, harvest, kw = setup
    s1 = S // 2
    kw1 = {k: v for k, v in kw.items() if k != "labels"}
    full = seeker_fleet_simulate(wins, harvest, **_it_kw(kw, aux))
    a = seeker_fleet_simulate(wins[:s1], harvest[:, :s1],
                              **_it_kw(kw1, aux))
    b = seeker_fleet_simulate(
        wins[s1:], harvest[:, s1:], state0=a["final_state"],
        node_keys=a["final_keys"], brownout_state0=a["final_brownout"],
        intermittent_state0=a["final_intermittent"], slot0=s1,
        **_it_kw(kw1, aux))
    for k in IT_KEYS:
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(a[k]), np.asarray(b[k])]),
            np.asarray(full[k]), err_msg=k)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        b["final_intermittent"], full["final_intermittent"])


# The streamed-driver and lane=None bitwise contracts moved to
# tests/test_resume_contract.py: one registry-parametrized harness sweeping
# EVERY lane combination (including the cross-segment rescoring path this
# file used to pin per-lane).


# ---------------------------------------------------------------------------
# The acceptance metric
# ---------------------------------------------------------------------------

def test_staged_beats_freeze_and_lose(setup):
    """Under scarce harvest the lane converts DEFER slots into completed
    inferences: completed count strictly above the brown-out baseline."""
    key, params, aux, wins, labels, harvest, kw = setup
    base = seeker_fleet_simulate(wins, harvest, **kw)
    staged = seeker_fleet_simulate(wins, harvest, **_it_kw(kw, aux))
    assert int(staged["it_full"]) + int(staged["it_early"]) > 0
    assert int(staged["completed"]) > int(base["completed"])
