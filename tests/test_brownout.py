"""Endogenous brown-out churn + the energy-debt fix (ISSUE 5).

The contracts pinned here (the sharded mirror lives in
tests/test_fleet_sharded.py's ``_BROWNOUT_CODE`` subprocess snippet):

* strict decision mode: under ANY (stored, harvested, forecast) the chosen
  decision's spend never exceeds ``stored + harvested`` — the forecast can
  rank but no longer mint energy;
* :func:`supercap_step_direct` never clip-forgives debt: while the caller
  keeps spend within the strict budget the update is exact arithmetic, the
  zero floor never engages;
* the engine-level debt invariant: with a ``BrownoutConfig``, no slot's
  reconstructed spend exceeds the energy actually available that slot, and
  the stored-µJ trace is the exact store-and-execute recurrence;
* hysteresis: a node drains below ``off_uj`` → browns out (DEFER, zero
  payload, frozen PRNG/predictor — bitwise the PR-4 frozen-node lanes),
  trickle-charges while down, and rejoins at ``restart_uj``;
* the streamed driver rejects S == 0 streams with a clear error — the
  ``brownout=None``-is-bitwise-legacy and streamed-resume-bitwise contracts
  moved to the registry-wide harness in tests/test_resume_contract.py
  (every lane combination, one parametrized sweep);
* ``bytes_on_wire_i32`` is exact where the float32 ``bytes_on_wire``
  already is not.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs.seeker_har import HAR
from repro.core import (
    DEFER, SUPERCAP_CAP_UJ, SUPERCAP_CHARGE_EFF, TABLE2_COSTS,
    BrownoutConfig, choose_decision, decision_energy, fleet_harvest_traces,
    supercap_step_direct,
)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)
from repro.serving.fleet import _wire_byte_pair

S, N = 12, 4


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, S)
    harvest = fleet_harvest_traces(key, N, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)
    return key, wins, labels, harvest, kw


# ---------------------------------------------------------------------------
# Decision core: the debt fix
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(stored=st.floats(0, 200), harvested=st.floats(0, 500),
       forecast=st.floats(0, 500), corr=st.floats(-1, 1))
def test_strict_spend_never_exceeds_available(stored, harvested, forecast,
                                              corr):
    """The acceptance property: in strict mode the spend is payable from
    stored + harvested alone, for any forecast."""
    out = choose_decision(jnp.asarray(corr), jnp.asarray(stored),
                          jnp.asarray(forecast), TABLE2_COSTS,
                          harvested_uj=jnp.asarray(harvested))
    assert float(out.spend) <= stored + harvested + 1e-4
    # spend is either the chosen row's table cost or the zero clamp
    cost = decision_energy(TABLE2_COSTS)
    assert float(out.spend) in (float(cost[int(out.decision)]), 0.0)


def test_forecast_no_longer_mints_energy():
    """The bug: an empty supercap plus a rosy forecast used to execute D2
    on energy that never existed.  Strict mode defers instead."""
    legacy = choose_decision(jnp.asarray(0.1), jnp.asarray(0.0),
                             jnp.asarray(1000.0), TABLE2_COSTS)
    assert int(legacy.decision) != DEFER          # the minting behaviour
    strict = choose_decision(jnp.asarray(0.1), jnp.asarray(0.0),
                             jnp.asarray(1000.0), TABLE2_COSTS,
                             harvested_uj=jnp.asarray(0.0))
    assert int(strict.decision) == DEFER
    assert float(strict.spend) == 0.0             # can't even afford sensing
    # a memo hit the node cannot transmit is not a hit either
    memo = choose_decision(jnp.asarray(0.99), jnp.asarray(0.0),
                           jnp.asarray(1000.0), TABLE2_COSTS,
                           harvested_uj=jnp.asarray(0.0))
    assert int(memo.decision) == DEFER and float(memo.spend) == 0.0


def test_strict_defer_pays_sensing_when_it_can():
    c = TABLE2_COSTS
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(c.sense + 0.1),
                          jnp.asarray(0.0), TABLE2_COSTS,
                          harvested_uj=jnp.asarray(0.0))
    assert int(out.decision) == DEFER
    assert float(out.spend) == pytest.approx(c.sense, abs=1e-6)


def test_strict_harvest_in_hand_still_spends():
    """This slot's actual income IS payable — store-and-execute, not
    store-then-execute: zero stored + a big harvest runs the DNN."""
    out = choose_decision(jnp.asarray(0.1), jnp.asarray(0.0),
                          jnp.asarray(0.0), TABLE2_COSTS,
                          harvested_uj=jnp.asarray(100.0))
    assert int(out.decision) != DEFER


@settings(max_examples=40, deadline=None)
@given(stored=st.floats(0, 200), harvested=st.floats(0, 500),
       frac=st.floats(0, 1))
def test_supercap_direct_never_clips_debt(stored, harvested, frac):
    """Within the strict budget the update is exact arithmetic — the zero
    floor (the clip that used to forgive debt) never engages."""
    spent = frac * (stored + harvested)
    out = float(supercap_step_direct(jnp.asarray(stored),
                                     jnp.asarray(harvested),
                                     jnp.asarray(spent)))
    direct = min(spent, harvested)
    exact = (stored + SUPERCAP_CHARGE_EFF * (harvested - direct)
             - (spent - direct))
    assert exact >= -1e-3                       # debt impossible by algebra
    assert out == pytest.approx(min(exact, SUPERCAP_CAP_UJ), abs=1e-3)


def test_brownout_config_validates():
    with pytest.raises(ValueError, match="off_uj"):
        BrownoutConfig(off_uj=30.0, restart_uj=10.0)
    with pytest.raises(ValueError, match="off_uj"):
        BrownoutConfig(off_uj=-1.0, restart_uj=10.0)


# ---------------------------------------------------------------------------
# Engine-level: the endogenous alive lane
# ---------------------------------------------------------------------------

def test_wire_byte_pair_agrees_with_float_sum(setup):
    """At this scale the float32 byte total is still exact, so the int pair
    must agree with it (the off-state sweep itself lives in
    tests/test_resume_contract.py)."""
    key, wins, labels, harvest, kw = setup
    res = seeker_fleet_simulate(wins, harvest, labels=labels, **kw)
    assert wire_bytes_exact(res) == int(float(res["bytes_on_wire"]))
    assert int(res["alive_slots"]) == S * N


def _drain_recharge_fixture(setup, *, drought: int):
    """Node 0 sees zero harvest for ``drought`` slots (drains, browns out),
    then a fat recharge; other nodes keep their heterogeneous traces."""
    key, wins, labels, harvest, kw = setup
    h = np.asarray(harvest).copy()
    h[0, :drought] = 0.0
    h[0, drought:] = 60.0
    return jnp.asarray(h)


def test_hysteresis_roundtrip_drain_brownout_recharge_rejoin(setup):
    """The full hysteresis round-trip on simulated charge: drain below
    off_uj -> browned-out DEFER slots with trickle-charging -> rejoin past
    restart_uj -> normal decisions again."""
    key, wins, labels, harvest, kw = setup
    cfg = BrownoutConfig(off_uj=10.0, restart_uj=30.0)
    h = _drain_recharge_fixture(setup, drought=4)
    res = seeker_fleet_simulate(wins, h, brownout=cfg, initial_uj=20.0, **kw)
    alive = np.asarray(res["alive"])[:, 0]
    browned = np.asarray(res["brownout"])[:, 0]
    stored = np.asarray(res["stored_uj"])[:, 0]
    dec = np.asarray(res["decisions"])[:, 0]

    # the node actually browned out and actually rejoined
    assert browned.any() and alive[0] and alive[-1], (browned, alive)
    off = int(np.flatnonzero(browned)[0])
    back = int(np.flatnonzero(alive[off:])[0]) + off
    assert back < S, "fixture never rejoined; retune thresholds"
    # composition rule: alive == exogenous (all-True here) ∧ ¬browned_out
    np.testing.assert_array_equal(alive, ~browned)
    # browned-out slots: DEFER, zero payload/logits, trickle-charged cap
    assert (dec[off:back] == DEFER).all()
    assert (np.asarray(res["payload_bytes"])[off:back, 0] == 0).all()
    assert (np.asarray(res["logits"])[off:back, 0] == 0).all()
    for t in range(off, back):
        want = min(stored[t - 1] + SUPERCAP_CHARGE_EFF * float(h[0, t]),
                   SUPERCAP_CAP_UJ)
        assert stored[t] == pytest.approx(want, abs=1e-4), t
    # it rejoined only once the charge cleared the restart threshold
    assert stored[back - 1] >= cfg.restart_uj
    # the onset is counted as one event
    assert int(res["brownout_events"]) >= 1
    assert int(res["alive_slots"]) + int(res["brownout_slots"]) == S * N


def test_browned_out_node_is_bitwise_a_frozen_node(setup):
    """The frozen lanes of a browned-out node are BITWISE those of an
    exogenously-frozen node: feed the engine the brown-out run's emitted
    alive lane as an exogenous trace and the PRNG keys and predictor
    histories match exactly (only the supercap differs — it trickle-charges
    while the exogenous freeze holds it)."""
    key, wins, labels, harvest, kw = setup
    cfg = BrownoutConfig(off_uj=10.0, restart_uj=30.0)
    h = _drain_recharge_fixture(setup, drought=4)
    res = seeker_fleet_simulate(wins, h, brownout=cfg, initial_uj=20.0, **kw)
    assert bool(jnp.any(res["brownout"])), "fixture must brown out"
    frozen = seeker_fleet_simulate(
        wins, h, alive=jnp.asarray(res["alive"]).T, **kw)
    np.testing.assert_array_equal(np.asarray(res["final_keys"]),
                                  np.asarray(frozen["final_keys"]))
    np.testing.assert_array_equal(
        np.asarray(res["final_state"].predictor.history),
        np.asarray(frozen["final_state"].predictor.history))
    np.testing.assert_array_equal(
        np.asarray(res["final_state"].predictor.pos),
        np.asarray(frozen["final_state"].predictor.pos))


def test_engine_debt_invariant(setup):
    """Reconstruct every slot's spend from the decision trace: it never
    exceeds the energy actually available (stored + harvested), and the
    stored trace is the exact store-and-execute recurrence — no hidden
    clip ever forgave a debt."""
    key, wins, labels, harvest, kw = setup
    cfg = BrownoutConfig(off_uj=5.0, restart_uj=25.0)
    res = seeker_fleet_simulate(wins, harvest, brownout=cfg, initial_uj=8.0,
                                **kw)
    cost = np.asarray(decision_energy(TABLE2_COSTS), np.float64)
    stored = np.asarray(res["stored_uj"], np.float64)
    alive = np.asarray(res["alive"])
    dec = np.asarray(res["decisions"])
    h = np.asarray(harvest, np.float64).T                    # (S, N)
    eff, cap = SUPERCAP_CHARGE_EFF, SUPERCAP_CAP_UJ
    prev = np.full((N,), 8.0)
    for t in range(S):
        for i in range(N):
            avail = prev[i] + h[t, i]
            if alive[t, i]:
                spend = cost[dec[t, i]]
                if dec[t, i] == DEFER and avail < cost[DEFER]:
                    spend = 0.0
                assert spend <= avail + 1e-4, (t, i, spend, avail)
                direct = min(spend, h[t, i])
                want = prev[i] + eff * (h[t, i] - direct) - (spend - direct)
                assert want >= -1e-4, (t, i)                 # no debt, ever
                want = min(want, cap)
            else:                                            # trickle charge
                want = min(prev[i] + eff * h[t, i], cap)
            assert stored[t, i] == pytest.approx(want, abs=1e-3), (t, i)
            prev[i] = stored[t, i]


def test_brownout_composes_with_exogenous_churn(setup):
    """alive = exogenous ∧ ¬browned_out: an exogenously-dead slot stays
    fully frozen (no trickle, no flag movement), and the aggregates split
    exactly along the composition."""
    key, wins, labels, harvest, kw = setup
    from repro.core import fleet_alive_traces
    cfg = BrownoutConfig(off_uj=5.0, restart_uj=25.0)
    exo = fleet_alive_traces(key, N, S, duty=0.6, period=4)
    res = seeker_fleet_simulate(wins, harvest, alive=exo, brownout=cfg,
                                initial_uj=8.0, **kw)
    a = np.asarray(res["alive"])
    b = np.asarray(res["brownout"])
    e = np.asarray(exo).T
    np.testing.assert_array_equal(a, e & ~b)
    assert int(res["alive_slots"]) == a.sum()
    assert int(res["brownout_slots"]) == (b & e).sum()
    stored = np.asarray(res["stored_uj"])
    h = np.asarray(harvest).T
    prev = np.full((N,), 8.0)
    for t in range(S):
        frozen = ~e[t]
        np.testing.assert_array_equal(stored[t][frozen], prev[frozen])
        prev = stored[t]


def test_streamed_empty_stream_raises(setup):
    """S == 0 used to die with ``IndexError: parts[0]``; now it refuses
    up front like the chunk < 1 check."""
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match="S must be >= 1"):
        seeker_fleet_simulate_streamed(wins[:0], harvest[:, :0], chunk=4,
                                       **kw)


def test_brownout_state0_wrong_shape_raises(setup):
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match="brownout_state0"):
        seeker_fleet_simulate(wins, harvest,
                              brownout=BrownoutConfig(),
                              brownout_state0=jnp.ones((N + 1,), bool), **kw)


# ---------------------------------------------------------------------------
# Exact byte accounting
# ---------------------------------------------------------------------------

def test_wire_byte_pair_exact_where_float32_is_not():
    """The satellite bug: float32 loses whole bytes once the running sum
    passes 2**24 (XLA's pairwise reduction keeps *uniform* payloads exact,
    so the fixture mixes sizes like a real fleet does: 2**17 slots of
    300..700-B payloads, ~65.5 MB total)."""
    import numpy as np
    vals = 300 + np.arange(1 << 17) % 401
    payload = jnp.asarray(vals.reshape(-1, 1), jnp.float32)
    act = jnp.ones(payload.shape, bool)
    pair = _wire_byte_pair(payload, act)
    exact = (int(pair[0]) << 16) + int(pair[1])
    assert exact == int(vals.sum())
    f32 = float(jnp.sum(payload))
    assert f32 != exact, "float32 sum unexpectedly exact; grow the fixture"


def test_wire_byte_pair_respects_mask():
    payload = jnp.asarray([[10.0, 3.0], [5.0, 7.0]])
    act = jnp.asarray([[True, False], [False, True]])
    pair = _wire_byte_pair(payload, act)
    assert (int(pair[0]) << 16) + int(pair[1]) == 17


def test_streamed_byte_pair_stays_normalized(setup):
    """The streamed driver propagates the pair's carry each segment (lo
    stays < 2**16), so long many-segment streams cannot overflow the lo
    digit the way naive component-wise int32 accumulation would."""
    key, wins, labels, harvest, kw = setup
    stream = seeker_fleet_simulate_streamed(wins, harvest, chunk=3, **kw)
    full = seeker_fleet_simulate(wins, harvest, **kw)
    hi, lo = (int(v) for v in np.asarray(stream["bytes_on_wire_i32"]))
    assert 0 <= lo < (1 << 16)
    assert wire_bytes_exact(stream) == wire_bytes_exact(full)


def test_engine_byte_pair_matches_trace(setup):
    """The engine's pair == the exact integer sum of its own masked payload
    trace (and the float32 aggregate at this small scale)."""
    key, wins, labels, harvest, kw = setup
    from repro.core import fleet_alive_traces
    alive = fleet_alive_traces(key, N, S, duty=0.7, period=4)
    res = seeker_fleet_simulate(wins, harvest, alive=alive, **kw)
    a = np.asarray(res["alive"])
    want = int(np.asarray(res["payload_bytes"], np.int64)[a].sum())
    assert wire_bytes_exact(res) == want == int(float(res["bytes_on_wire"]))
