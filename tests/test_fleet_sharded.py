"""Sharded fleet engine tests.

The equivalence contract: on a CPU mesh (subprocess with
``--xla_force_host_platform_device_count=8``, same pattern as
test_sharding_and_dryrun.py), :func:`seeker_fleet_simulate_sharded` must
reproduce :func:`seeker_fleet_simulate` BITWISE — decisions, payload bytes,
stored µJ, k trace, and (with a common ``node_block`` pinning XLA's
batch-shape-dependent matmul lowering) host logits — for both a divisible
N=8 and a non-divisible N=13, the latter exercising the pad-to-quantum /
inert-node masking path.  Fleet aggregates (bytes on wire, decision
histogram, completion, accuracy) are the only psum-ed quantities and are
checked against recomputation from the unsharded traces.

The state0-resume fix (two chained runs == one long run) needs no mesh and
runs in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_EQUIV_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate, seeker_fleet_simulate_sharded
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, BLOCK = 6, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
sigs = class_signatures()
wins, labels = har_stream(key, S)

for n, mesh in ((8, make_mesh_compat((8,), ("data",))),
                (13, make_mesh_compat((8,), ("data",))),
                (13, make_mesh_compat((2, 4), ("pod", "data")))):
    harvest = fleet_harvest_traces(key, n, S)
    ref = seeker_fleet_simulate(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR,
        node_block=BLOCK, donate=False)
    sh = seeker_fleet_simulate_sharded(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR, mesh=mesh,
        labels=labels, node_block=BLOCK, donate=False)
    assert sh["padded_nodes"] == (-n) % 8, sh["padded_nodes"]

    # --- bitwise per-node traces (the acceptance contract) -----------------
    for k in ("decisions", "payload_bytes", "stored_uj", "k_trace",
              "logits", "preds"):
        np.testing.assert_array_equal(
            np.asarray(sh[k]), np.asarray(ref[k]),
            err_msg=f"{k} (N={n}, mesh {mesh.shape})")
    np.testing.assert_array_equal(
        np.asarray(sh["final_state"].stored_uj),
        np.asarray(ref["final_state"].stored_uj))

    # --- psum-ed fleet aggregates vs recomputation from unsharded traces ---
    dec = np.asarray(ref["decisions"])
    sent = dec != 5
    np.testing.assert_allclose(float(sh["bytes_on_wire"]),
                               float(ref["bytes_on_wire"]),
                               rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(sh["decision_histogram"]),
        np.bincount(dec.ravel(), minlength=6))
    assert abs(float(sh["completed_frac"]) - sent.mean()) < 1e-6
    correct = ((np.asarray(ref["preds"]) == np.asarray(labels)[:, None])
               & sent).sum()
    want = correct / max(sent.sum(), 1)
    assert abs(float(sh["fleet_accuracy"]) - want) < 1e-6
    print(f"N={n} mesh={mesh.shape} OK")

# default path (node_block=None, full-batch vmap): integer and energy traces
# stay bitwise; logits only to tolerance (XLA batch-shape matmul lowering)
n, mesh = 13, make_mesh_compat((8,), ("data",))
harvest = fleet_harvest_traces(key, n, S)
ref = seeker_fleet_simulate(
    wins, harvest, signatures=sigs, qdnn_params=params, host_params=params,
    gen_params=gen, har_cfg=HAR, donate=False)
sh = seeker_fleet_simulate_sharded(
    wins, harvest, signatures=sigs, qdnn_params=params, host_params=params,
    gen_params=gen, har_cfg=HAR, mesh=mesh, donate=False)
for k in ("decisions", "payload_bytes", "stored_uj", "k_trace"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=f"{k} (default node_block)")
np.testing.assert_allclose(np.asarray(sh["logits"]), np.asarray(ref["logits"]),
                           rtol=1e-5, atol=1e-5)
print("default node_block OK")
print("OK")
"""


_SERVE_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core.coreset import ClusterCoreset, channel_cluster_coresets
from repro.core.recovery import recover_cluster_window
from repro.data.sensors import har_stream
from repro.models.har import har_apply, har_init
from repro.serving import fleet_serve_step
from repro.serving.edge_host import (decode_wire_coresets,
                                     encode_wire_coresets)
from repro.sharding import make_mesh_compat

key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
for n, shape, axes in ((16, (8,), ("data",)), (13, (8,), ("data",)),
                       (16, (2, 4), ("pod", "data"))):
    wins, _ = har_stream(jax.random.PRNGKey(2), n)
    mesh = make_mesh_compat(shape, axes)
    out = fleet_serve_step(wins, host_params=params, har_cfg=HAR, mesh=mesh,
                           key=key)
    assert out["host_logits"].shape == (n, HAR.n_classes)
    assert out["wire_bytes"] < out["raw_bytes"]
    # unsharded host-side oracle on the padded fleet (same key split count)
    pad = (-n) % 8
    wp = jnp.pad(wins, ((0, pad), (0, 0), (0, 0)))
    c, r, cnt = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=12, iters=4))(wp)
    cr, rr, nr = decode_wire_coresets(encode_wire_coresets(c, r, cnt))
    keys = jax.random.split(key, n + pad)
    rec = jax.vmap(lambda cc, rad, cn, kk: recover_cluster_window(
        ClusterCoreset(cc, rad, cn), kk, HAR.window))(cr, rr, nr, keys)
    np.testing.assert_array_equal(np.asarray(out["host_logits"]),
                                  np.asarray(har_apply(params, rec)[:n]),
                                  err_msg=f"n={n} mesh={shape}")
    print(f"n={n} mesh={shape} OK")
print("OK")
"""


@pytest.mark.slow
def test_sharded_fleet_bitwise_equivalence_8dev():
    """Sharded == unsharded bitwise on an 8-virtual-device CPU mesh, for
    divisible N=8, non-divisible N=13 (padding/masking path), and a 2-axis
    ("pod","data") mesh."""
    assert "OK" in _run(_EQUIV_CODE, devices=8)


@pytest.mark.slow
def test_fleet_serve_step_gathers_payloads_8dev():
    """The edge->host tier gathers only wire-format coreset payloads across
    the mesh; host logits match the unsharded encode/decode/recover oracle
    bitwise (the host side runs at the full gathered batch either way)."""
    assert "OK" in _run(_SERVE_CODE, devices=8)


# ---------------------------------------------------------------------------
# state0 resume (the silently-reset-initial_uj fix) — no mesh needed
# ---------------------------------------------------------------------------

S = 12


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, S)
    return key, params, gen, sigs, wins, labels


def test_fleet_state0_is_used_not_reset(setup):
    """The fix: a passed ``state0`` must drive the run — the engine used to
    silently rebuild node state with the default ``initial_uj``."""
    key, params, gen, sigs, wins, labels = setup
    from repro.serving.fleet import fleet_node_init
    n = 5
    harvest = fleet_harvest_traces(key, n, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)

    low = seeker_fleet_simulate(wins, harvest,
                                state0=fleet_node_init(n, initial_uj=5.0),
                                **kw)
    # state0 at charge X == fresh init with initial_uj=X, bit for bit
    oracle = seeker_fleet_simulate(wins, harvest, initial_uj=5.0, **kw)
    np.testing.assert_array_equal(np.asarray(low["decisions"]),
                                  np.asarray(oracle["decisions"]))
    np.testing.assert_array_equal(np.asarray(low["stored_uj"]),
                                  np.asarray(oracle["stored_uj"]))
    # ... and differs from the default-init run the old code always did
    default = seeker_fleet_simulate(wins, harvest, **kw)
    assert not np.array_equal(np.asarray(low["stored_uj"]),
                              np.asarray(default["stored_uj"]))


def test_fleet_resume_chain_matches_one_long_run(setup):
    """Serving-loop resume: chaining ``final_state -> state0`` AND
    ``final_keys -> node_keys`` makes two runs bitwise equal to one long
    run — charge, predictor history, AAC continuity and every node's PRNG
    stream all continue where the previous segment stopped."""
    key, params, gen, sigs, wins, labels = setup
    n = 4
    harvest = fleet_harvest_traces(key, n, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)
    half = S // 2
    full = seeker_fleet_simulate(wins, harvest, **kw)
    first = seeker_fleet_simulate(wins[:half], harvest[:, :half], **kw)
    second = seeker_fleet_simulate(wins[half:], harvest[:, half:],
                                   state0=first["final_state"],
                                   node_keys=first["final_keys"], **kw)
    for k in ("decisions", "payload_bytes", "stored_uj", "logits"):
        np.testing.assert_array_equal(np.asarray(second[k]),
                                      np.asarray(full[k][half:]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(second["final_state"].stored_uj),
                                  np.asarray(full["final_state"].stored_uj))
    np.testing.assert_array_equal(np.asarray(second["final_keys"]),
                                  np.asarray(full["final_keys"]))
    # and it is NOT the trajectory a silently-reset fleet would follow
    fresh = seeker_fleet_simulate(wins[half:], harvest[:, half:], **kw)
    assert not np.array_equal(np.asarray(second["stored_uj"]),
                              np.asarray(fresh["stored_uj"]))


def test_fleet_state0_wrong_size_raises(setup):
    key, params, gen, sigs, wins, labels = setup
    from repro.serving.fleet import fleet_node_init
    harvest = fleet_harvest_traces(key, 4, S)
    with pytest.raises(ValueError, match="stacked for"):
        seeker_fleet_simulate(wins, harvest, signatures=sigs,
                              qdnn_params=params, host_params=params,
                              gen_params=gen, har_cfg=HAR,
                              state0=fleet_node_init(3), donate=False)
