"""Sharded fleet engine tests.

The equivalence contract: on a CPU mesh (subprocess with
``--xla_force_host_platform_device_count=8``, same pattern as
test_sharding_and_dryrun.py), :func:`seeker_fleet_simulate_sharded` must
reproduce :func:`seeker_fleet_simulate` BITWISE — decisions, payload bytes,
stored µJ, k trace, and (with a common ``node_block`` pinning XLA's
batch-shape-dependent matmul lowering) host logits — for both a divisible
N=8 and a non-divisible N=13, the latter exercising the pad-to-quantum /
inert-node masking path.  Fleet aggregates (bytes on wire, decision
histogram, completion, accuracy) are the only psum-ed quantities and are
checked against recomputation from the unsharded traces.

The state0-resume fix (two chained runs == one long run) needs no mesh and
runs in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_EQUIV_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import seeker_fleet_simulate, seeker_fleet_simulate_sharded
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, BLOCK = 6, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
sigs = class_signatures()
wins, labels = har_stream(key, S)

for n, mesh in ((8, make_mesh_compat((8,), ("data",))),
                (13, make_mesh_compat((8,), ("data",))),
                (13, make_mesh_compat((2, 4), ("pod", "data")))):
    harvest = fleet_harvest_traces(key, n, S)
    ref = seeker_fleet_simulate(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR,
        node_block=BLOCK, donate=False)
    sh = seeker_fleet_simulate_sharded(
        wins, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR, mesh=mesh,
        labels=labels, node_block=BLOCK, donate=False)
    assert sh["padded_nodes"] == (-n) % 8, sh["padded_nodes"]

    # --- bitwise per-node traces (the acceptance contract) -----------------
    for k in ("decisions", "payload_bytes", "stored_uj", "k_trace",
              "logits", "preds"):
        np.testing.assert_array_equal(
            np.asarray(sh[k]), np.asarray(ref[k]),
            err_msg=f"{k} (N={n}, mesh {mesh.shape})")
    np.testing.assert_array_equal(
        np.asarray(sh["final_state"].stored_uj),
        np.asarray(ref["final_state"].stored_uj))

    # --- psum-ed fleet aggregates vs recomputation from unsharded traces ---
    dec = np.asarray(ref["decisions"])
    sent = dec != 5
    np.testing.assert_allclose(float(sh["bytes_on_wire"]),
                               float(ref["bytes_on_wire"]),
                               rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(sh["decision_histogram"]),
        np.bincount(dec.ravel(), minlength=6))
    assert abs(float(sh["completed_frac"]) - sent.mean()) < 1e-6
    correct = ((np.asarray(ref["preds"]) == np.asarray(labels)[:, None])
               & sent).sum()
    want = correct / max(sent.sum(), 1)
    assert abs(float(sh["fleet_accuracy"]) - want) < 1e-6
    print(f"N={n} mesh={mesh.shape} OK")

# default path (node_block=None, full-batch vmap): integer and energy traces
# stay bitwise; logits only to tolerance (XLA batch-shape matmul lowering)
n, mesh = 13, make_mesh_compat((8,), ("data",))
harvest = fleet_harvest_traces(key, n, S)
ref = seeker_fleet_simulate(
    wins, harvest, signatures=sigs, qdnn_params=params, host_params=params,
    gen_params=gen, har_cfg=HAR, donate=False)
sh = seeker_fleet_simulate_sharded(
    wins, harvest, signatures=sigs, qdnn_params=params, host_params=params,
    gen_params=gen, har_cfg=HAR, mesh=mesh, donate=False)
for k in ("decisions", "payload_bytes", "stored_uj", "k_trace"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=f"{k} (default node_block)")
np.testing.assert_allclose(np.asarray(sh["logits"]), np.asarray(ref["logits"]),
                           rtol=1e-5, atol=1e-5)
print("default node_block OK")
print("OK")
"""


_SERVE_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core.coreset import ClusterCoreset, channel_cluster_coresets
from repro.core.recovery import recover_cluster_window
from repro.data.sensors import har_stream
from repro.models.har import har_apply, har_init
from repro.serving import fleet_serve_step
from repro.serving.edge_host import (decode_wire_coresets,
                                     encode_wire_coresets)
from repro.sharding import make_mesh_compat

key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
for n, shape, axes in ((16, (8,), ("data",)), (13, (8,), ("data",)),
                       (16, (2, 4), ("pod", "data"))):
    wins, _ = har_stream(jax.random.PRNGKey(2), n)
    mesh = make_mesh_compat(shape, axes)
    out = fleet_serve_step(wins, host_params=params, har_cfg=HAR, mesh=mesh,
                           key=key)
    assert out["host_logits"].shape == (n, HAR.n_classes)
    assert out["wire_bytes"] < out["raw_bytes"]
    # unsharded host-side oracle on the padded fleet (same key split count)
    pad = (-n) % 8
    wp = jnp.pad(wins, ((0, pad), (0, 0), (0, 0)))
    c, r, cnt = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=12, iters=4))(wp)
    cr, rr, nr = decode_wire_coresets(encode_wire_coresets(c, r, cnt))
    keys = jax.random.split(key, n + pad)
    rec = jax.vmap(lambda cc, rad, cn, kk: recover_cluster_window(
        ClusterCoreset(cc, rad, cn), kk, HAR.window))(cr, rr, nr, keys)
    np.testing.assert_array_equal(np.asarray(out["host_logits"]),
                                  np.asarray(har_apply(params, rec)[:n]),
                                  err_msg=f"n={n} mesh={shape}")
    print(f"n={n} mesh={shape} OK")
print("OK")
"""


_CHURN_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import fleet_alive_traces, fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, N, BLOCK = 6, 13, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
sigs = class_signatures()
wins, labels = har_stream(key, S)
harvest = fleet_harvest_traces(key, N, S)
alive = fleet_alive_traces(key, N, S, duty=0.6, period=4)
assert bool(jnp.any(~alive)), "fixture must churn"
mesh = make_mesh_compat((8,), ("data",))
kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
          gen_params=gen, har_cfg=HAR, node_block=BLOCK, donate=False)

# --- churn: sharded == single-device bitwise under the same alive trace ---
ref = seeker_fleet_simulate(wins, harvest, alive=alive, labels=labels, **kw)
sh = seeker_fleet_simulate_sharded(wins, harvest, alive=alive, labels=labels,
                                   mesh=mesh, **kw)
for k in ("decisions", "payload_bytes", "stored_uj", "k_trace", "logits",
          "preds"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=k)
np.testing.assert_array_equal(np.asarray(sh["final_keys"]),
                              np.asarray(ref["final_keys"]))
# psum'd aggregates == the single-device engine's (ints exactly)
for k in ("decision_histogram", "completed", "alive_slots", "correct"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=k)
np.testing.assert_allclose(float(sh["bytes_on_wire"]),
                           float(ref["bytes_on_wire"]), rtol=1e-6)
assert abs(float(sh["completed_frac"]) - float(ref["completed_frac"])) < 1e-6
assert abs(float(sh["fleet_accuracy"]) - float(ref["fleet_accuracy"])) < 1e-6
# the histogram ignores dead slots: recompute from the alive mask
a = np.asarray(alive).T
np.testing.assert_array_equal(
    np.asarray(sh["decision_histogram"]),
    np.bincount(np.asarray(ref["decisions"])[a].ravel(), minlength=6))
print("churn equivalence OK")

# --- per-node (S, N) labels: the headline accuracy bugfix, sharded --------
wn = jnp.stack([wins + 0.01 * i for i in range(N)])       # per-node streams
tracks = jnp.stack([jnp.roll(labels, i) for i in range(N)], axis=1)
refp = seeker_fleet_simulate(wn, harvest, labels=tracks, **kw)
shp = seeker_fleet_simulate_sharded(wn, harvest, labels=tracks, mesh=mesh,
                                    **kw)
np.testing.assert_array_equal(np.asarray(shp["correct"]),
                              np.asarray(refp["correct"]))
sent = np.asarray(refp["decisions"]) != 5
want = ((np.asarray(refp["preds"]) == np.asarray(tracks)) & sent).sum()
assert int(shp["correct"]) == want, (int(shp["correct"]), want)
assert abs(float(shp["fleet_accuracy"])
           - want / max(sent.sum(), 1)) < 1e-6
# shared (S,) track with per-node streams refuses (the old silent bug)
try:
    seeker_fleet_simulate_sharded(wn, harvest, labels=labels, mesh=mesh,
                                  **kw)
    raise SystemExit("shared labels with per-node streams must raise")
except ValueError as e:
    assert "ambiguous" in str(e)
print("per-node labels OK")

# --- streamed sharded == one long sharded run bitwise ---------------------
stream = seeker_fleet_simulate_streamed(
    wins, harvest, chunk=4, alive=alive, labels=labels, mesh=mesh, **kw)
for k in ("decisions", "payload_bytes", "stored_uj", "logits"):
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(sh[k]),
                                  err_msg="streamed " + k)
np.testing.assert_array_equal(np.asarray(stream["final_keys"]),
                              np.asarray(sh["final_keys"]))
for k in ("decision_histogram", "completed", "alive_slots", "correct"):
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(sh[k]),
                                  err_msg="streamed " + k)
assert stream["n_chunks"] == 2 and stream["padded_nodes"] == 3
print("streamed sharded OK")
print("OK")
"""


_BROWNOUT_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import BrownoutConfig, fleet_alive_traces, \\
    fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, N, BLOCK = 8, 13, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
sigs = class_signatures()
wins, labels = har_stream(key, S)
harvest = fleet_harvest_traces(key, N, S)
exo = fleet_alive_traces(key, N, S, duty=0.8, period=4)
cfg = BrownoutConfig(off_uj=8.0, restart_uj=28.0)
mesh = make_mesh_compat((8,), ("data",))
kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
          gen_params=gen, har_cfg=HAR, node_block=BLOCK, donate=False,
          brownout=cfg, initial_uj=10.0, labels=labels, alive=exo)

# --- endogenous brown-out: sharded == single-device bitwise, N=13 pads ----
ref = seeker_fleet_simulate(wins, harvest, **kw)
assert bool(jnp.any(ref["brownout"])), "fixture must brown out"
sh = seeker_fleet_simulate_sharded(wins, harvest, mesh=mesh, **kw)
assert sh["padded_nodes"] == 3
for k in ("decisions", "payload_bytes", "stored_uj", "k_trace", "logits",
          "alive", "brownout"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=k)
np.testing.assert_array_equal(np.asarray(sh["final_brownout"]),
                              np.asarray(ref["final_brownout"]))
np.testing.assert_array_equal(np.asarray(sh["final_keys"]),
                              np.asarray(ref["final_keys"]))
# psum'd realism counters == single-device ints EXACTLY (acceptance), and
# the padding nodes never browned in (their slots are outside every count)
for k in ("brownout_slots", "brownout_events", "completed", "alive_slots",
          "correct"):
    assert int(sh[k]) == int(ref[k]), (k, int(sh[k]), int(ref[k]))
a = np.asarray(ref["alive"]); b = np.asarray(ref["brownout"])
e = np.asarray(exo).T
np.testing.assert_array_equal(a, e & ~b)          # composition rule
assert int(sh["alive_slots"]) + int(sh["brownout_slots"]) == e.sum()
# exact int byte pair: psum'd == single-device == int64 recomputation
want = int(np.asarray(ref["payload_bytes"], np.int64)[a].sum())
assert wire_bytes_exact(sh) == wire_bytes_exact(ref) == want
print("sharded brown-out OK")

# --- streamed sharded: the flag rides the resume contract ------------------
stream = seeker_fleet_simulate_streamed(wins, harvest, chunk=3, mesh=mesh,
                                        **kw)
for k in ("decisions", "stored_uj", "logits", "alive", "brownout"):
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(sh[k]),
                                  err_msg="streamed " + k)
for k in ("brownout_slots", "brownout_events", "completed", "alive_slots"):
    assert int(stream[k]) == int(sh[k]), k
np.testing.assert_array_equal(np.asarray(stream["final_brownout"]),
                              np.asarray(sh["final_brownout"]))
assert wire_bytes_exact(stream) == wire_bytes_exact(sh)
print("streamed sharded brown-out OK")
print("OK")
"""


_INTERMITTENT_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import (BrownoutConfig, IntermittentConfig,
                        fleet_harvest_traces)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_aux_init, har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, N, BLOCK = 8, 13, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
aux = har_aux_init(jax.random.fold_in(key, 7), HAR)
gen = init_generator(key, HAR.window, HAR.channels)
wins, labels = har_stream(key, S)
harvest = fleet_harvest_traces(key, N, S) * 0.04      # scarce: DEFER-heavy
mesh = make_mesh_compat((8,), ("data",))
kw = dict(signatures=class_signatures(), qdnn_params=params,
          host_params=params, gen_params=gen, har_cfg=HAR, node_block=BLOCK,
          donate=False, initial_uj=12.0, labels=labels,
          brownout=BrownoutConfig(), intermittent=IntermittentConfig(),
          aux_params=aux)

IT_KEYS = ("decisions", "payload_bytes", "stored_uj", "logits", "alive",
           "brownout", "it_emit", "it_label", "it_conf", "it_src",
           "it_stage")
COUNTERS = ("completed", "alive_slots", "brownout_slots", "it_full",
            "it_early", "correct", "correct_ladder", "it_correct_full",
            "it_correct_early")

# --- intermittent lane: sharded == single-device bitwise, N=13 pads --------
ref = seeker_fleet_simulate(wins, harvest, **kw)
assert int(ref["it_full"]) + int(ref["it_early"]) > 0, "lane must emit"
sh = seeker_fleet_simulate_sharded(wins, harvest, mesh=mesh, **kw)
assert sh["padded_nodes"] == 3
for k in IT_KEYS:
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg=k)
for k in COUNTERS:
    assert int(sh[k]) == int(ref[k]), (k, int(sh[k]), int(ref[k]))
np.testing.assert_array_equal(np.asarray(sh["decision_histogram"]),
                              np.asarray(ref["decision_histogram"]))
jax.tree_util.tree_map(
    lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
    sh["final_intermittent"], ref["final_intermittent"])
assert wire_bytes_exact(sh) == wire_bytes_exact(ref)
print("sharded intermittent OK")

# --- streamed sharded: suspended progress rides the resume contract --------
stream = seeker_fleet_simulate_streamed(wins, harvest, chunk=3, mesh=mesh,
                                        **kw)
for k in IT_KEYS:
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(sh[k]),
                                  err_msg="streamed " + k)
for k in COUNTERS:
    assert int(stream[k]) == int(sh[k]), k
jax.tree_util.tree_map(
    lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
    stream["final_intermittent"], sh["final_intermittent"])
print("streamed sharded intermittent OK")
print("OK")
"""


_TELEMETRY_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import BrownoutConfig, fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.obs import counter_value
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, N, BLOCK = 6, 13, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
wins, labels = har_stream(key, S)
harvest = fleet_harvest_traces(key, N, S)
mesh = make_mesh_compat((8,), ("data",))
kw = dict(signatures=class_signatures(), qdnn_params=params,
          host_params=params, gen_params=gen, har_cfg=HAR, labels=labels,
          node_block=BLOCK, donate=False,
          brownout=BrownoutConfig(off_uj=8.0, restart_uj=28.0),
          initial_uj=10.0)

# --- registry lanes: single-device == sharded == streamed, bitwise --------
ref = seeker_fleet_simulate(wins, harvest, telemetry=True, **kw)
sh = seeker_fleet_simulate_sharded(wins, harvest, mesh=mesh, telemetry=True,
                                   **kw)
stream = seeker_fleet_simulate_streamed(wins, harvest, chunk=4, mesh=mesh,
                                        telemetry=True, **kw)
spec = ref["telemetry_spec"]
assert sh["telemetry_spec"] is spec and stream["telemetry_spec"] is spec
for name in spec.names():
    np.testing.assert_array_equal(np.asarray(sh["telemetry"][name]),
                                  np.asarray(ref["telemetry"][name]),
                                  err_msg="sharded " + name)
    np.testing.assert_array_equal(np.asarray(stream["telemetry"][name]),
                                  np.asarray(ref["telemetry"][name]),
                                  err_msg="streamed " + name)
# counters are exact ints, equal to the engine's own psum'd aggregates
tel = sh["telemetry"]
assert counter_value(tel, "fleet.wire_bytes") == wire_bytes_exact(sh)
assert counter_value(tel, "fleet.completed") == int(sh["completed"])
assert counter_value(tel, "fleet.alive_slots") == int(sh["alive_slots"])
assert counter_value(tel, "fleet.brownout_slots") == int(sh["brownout_slots"])
assert counter_value(tel, "fleet.brownout_events") \\
    == int(sh["brownout_events"])
np.testing.assert_array_equal(np.asarray(tel["fleet.decisions"]),
                              np.asarray(sh["decision_histogram"]))
print("telemetry lanes OK")

# --- telemetry=None leaves the sharded engine bitwise untouched ------------
off = seeker_fleet_simulate_sharded(wins, harvest, mesh=mesh, **kw)
assert "telemetry" not in off
for k in ("decisions", "payload_bytes", "stored_uj", "logits", "alive",
          "brownout"):
    np.testing.assert_array_equal(np.asarray(off[k]), np.asarray(sh[k]),
                                  err_msg="off " + k)
print("telemetry=None OK")
print("OK")
"""


_PER_SHARD_HOST_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core.recovery import init_generator
from repro.data.sensors import har_stream
from repro.models.har import har_init
from repro.serving import fleet_serve_step
from repro.host import (HostServeConfig, host_server_init,
                        host_server_init_stacked, host_server_stats)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
n = 13
wins, _ = har_stream(jax.random.PRNGKey(2), n)
cfg = HostServeConfig(channels=HAR.channels, k=12, m=20, t=HAR.window,
                      n_classes=HAR.n_classes, n_nodes=n, batch_size=4,
                      queue_capacity=16, cache_capacity=16, qos_slots=4)
alive = jnp.asarray([True] * 8 + [False] * 5)

def by_node(so):
    v = np.asarray(so.valid)
    return {int(nn): np.asarray(so.logits)[i]
            for i, nn in enumerate(np.asarray(so.node_id)) if v[i]}

for shape, axes in (((8,), ("data",)), ((2, 4), ("pod", "data"))):
    mesh = make_mesh_compat(shape, axes)
    central = fleet_serve_step(
        wins, host_params=params, har_cfg=HAR, mesh=mesh, key=key,
        host_state=host_server_init(cfg), serve_cfg=cfg, gen_params=gen,
        alive=alive)
    st = host_server_init_stacked(cfg, 8)
    ps = fleet_serve_step(
        wins, host_params=params, har_cfg=HAR, mesh=mesh, key=key,
        host_state=st, serve_cfg=cfg, gen_params=gen, alive=alive,
        per_shard_host=True)
    # psum'd QoS counters: every alive node served, nothing lost
    assert ps["qos"] == {"served": 8, "deadline_misses": 0,
                         "drops_overflow": 0}, ps["qos"]
    a, b = by_node(central["slot_output"]), by_node(ps["slot_output"])
    assert sorted(a) == sorted(b) == [0, 1, 2, 3, 4, 5, 6, 7]
    # payload-deterministic recovery PRNG: each node's answer matches the
    # central queue's (row-independent host DNN at the same batch shape)
    for nn in a:
        np.testing.assert_allclose(a[nn], b[nn], rtol=1e-6, atol=1e-6,
                                   err_msg=f"node {nn} mesh {shape}")
    # the stacked carry resumes: a second identical round is cache-served
    ps2 = fleet_serve_step(
        wins, host_params=params, har_cfg=HAR, mesh=mesh, key=key,
        host_state=ps["host_state"], serve_cfg=cfg, gen_params=gen,
        alive=alive, per_shard_host=True)
    assert ps2["qos"]["served"] == 16
    hits = int(jnp.sum(ps2["host_state"].cache.hits))
    assert hits == 8, hits
    b2 = by_node(ps2["slot_output"])
    for nn in b:
        np.testing.assert_array_equal(b[nn], b2[nn])
    print(f"mesh {shape} OK")
print("OK")
"""


_MIXED_TASK_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.seeker_har import HAR
from repro.core import BrownoutConfig, fleet_harvest_traces
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (TaskLaneConfig, seeker_fleet_simulate,
                           seeker_fleet_simulate_sharded,
                           seeker_fleet_simulate_streamed)
from repro.sharding import make_mesh_compat

assert jax.device_count() == 8, jax.device_count()
S, N, BLOCK = 6, 13, 4
key = jax.random.PRNGKey(0)
params = har_init(key, HAR)
gen = init_generator(key, HAR.window, HAR.channels)
wins, labels = har_stream(key, S)
harvest = fleet_harvest_traces(key, N, S)
mesh = make_mesh_compat((8,), ("data",))
cfg = TaskLaneConfig()   # round-robin har/bearing ids, bearing cost scale
kw = dict(signatures=class_signatures(), qdnn_params=params,
          host_params=params, gen_params=gen, har_cfg=HAR, labels=labels,
          node_block=BLOCK, donate=False, task=cfg,
          brownout=BrownoutConfig(off_uj=8.0, restart_uj=28.0),
          initial_uj=10.0)

ref = seeker_fleet_simulate(wins, harvest, **kw)
sh = seeker_fleet_simulate_sharded(wins, harvest, mesh=mesh, **kw)
stream = seeker_fleet_simulate_streamed(wins, harvest, chunk=4, mesh=mesh,
                                        **kw)

# --- mixed fleet traces bitwise across all three drivers -------------------
for k in ("decisions", "payload_bytes", "stored_uj", "logits", "alive",
          "brownout"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg="sharded " + k)
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(ref[k]),
                                  err_msg="streamed " + k)
assert sh["task_names"] == stream["task_names"] == ("har", "bearing")
np.testing.assert_array_equal(np.asarray(sh["tasks"]), np.asarray(ref["tasks"]))
print("mixed traces OK")

# --- per-task splits: psum'd ints EXACTLY equal single-device --------------
for k in ("completed_by_task", "deadline_miss_by_task", "correct_by_task"):
    np.testing.assert_array_equal(np.asarray(sh[k]), np.asarray(ref[k]),
                                  err_msg="sharded " + k)
    np.testing.assert_array_equal(np.asarray(stream[k]), np.asarray(ref[k]),
                                  err_msg="streamed " + k)

# recompute the split from the unsharded traces: padding (N=13 on 8 devices)
# must never enter a per-task count
tasks = np.asarray(ref["tasks"])
sent = (np.asarray(ref["decisions"]) != 5) & np.asarray(ref["alive"])
comp = np.asarray(sh["completed_by_task"])
miss = np.asarray(sh["deadline_miss_by_task"])
for t in range(cfg.n_tasks):
    assert comp[t] == sent[:, tasks == t].sum(), t
assert comp.sum() == int(sh["completed"])
assert comp.sum() + miss.sum() == int(sh["alive_slots"])
ok = np.asarray(ref["preds"]) == np.asarray(labels)[:, None]
corr = np.asarray(sh["correct_by_task"])
for t in range(cfg.n_tasks):
    assert corr[t] == (ok & sent)[:, tasks == t].sum(), t
print("per-task psum splits OK")
print("OK")
"""


@pytest.mark.slow
def test_sharded_mixed_task_fleet_psum_exact_8dev():
    """ISSUE 9 acceptance on the mesh: a mixed HAR+bearing fleet (task lane,
    round-robin ids, bearing cost scale) is bitwise identical single-device
    vs sharded vs streamed under brown-outs with N=13 padding, and the
    per-task aggregate splits (completed / deadline-miss / correct by task)
    are psum-exact integers that partition the fleet totals, recomputed
    from the unsharded traces."""
    assert "OK" in _run(_MIXED_TASK_CODE, devices=8)


@pytest.mark.slow
def test_sharded_fleet_bitwise_equivalence_8dev():
    """Sharded == unsharded bitwise on an 8-virtual-device CPU mesh, for
    divisible N=8, non-divisible N=13 (padding/masking path), and a 2-axis
    ("pod","data") mesh."""
    assert "OK" in _run(_EQUIV_CODE, devices=8)


@pytest.mark.slow
def test_sharded_churn_labels_streaming_8dev():
    """ISSUE 4 acceptance on the sharded engine: churn bitwise-equivalence
    against the single-device engine under one alive trace, per-node (S, N)
    label accuracy (psum'd ints exactly equal), the shared-track refusal,
    and streamed == one long sharded run."""
    assert "OK" in _run(_CHURN_CODE, devices=8)


@pytest.mark.slow
def test_sharded_brownout_parity_8dev():
    """ISSUE 5 acceptance on the mesh: endogenous brown-out churn is bitwise
    identical single-device vs sharded vs streamed — alive/brownout lanes,
    the psum'd ``brownout_slots``/``brownout_events`` pair (exact ints), the
    exogenous∧endogenous composition rule, the padding-never-browns-in
    guarantee (N=13 on 8 devices), and the exact int32-pair byte counter
    against an int64 recomputation."""
    assert "OK" in _run(_BROWNOUT_CODE, devices=8)


@pytest.mark.slow
def test_sharded_intermittent_parity_8dev():
    """ISSUE 7 acceptance on the mesh: the staged intermittent-inference
    lane is bitwise identical single-device vs sharded vs streamed under
    scarce harvest + brown-outs — it_* traces, the 9-bin histogram, the
    psum'd completion/accuracy counters (exact ints), suspended progress
    chained through the resume contract, and padding nodes (N=13 on 8
    devices) never entering any lane aggregate."""
    assert "OK" in _run(_INTERMITTENT_CODE, devices=8)


@pytest.mark.slow
def test_sharded_telemetry_lane_parity_8dev():
    """ISSUE 8 acceptance on the mesh: every registry lane (exact int-pair
    counters, gauges, histograms) is bitwise identical single-device vs
    sharded (psum inside shard_map) vs streamed (metrics_merge across
    segments) under brown-out churn with N=13 padding, counters equal the
    engine's own aggregates, and ``telemetry=None`` leaves the sharded
    engine bitwise untouched."""
    assert "OK" in _run(_TELEMETRY_CODE, devices=8)


@pytest.mark.slow
def test_fleet_serve_step_per_shard_host_8dev():
    """Per-shard host serving (the ROADMAP multi-host shape on one
    process): each shard's own queue/EDF/cache serves its local tile, only
    QoS counters cross shards (psum), answers match the central queue mode,
    and the stacked carry resumes with cache hits."""
    assert "OK" in _run(_PER_SHARD_HOST_CODE, devices=8)


@pytest.mark.slow
def test_fleet_serve_step_gathers_payloads_8dev():
    """The edge->host tier gathers only wire-format coreset payloads across
    the mesh; host logits match the unsharded encode/decode/recover oracle
    bitwise (the host side runs at the full gathered batch either way)."""
    assert "OK" in _run(_SERVE_CODE, devices=8)


# ---------------------------------------------------------------------------
# state0 resume (the silently-reset-initial_uj fix) — no mesh needed
# ---------------------------------------------------------------------------

S = 12


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, S)
    return key, params, gen, sigs, wins, labels


def test_fleet_state0_is_used_not_reset(setup):
    """The fix: a passed ``state0`` must drive the run — the engine used to
    silently rebuild node state with the default ``initial_uj``."""
    key, params, gen, sigs, wins, labels = setup
    from repro.serving.fleet import fleet_node_init
    n = 5
    harvest = fleet_harvest_traces(key, n, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)

    low = seeker_fleet_simulate(wins, harvest,
                                state0=fleet_node_init(n, initial_uj=5.0),
                                **kw)
    # state0 at charge X == fresh init with initial_uj=X, bit for bit
    oracle = seeker_fleet_simulate(wins, harvest, initial_uj=5.0, **kw)
    np.testing.assert_array_equal(np.asarray(low["decisions"]),
                                  np.asarray(oracle["decisions"]))
    np.testing.assert_array_equal(np.asarray(low["stored_uj"]),
                                  np.asarray(oracle["stored_uj"]))
    # ... and differs from the default-init run the old code always did
    default = seeker_fleet_simulate(wins, harvest, **kw)
    assert not np.array_equal(np.asarray(low["stored_uj"]),
                              np.asarray(default["stored_uj"]))


def test_fleet_resume_chain_matches_one_long_run(setup):
    """Serving-loop resume: chaining ``final_state -> state0`` AND
    ``final_keys -> node_keys`` makes two runs bitwise equal to one long
    run — charge, predictor history, AAC continuity and every node's PRNG
    stream all continue where the previous segment stopped."""
    key, params, gen, sigs, wins, labels = setup
    n = 4
    harvest = fleet_harvest_traces(key, n, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)
    half = S // 2
    full = seeker_fleet_simulate(wins, harvest, **kw)
    first = seeker_fleet_simulate(wins[:half], harvest[:, :half], **kw)
    second = seeker_fleet_simulate(wins[half:], harvest[:, half:],
                                   state0=first["final_state"],
                                   node_keys=first["final_keys"], **kw)
    for k in ("decisions", "payload_bytes", "stored_uj", "logits"):
        np.testing.assert_array_equal(np.asarray(second[k]),
                                      np.asarray(full[k][half:]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(second["final_state"].stored_uj),
                                  np.asarray(full["final_state"].stored_uj))
    np.testing.assert_array_equal(np.asarray(second["final_keys"]),
                                  np.asarray(full["final_keys"]))
    # and it is NOT the trajectory a silently-reset fleet would follow
    fresh = seeker_fleet_simulate(wins[half:], harvest[:, half:], **kw)
    assert not np.array_equal(np.asarray(second["stored_uj"]),
                              np.asarray(fresh["stored_uj"]))


def test_fleet_state0_wrong_size_raises(setup):
    key, params, gen, sigs, wins, labels = setup
    from repro.serving.fleet import fleet_node_init
    harvest = fleet_harvest_traces(key, 4, S)
    with pytest.raises(ValueError, match="stacked for"):
        seeker_fleet_simulate(wins, harvest, signatures=sigs,
                              qdnn_params=params, host_params=params,
                              gen_params=gen, har_cfg=HAR,
                              state0=fleet_node_init(3), donate=False)
