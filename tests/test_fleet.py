"""Fleet-engine tests: the batched simulator must reproduce the legacy
per-sensor loop node for node, and heterogeneous harvest must make per-node
energy trajectories diverge (the point of fleet-scale simulation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import (DEFER, fleet_harvest_traces, harvest_trace,
                        predictor_forecast, predictor_init, predictor_update)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (seeker_fleet_simulate, seeker_simulate,
                           seeker_simulate_reference)

S = 20  # time slots per simulation — small, every test compiles a scan


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, S)
    return key, params, gen, sigs, wins, labels


@pytest.fixture(scope="module")
def reference(setup):
    key, params, gen, sigs, wins, labels = setup
    harvest = harvest_trace(key, S, "rf")
    return harvest, seeker_simulate_reference(
        wins, labels, harvest, signatures=sigs, qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR)


def test_fleet_replicated_matches_reference(setup, reference):
    """N=3 fleet on a replicated harvest == the legacy 3-sensor loop:
    decisions exactly, payload bytes / stored energy to tolerance."""
    key, params, gen, sigs, wins, labels = setup
    harvest, ref = reference
    fleet = seeker_fleet_simulate(
        wins, jnp.broadcast_to(harvest[None], (3, S)), signatures=sigs,
        qdnn_params=params, host_params=params, gen_params=gen, har_cfg=HAR)
    np.testing.assert_array_equal(np.asarray(fleet["decisions"][:, 0]),
                                  np.asarray(ref["decisions"]))
    np.testing.assert_allclose(np.asarray(fleet["payload_bytes"][:, 0]),
                               np.asarray(ref["payload_bytes"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fleet["stored_uj"][:, 0]),
                               np.asarray(ref["stored_uj"]),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(fleet["k_trace"][:, 0]),
                                  np.asarray(ref["k_trace"]))
    # replicated harvest + replicated stream, but per-node PRNG folds:
    # every node went through the same decision ladder
    assert fleet["decisions"].shape == (S, 3)


def test_wrapper_matches_reference(setup, reference):
    """The public seeker_simulate (now a fleet wrapper) keeps the legacy
    trace contract."""
    key, params, gen, sigs, wins, labels = setup
    harvest, ref = reference
    res = seeker_simulate(wins, labels, harvest, signatures=sigs,
                          qdnn_params=params, host_params=params,
                          gen_params=gen, har_cfg=HAR)
    np.testing.assert_array_equal(np.asarray(res["decisions"]),
                                  np.asarray(ref["decisions"]))
    np.testing.assert_array_equal(np.asarray(res["preds"]),
                                  np.asarray(ref["preds"]))
    np.testing.assert_allclose(np.asarray(res["stored_uj"]),
                               np.asarray(ref["stored_uj"]),
                               rtol=1e-5, atol=1e-4)
    assert float(res["completed_frac"]) == pytest.approx(
        float(ref["completed_frac"]), abs=1e-6)


def test_fleet_heterogeneous_energy_diverges(setup):
    """Nodes fed different harvest modalities must follow different energy
    trajectories (per-node state is real, not broadcast)."""
    key, params, gen, sigs, wins, labels = setup
    n = 6
    harvest = fleet_harvest_traces(key, n, S)      # rf/wifi/piezo/solar mix
    fleet = seeker_fleet_simulate(wins, harvest, signatures=sigs,
                                  qdnn_params=params, host_params=params,
                                  gen_params=gen, har_cfg=HAR)
    stored = np.asarray(fleet["stored_uj"])        # (S, N)
    # at least two nodes end at different charge, and trajectories are not
    # all identical across nodes
    assert np.std(stored[-1]) > 1e-3
    assert not np.allclose(stored[:, 0], stored[:, 2])
    # all invariants still hold per node
    assert stored.min() >= 0.0 and stored.max() <= 200.0
    d = np.asarray(fleet["decisions"])
    assert ((d >= 0) & (d <= DEFER)).all()


def test_fleet_per_node_streams(setup):
    """(N, S, T, C) per-node window streams are accepted and keep shapes."""
    key, params, gen, sigs, wins, labels = setup
    n = 4
    wn = jnp.stack([wins + 0.01 * i for i in range(n)])
    harvest = fleet_harvest_traces(key, n, S)
    fleet = seeker_fleet_simulate(wn, harvest, signatures=sigs,
                                  qdnn_params=params, host_params=params,
                                  gen_params=gen, har_cfg=HAR)
    assert fleet["decisions"].shape == (S, n)
    assert fleet["logits"].shape == (S, n, HAR.n_classes)
    assert bool(jnp.all(jnp.isfinite(fleet["logits"])))


def test_fleet_n1_matches_reference_sensor0(setup, reference):
    """A 1-node fleet is bit-compatible with the reference's sensor 0 (same
    fold_in(key, 0) stream)."""
    key, params, gen, sigs, wins, labels = setup
    harvest, ref = reference
    fleet = seeker_fleet_simulate(wins, harvest[None], signatures=sigs,
                                  qdnn_params=params, host_params=params,
                                  gen_params=gen, har_cfg=HAR)
    np.testing.assert_array_equal(np.asarray(fleet["decisions"][:, 0]),
                                  np.asarray(ref["decisions"]))


# ---------------------------------------------------------------------------
# Batched core helpers the fleet carry relies on
# ---------------------------------------------------------------------------

def test_predictor_batched_matches_scalar():
    n, w = 4, 8
    batched = predictor_init(w, batch=n)
    scalars = [predictor_init(w) for _ in range(n)]
    inc = jnp.asarray([[1.0, 2.0, 3.0, 4.0], [10.0, 0.0, 5.0, 2.5]])
    for step_vals in inc:
        batched = predictor_update(batched, step_vals)
        scalars = [predictor_update(s, v) for s, v in zip(scalars, step_vals)]
    fb = predictor_forecast(batched)
    assert fb.shape == (n,)
    for i, s in enumerate(scalars):
        assert float(fb[i]) == pytest.approx(float(predictor_forecast(s)),
                                             rel=1e-6)


def test_predictor_batched_ring_wraps():
    n, w = 2, 3
    state = predictor_init(w, batch=n)
    for v in range(5):   # more updates than the window
        state = predictor_update(state, jnp.full((n,), float(v)))
    assert state.history.shape == (n, w)
    # last w values are 2, 3, 4 -> mean 3
    np.testing.assert_allclose(np.asarray(predictor_forecast(state)),
                               3.0, rtol=1e-6)


def test_fleet_harvest_traces_heterogeneous(key):
    tr = fleet_harvest_traces(key, 8, 32)
    assert tr.shape == (8, 32)
    assert bool(jnp.all(tr >= 0))
    # different modalities and folds: no two nodes share a trace
    t = np.asarray(tr)
    for i in range(7):
        assert not np.allclose(t[i], t[i + 1])
