"""The docs tier doesn't rot: links resolve, code references exist.

Checks every page under ``docs/`` plus the README for

- relative markdown links (``[text](target)``) pointing at files that
  actually exist in the repo;
- backticked dotted references (``repro.module.symbol``) that must resolve
  via importlib — a renamed function invalidates the page that cites it;
- backticked file paths (``serving/fleet.py``-style) that must exist under
  the repo root, ``src/`` or ``src/repro/``;
- ``tests/test_x.py::test_name`` references whose named test function must
  be defined in that file.

Fenced code blocks are excluded (ASCII diagrams and module-map trees are
illustrations, not references).
"""
import importlib
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_PAGES = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

_FENCE = re.compile(r"```.*?```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]+\]\(([^)]+)\)")
_SPAN = re.compile(r"`([^`]+)`")
_DOTTED = re.compile(r"^repro(\.\w+)+$")
_PATH = re.compile(r"^[\w./-]*/[\w.-]+\.(py|md)$")
_TEST_REF = re.compile(r"(tests/[\w/.-]+\.py)::(\w+)")


def _prose(page: Path) -> str:
    return _FENCE.sub("", page.read_text())


def _resolves(dotted: str) -> bool:
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


@pytest.mark.parametrize("page", DOC_PAGES, ids=lambda p: p.name)
def test_docs_exist_and_nonempty(page):
    assert page.is_file() and page.stat().st_size > 0


@pytest.mark.parametrize("page", DOC_PAGES, ids=lambda p: p.name)
def test_relative_links_resolve(page):
    broken = []
    for target in _LINK.findall(_prose(page)):
        if "://" in target or target.startswith("#"):
            continue
        rel = target.split("#")[0]
        if not (page.parent / rel).exists():
            broken.append(target)
    assert not broken, f"{page.name}: broken links {broken}"


@pytest.mark.parametrize("page", DOC_PAGES, ids=lambda p: p.name)
def test_code_references_resolve(page):
    broken = []
    for span in _SPAN.findall(_prose(page)):
        if _DOTTED.match(span) and not _resolves(span):
            broken.append(span)
    assert not broken, f"{page.name}: dangling code references {broken}"


@pytest.mark.parametrize("page", DOC_PAGES, ids=lambda p: p.name)
def test_file_path_references_resolve(page):
    broken = []
    for span in _SPAN.findall(_prose(page)):
        span = span.rstrip("/")
        if not _PATH.match(span):
            continue
        roots = (REPO, REPO / "src", REPO / "src" / "repro")
        if not any((r / span).exists() for r in roots):
            broken.append(span)
    assert not broken, f"{page.name}: dangling file references {broken}"


@pytest.mark.parametrize("page", DOC_PAGES, ids=lambda p: p.name)
def test_test_references_resolve(page):
    broken = []
    for path, func in _TEST_REF.findall(page.read_text()):
        f = REPO / path
        if not f.is_file() or f"def {func}(" not in f.read_text():
            broken.append(f"{path}::{func}")
    assert not broken, f"{page.name}: dangling test references {broken}"


def test_readme_links_every_docs_page():
    readme = (REPO / "README.md").read_text()
    missing = [p.name for p in (REPO / "docs").glob("*.md")
               if f"docs/{p.name}" not in readme]
    assert not missing, f"docs pages not linked from README: {missing}"
