"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — tests
run single-device by design; mesh/dry-run integration tests spawn
subprocesses with their own flags (see test_dryrun_smoke.py)."""
import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
