"""Host-tier queue + EDF scheduler invariants (ISSUE 3 satellites).

Property-tested through the ``tests/_prop.py`` shim:

* EDF pops return live entries in deadline order (stable tie-break);
* overflow drops the LATEST-deadline entry — incoming or resident — and
  increments the drop counter by exactly one per overflowing push;
* expiry accounting: entries whose deadline passed are counted as misses,
  never served;
* the ring reuses slots across push/pop cycles well past its capacity.

The queue under test carries a tiny scalar payload pytree — the queue is
payload-agnostic; the full ``HostPayload`` plumbing is exercised by
test_host_server.py.
"""
import jax
import jax.numpy as jnp
import numpy as np

from _prop import given, settings, st

from repro.host import (MicroBatch, NO_DEADLINE, edf_pop_batch,
                        expire_deadlines, queue_init, queue_occupancy,
                        queue_push, queue_push_batch)


def _mini_queue(capacity):
    """Queue whose payload is a single () int32 'payload id' leaf."""
    return queue_init({"pid": jnp.zeros((), jnp.int32)}, capacity)


def _push_all(q, deadlines, arrival=0):
    for i, d in enumerate(deadlines):
        q, _ = queue_push(q, {"pid": jnp.asarray(i, jnp.int32)},
                          node_id=i, arrival=arrival, deadline=d)
    return q


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.integers(4, 24),
       batch=st.integers(1, 8))
def test_edf_pops_in_deadline_order(seed, cap, batch):
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, cap + 1))
    deadlines = rng.randint(0, 1000, size=n)
    q = _push_all(_mini_queue(cap), deadlines)

    popped = []
    for _ in range(-(-n // batch)):
        q, mb, missed = edf_pop_batch(q, batch)
        assert int(missed) == 0
        popped.extend(np.asarray(mb.deadline)[np.asarray(mb.valid)].tolist())
    assert popped == sorted(deadlines.tolist())
    assert int(queue_occupancy(q)) == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.integers(2, 12))
def test_overflow_drops_latest_deadline_and_counts(seed, cap):
    rng = np.random.RandomState(seed)
    # cap + 1 DISTINCT deadlines: exactly one must be dropped — the largest
    deadlines = rng.permutation(cap + 1) * 7 + int(rng.randint(0, 100))
    q = _push_all(_mini_queue(cap), deadlines)

    assert int(q.drops_overflow) == 1
    assert int(queue_occupancy(q)) == cap
    kept = np.asarray(q.deadline)[np.asarray(q.valid)]
    assert sorted(kept.tolist()) == sorted(deadlines.tolist())[:-1], \
        "the latest-deadline entry must be the one dropped"


def test_overflow_prefers_evicting_resident_with_later_deadline():
    q = _push_all(_mini_queue(2), [10, 20])
    # incoming deadline 5 beats resident 20 -> 20 is evicted
    q, dropped = queue_push(q, {"pid": jnp.asarray(99, jnp.int32)},
                            node_id=9, arrival=0, deadline=5)
    assert bool(dropped)
    kept = sorted(np.asarray(q.deadline)[np.asarray(q.valid)].tolist())
    assert kept == [5, 10]
    assert int(q.drops_overflow) == 1
    # incoming deadline 30 is the latest -> incoming itself is dropped
    q, dropped = queue_push(q, {"pid": jnp.asarray(98, jnp.int32)},
                            node_id=8, arrival=0, deadline=30)
    assert bool(dropped)
    kept = sorted(np.asarray(q.deadline)[np.asarray(q.valid)].tolist())
    assert kept == [5, 10]
    assert int(q.drops_overflow) == 2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), now=st.integers(0, 50))
def test_expiry_counts_misses_and_never_serves_late(seed, now):
    rng = np.random.RandomState(seed)
    deadlines = rng.randint(0, 100, size=12)
    q = _push_all(_mini_queue(16), deadlines)
    late = int((deadlines < now).sum())

    q, missed = expire_deadlines(q, jnp.asarray(now))
    assert int(missed) == late
    q2, mb, missed2 = edf_pop_batch(q, 16, now=jnp.asarray(now))
    assert int(missed2) == 0                     # already expired above
    served = np.asarray(mb.deadline)[np.asarray(mb.valid)]
    assert (served >= now).all()
    assert len(served) == len(deadlines) - late


def test_edf_pop_expires_before_assembly():
    q = _push_all(_mini_queue(8), [1, 2, 9, 10])
    q, mb, missed = edf_pop_batch(q, 4, now=jnp.asarray(5))
    assert int(missed) == 2                      # deadlines 1, 2 are late
    served = np.asarray(mb.deadline)[np.asarray(mb.valid)]
    np.testing.assert_array_equal(served, [9, 10])


def test_partial_batch_is_masked_padding():
    q = _push_all(_mini_queue(8), [3])
    q, mb, _ = edf_pop_batch(q, 4)
    assert isinstance(mb, MicroBatch)
    assert mb.deadline.shape == (4,) and mb.valid.shape == (4,)
    assert int(np.asarray(mb.valid).sum()) == 1
    # padding rows carry the empty-slot sentinel deadline
    assert (np.asarray(mb.deadline)[~np.asarray(mb.valid)]
            == NO_DEADLINE).all()


def test_ring_reuses_slots_across_many_cycles():
    cap = 4
    q = _mini_queue(cap)
    for cycle in range(5 * cap):
        q, dropped = queue_push(q, {"pid": jnp.asarray(cycle, jnp.int32)},
                                node_id=cycle, arrival=cycle,
                                deadline=cycle + 3)
        assert not bool(dropped)
        q, mb, missed = edf_pop_batch(q, 1, now=jnp.asarray(cycle))
        assert int(missed) == 0
        assert int(np.asarray(mb.payload["pid"])[0]) == cycle
    assert int(queue_occupancy(q)) == 0
    assert int(q.drops_overflow) == 0


def test_push_batch_masks_inert_rows():
    q = _mini_queue(8)
    pids = jnp.arange(6, dtype=jnp.int32)
    mask = jnp.asarray([True, False, True, True, False, True])
    q, n_dropped = queue_push_batch(
        q, {"pid": pids}, jnp.arange(6, dtype=jnp.int32),
        jnp.zeros(6, jnp.int32), jnp.arange(6, dtype=jnp.int32) + 10, mask)
    assert int(n_dropped) == 0
    assert int(queue_occupancy(q)) == 4
    live = sorted(np.asarray(q.payload["pid"])[np.asarray(q.valid)].tolist())
    assert live == [0, 2, 3, 5]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.integers(4, 16))
def test_push_batch_bulk_path_matches_sequential(seed, cap):
    """The vectorized no-overflow fast path must be bitwise-equal (slots,
    cursor, counters) to A sequential pushes."""
    rng = np.random.RandomState(seed)
    pre = int(rng.randint(0, cap // 2 + 1))
    q0 = _push_all(_mini_queue(cap), rng.randint(0, 50, size=pre))
    # pop a couple to move the cursor / punch holes
    for _ in range(int(rng.randint(0, pre + 1))):
        q0, _, _ = edf_pop_batch(q0, 1)

    a = int(rng.randint(1, cap - int(np.asarray(queue_occupancy(q0))) + 1))
    pids = jnp.arange(100, 100 + a, dtype=jnp.int32)
    nids = jnp.arange(a, dtype=jnp.int32)
    arrs = jnp.zeros(a, jnp.int32)
    dls = jnp.asarray(rng.randint(0, 50, size=a), jnp.int32)
    mask = jnp.asarray(rng.rand(a) < 0.8)

    batch_q, n_drop = queue_push_batch(q0, {"pid": pids}, nids, arrs, dls,
                                       mask)
    seq_q = q0
    for i in range(a):
        seq_q, _ = queue_push(seq_q, {"pid": pids[i]}, nids[i], arrs[i],
                              dls[i], mask[i])
    assert int(n_drop) == 0
    for leaf_a, leaf_b in zip(jax.tree_util.tree_leaves(batch_q),
                              jax.tree_util.tree_leaves(seq_q)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap=st.integers(2, 12),
       lane=st.integers(2, 12))
def test_push_batch_overflow_path_matches_sequential(seed, cap, lane):
    """The OVERFLOW path of queue_push_batch (the lax.cond fallback when the
    masked lane does not fit the free slots) must be bitwise-equal — slots,
    payloads, cursor AND drops_overflow — to N repeated queue_push calls
    under random masks and deadlines that force eviction.  (The bulk==
    sequential property above only ever exercises the no-overflow path.)"""
    rng = np.random.RandomState(seed)
    # pre-fill most of the ring so the incoming lane overflows it
    pre = int(rng.randint(max(cap - 2, 1), cap + 1))
    q0 = _push_all(_mini_queue(cap), rng.randint(0, 50, size=pre))
    for _ in range(int(rng.randint(0, 2))):       # maybe move the cursor
        q0, _, _ = edf_pop_batch(q0, 1)

    pids = jnp.arange(100, 100 + lane, dtype=jnp.int32)
    nids = jnp.arange(lane, dtype=jnp.int32)
    arrs = jnp.zeros(lane, jnp.int32)
    # mixed deadlines: some earlier than the residents (forcing eviction of
    # a resident), some later (the incoming entry itself is dropped)
    dls = jnp.asarray(rng.randint(0, 100, size=lane), jnp.int32)
    mask = jnp.asarray(rng.rand(lane) < 0.8)

    n_free = cap - int(np.asarray(queue_occupancy(q0)))
    if int(np.asarray(mask).sum()) <= n_free:
        mask = jnp.ones((lane,), bool)            # force the overflow branch
    if int(np.asarray(mask).sum()) <= n_free:
        return                                    # lane can't overflow cap

    batch_q, n_drop = queue_push_batch(q0, {"pid": pids}, nids, arrs, dls,
                                       mask)
    seq_q = q0
    drops = 0
    for i in range(lane):
        seq_q, dropped = queue_push(seq_q, {"pid": pids[i]}, nids[i],
                                    arrs[i], dls[i], mask[i])
        drops += int(dropped)
    assert drops > 0, "property must exercise the eviction path"
    assert int(n_drop) == drops
    for leaf_a, leaf_b in zip(jax.tree_util.tree_leaves(batch_q),
                              jax.tree_util.tree_leaves(seq_q)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


def test_queue_ops_are_jittable():
    """The whole push/pop cycle traces into one jitted fn (the serve slot
    relies on this)."""
    q = _mini_queue(8)

    @jax.jit
    def cycle(q, pid, deadline, now):
        q, _ = queue_push(q, {"pid": pid}, node_id=0, arrival=now,
                          deadline=deadline)
        q, mb, missed = edf_pop_batch(q, 2, now=now)
        return q, mb, missed

    q, mb, missed = cycle(q, jnp.asarray(7, jnp.int32),
                          jnp.asarray(4, jnp.int32), jnp.asarray(1, jnp.int32))
    assert int(np.asarray(mb.valid).sum()) == 1
    assert int(np.asarray(mb.payload["pid"])[0]) == 7
