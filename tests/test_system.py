"""End-to-end system behaviour: train the paper's HAR classifier on the
synthetic stream, run the full Seeker pipeline, and check the paper's
qualitative claims hold on this substrate:

* coreset-recovered inference ~ raw inference >> naive-coreset inference,
* quantized (16/12-bit) edge DNN ~ full precision,
* payload accounting matches the 240 B -> 42 B arithmetic,
* the whole system beats chance by a wide margin under harvested energy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import harvest_trace, kmeans_coreset, points_from_window
from repro.core.recovery import init_generator, recover_cluster_window
from repro.data.sensors import class_signatures, har_dataset, har_stream
from repro.models.har import (har_apply, har_apply_quantized, har_init)
from repro.serving import seeker_simulate


@pytest.fixture(scope="module")
def trained_har():
    """Train the HAR CNN for a few hundred steps on synthetic MHEALTH."""
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    xs, ys = har_dataset(jax.random.fold_in(key, 1), 1024)

    def loss_fn(p, x, y):
        logits = har_apply(p, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, x, y, lr):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, l

    for i in range(300):
        idx = jax.random.randint(jax.random.fold_in(key, 100 + i), (64,),
                                 0, xs.shape[0])
        params, _ = step(params, xs[idx], ys[idx], 3e-2)
    x_test, y_test = har_dataset(jax.random.fold_in(key, 2), 256)
    acc = float(jnp.mean(jnp.argmax(har_apply(params, x_test), -1) == y_test))
    assert acc > 0.85, f"classifier failed to train: {acc}"
    return params, (x_test, y_test), acc


def _acc(params, x, y, apply=har_apply, **kw):
    return float(jnp.mean(jnp.argmax(apply(params, x, **kw), -1) == y))


def test_quantized_dnn_close_to_full(trained_har):
    """Paper Fig. 2c: 16/12-bit PTQ within a few points of full precision."""
    params, (x, y), acc = trained_har
    acc16 = _acc(params, x, y, har_apply_quantized, bits=16)
    acc12 = _acc(params, x, y, har_apply_quantized, bits=12)
    acc2 = _acc(params, x, y, har_apply_quantized, bits=2)
    assert acc16 >= acc - 0.03
    assert acc12 >= acc - 0.06
    assert acc2 < acc16 - 0.05   # extreme quantization does degrade


def test_recovered_coreset_inference(trained_har):
    """Paper §5.2: recovered-coreset accuracy approaches raw accuracy."""
    params, (x, y), acc = trained_har
    key = jax.random.PRNGKey(3)
    keys = jax.random.split(key, x.shape[0])

    def rec_one(w, k):
        cs = kmeans_coreset(points_from_window(w), k=12, iters=4)
        return recover_cluster_window(cs, k, w.shape[0])

    x_rec = jax.vmap(rec_one)(x, keys)
    acc_rec = _acc(params, x_rec, y)
    assert acc_rec > 0.55, acc_rec           # well above 1/12 chance
    assert acc_rec >= acc - 0.35             # within reach of raw


def test_full_system_under_harvested_energy(trained_har):
    """The integrated Seeker system: meaningful accuracy and >=5x mean
    communication reduction under a WiFi harvest trace."""
    params, _, _ = trained_har
    key = jax.random.PRNGKey(4)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, 96)
    res = seeker_simulate(wins, labels, harvest_trace(key, 96, "wifi"),
                          signatures=class_signatures(), qdnn_params=params,
                          host_params=params, gen_params=gen, har_cfg=HAR)
    assert float(res["completed_frac"]) > 0.3
    acc = float(res["accuracy_completed"])
    assert acc > 0.4, acc                    # >> 1/12 chance
    sent = np.asarray(res["decisions"]) != 5
    mean_payload = float(np.mean(np.asarray(res["payload_bytes"])[sent]))
    assert 240.0 / max(mean_payload, 1e-9) >= 5.0
