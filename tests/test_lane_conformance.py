"""Static conformance of the fleet lane registry (ISSUE 9).

Fail-fast companion to tests/test_docs.py: before any engine runs, every
lane registered in :data:`repro.serving.FLEET_LANES` must carry the full
protocol — an ``init`` reference that resolves to a real callable, a legal
freeze kind, a resume contract for anything it actually carries, aggregate
declarations consistent with the counters it streams, and a section in
docs/RESUME_CONTRACT.md.  Adding a lane is ONE registration; forgetting any
of its declared duties is a test failure here, not a silent engine bug.
"""
import importlib
import pathlib
import re

import pytest

from repro.serving.fleet_lanes import (FLEET_LANES, FREEZE_KINDS, FleetCarry,
                                       FleetLane, fleet_lane)

DOC = pathlib.Path(__file__).resolve().parents[1] / "docs" / \
    "RESUME_CONTRACT.md"
LANE_IDS = [ln.name for ln in FLEET_LANES]


def _lane(name) -> FleetLane:
    return fleet_lane(name)


def test_registry_covers_every_carry_field():
    """Each FleetCarry field is owned by exactly one registered lane."""
    owners = [ln.carry_field for ln in FLEET_LANES
              if ln.carry_field is not None]
    assert sorted(owners) == sorted(set(owners)), \
        f"duplicate carry-field owners: {owners}"
    assert set(owners) == set(FleetCarry._fields), \
        f"carry fields without a registered lane: " \
        f"{set(FleetCarry._fields) - set(owners)}"


def test_lane_names_unique():
    assert len(LANE_IDS) == len(set(LANE_IDS))


@pytest.mark.parametrize("name", LANE_IDS)
def test_lane_declares_protocol(name):
    """init / freeze / resume / aggregate declarations are all present and
    well-formed — the harness in tests/test_resume_contract.py relies on
    every one of them."""
    ln = _lane(name)
    assert ln.doc and ln.doc.strip(), f"{name}: missing doc"
    assert ln.freeze in FREEZE_KINDS, f"{name}: freeze {ln.freeze!r}"
    assert ln.init and ":" in ln.init, \
        f"{name}: init must be a 'module:attr' reference, got {ln.init!r}"
    # a lane that owns a carry field must say how to resume it
    if ln.carry_field is not None:
        assert ln.resume_in, f"{name}: carried lane without resume_in"
        assert ln.resume_out, f"{name}: carried lane without resume_out"
    # counters it streams must be declared aggregates
    missing = set(ln.counter_keys) - set(ln.aggregates) - {
        "decision_histogram", "completed", "alive_slots", "correct"}
    assert not set(ln.counter_keys) - set(ln.aggregates), \
        f"{name}: counter_keys {missing} not declared in aggregates"


@pytest.mark.parametrize("name", LANE_IDS)
def test_lane_init_reference_resolves(name):
    """The registered ``module:attr`` init is a real importable callable."""
    mod, attr = _lane(name).init.split(":")
    fn = getattr(importlib.import_module(mod), attr, None)
    assert callable(fn), f"{name}: init {mod}:{attr} does not resolve"


@pytest.mark.parametrize("name", LANE_IDS)
def test_lane_documented_in_resume_contract(name):
    """Every registered lane has its section in docs/RESUME_CONTRACT.md —
    an undocumented lane fails here before the engines ever run."""
    text = DOC.read_text()
    assert re.search(rf"`{re.escape(name)}`", text), \
        f"lane {name!r} is not documented in docs/RESUME_CONTRACT.md"


@pytest.mark.parametrize("name", LANE_IDS)
def test_lane_resume_keys_documented(name):
    """The resume-contract doc names each carried lane's resume keys, so the
    doc cannot drift from the registry."""
    text = DOC.read_text()
    ln = _lane(name)
    for k in (*ln.resume_in, *ln.resume_out):
        assert f"`{k}`" in text, \
            f"lane {name!r}: resume key {k!r} missing from " \
            f"docs/RESUME_CONTRACT.md"


def test_active_off_states():
    """Lanes with no config kwarg are always active; output lanes advertise
    their off-state presence correctly."""
    for ln in FLEET_LANES:
        if ln.config_kwarg is None:
            assert ln.active(frozenset()), ln.name
    assert _lane("brownout").outputs_when_off
    assert _lane("churn").outputs_when_off
    assert not _lane("intermittent").outputs_when_off
    assert not _lane("task").outputs_when_off
