"""Coreset construction unit + property tests (paper §3.1-3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (
    cluster_payload_bytes, dequantize_uniform, importance_coreset,
    importance_weights, kmeans_coreset, points_from_window,
    quantize_uniform, raw_payload_bytes, sampling_payload_bytes,
    topk_importance_coreset, window_from_points,
)


def _window(seed: int, t: int = 60, c: int = 3) -> jnp.ndarray:
    k = jax.random.PRNGKey(seed)
    tt = jnp.linspace(0, 4 * jnp.pi, t)[:, None]
    return jnp.sin(tt * (1 + seed % 3)) + 0.1 * jax.random.normal(k, (t, c))


# ---------------------------------------------------------------------------
# Paper arithmetic (the 240 B -> 36/42 B -> 8.9x headline numbers)
# ---------------------------------------------------------------------------

def test_paper_byte_accounting():
    assert raw_payload_bytes(60) == 240                      # §3.2
    assert cluster_payload_bytes(12, recoverable=False) == 36
    assert cluster_payload_bytes(12, recoverable=True) == 42  # +4 bits/cluster
    # 42 B is the paper's 5.7x claim
    assert pytest.approx(240 / 42, abs=0.02) == 5.71
    assert sampling_payload_bytes(20, with_moments=False) == 60


def test_kmeans_partitions_all_points():
    pts = points_from_window(_window(0))
    cs = kmeans_coreset(pts, k=12, iters=4)
    assert int(cs.counts.sum()) == pts.shape[0]
    assert cs.centers.shape == (12, pts.shape[1])
    assert bool(jnp.all(cs.radii >= 0))


def test_kmeans_radius_covers_members():
    """Every point lies within the radius of its nearest center (the 2r
    recovery guarantee of §3.2.2 depends on this)."""
    pts = points_from_window(_window(1))
    cs = kmeans_coreset(pts, k=8, iters=4)
    d = jnp.linalg.norm(pts[:, None] - cs.centers[None], axis=-1)
    assign = jnp.argmin(d, axis=1)
    dist = jnp.take_along_axis(d, assign[:, None], axis=1)[:, 0]
    assert bool(jnp.all(dist <= cs.radii[assign] + 1e-5))


def test_kmeans_paper_hw_limits():
    """Paper §4.2: <=16 points per cluster at k=12 on 60-pt windows, 4 Lloyd
    iterations suffice (objective stops improving materially)."""
    for seed in range(8):
        pts = points_from_window(_window(seed))
        cs = kmeans_coreset(pts, k=12, iters=4)
        assert int(cs.counts.max()) <= 16
        cs8 = kmeans_coreset(pts, k=12, iters=8)
        # doubling the iteration budget moves centers only marginally
        drift = float(jnp.max(jnp.abs(cs.centers - cs8.centers)))
        spread = float(jnp.max(pts) - jnp.min(pts))
        assert drift <= 0.25 * spread


def test_importance_weights_are_distribution():
    w = importance_weights(_window(2))
    assert w.shape == (60,)
    np.testing.assert_allclose(float(w.sum()), 1.0, rtol=1e-5)
    assert bool(jnp.all(w >= 0))


def test_importance_coreset_shapes_and_sorted(key):
    sc = importance_coreset(_window(3), 20, key)
    assert sc.indices.shape == (20,)
    assert sc.values.shape == (20, 3)
    assert bool(jnp.all(jnp.diff(sc.indices) > 0))      # unique + ascending
    assert bool(jnp.all(sc.indices >= 0)) and bool(jnp.all(sc.indices < 60))


def test_topk_variant_deterministic():
    a = topk_importance_coreset(_window(4), 16)
    b = topk_importance_coreset(_window(4), 16)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))


def test_window_points_roundtrip():
    w = _window(5)
    pts = points_from_window(w)
    back = window_from_points(pts, w.shape[0])
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), k=st.integers(2, 16),
       n=st.integers(17, 80))
def test_kmeans_invariants_property(seed, k, n):
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, 3))
    cs = kmeans_coreset(pts, k=k, iters=4)
    assert int(cs.counts.sum()) == n
    assert bool(jnp.all(cs.counts >= 0))
    assert bool(jnp.all(jnp.isfinite(cs.centers)))
    # radius coverage
    d = jnp.linalg.norm(pts[:, None] - cs.centers[None], axis=-1)
    assign = jnp.argmin(d, axis=1)
    dist = jnp.take_along_axis(d, assign[:, None], axis=1)[:, 0]
    assert bool(jnp.all(dist <= cs.radii[assign] + 1e-4))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**30), bits=st.sampled_from([4, 8, 12, 16]))
def test_quantize_roundtrip_error_bound(seed, bits):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (64,), minval=-3, maxval=5)
    lo, hi = float(x.min()), float(x.max())
    codes = quantize_uniform(x, bits, lo, hi)
    back = dequantize_uniform(codes, bits, lo, hi)
    step = (hi - lo) / (2 ** bits - 1)
    assert float(jnp.max(jnp.abs(back - x))) <= step / 2 + 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30), m=st.integers(4, 40))
def test_importance_selection_property(seed, m):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (60, 2))
    sc = importance_coreset(w, m, key)
    assert sc.indices.shape == (m,)
    assert len(set(np.asarray(sc.indices).tolist())) == m   # no repeats
    # values are the actual window samples
    np.testing.assert_allclose(np.asarray(sc.values),
                               np.asarray(w[sc.indices]), rtol=1e-6)
