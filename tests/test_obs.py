"""Observability layer (ISSUE 8): registry exactness, engine telemetry
parity, host QoS percentiles, compile-shape budgets, the span tracer's
Chrome-trace export, and the benchmark regression gate.

The sharded half of the parity contract (lanes bitwise-equal across an
8-virtual-device mesh) lives in tests/test_fleet_sharded.py, which already
owns the subprocess mesh idiom.
"""
import importlib.util
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import DEFER, BrownoutConfig, fleet_harvest_traces
from repro.core.coreset import channel_cluster_coresets
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.host import (CLUSTER_KIND, HostServeConfig, cluster_entries,
                        host_serve_slot, host_server_init, host_server_stats,
                        host_telemetry_spec)
from repro.models.har import har_init
from repro.obs import (CompileBudgetError, MetricsSpec, categorical_counts,
                       compile_count, compile_event, compile_guard, counter,
                       counter_add, counter_value, gauge, gauge_set,
                       hist_observe, histogram, int_pair_sum, int_pair_total,
                       lane_edges, metrics_init, metrics_merge,
                       metrics_summary, percentile_from_hist, trace)
from repro.serving import (encode_wire_coresets, fleet_telemetry_spec,
                           seeker_fleet_simulate,
                           seeker_fleet_simulate_streamed, wire_bytes_exact)

S, N = 6, 5


# ---------------------------------------------------------------------------
# Registry: exact int accounting on a jit-friendly pytree
# ---------------------------------------------------------------------------

def _spec():
    return MetricsSpec((counter("c", unit="B"), gauge("g"),
                        histogram("h_log", bins=6, lo=1.0, hi=100.0),
                        histogram("h_cat", bins=4, log=False)))


def test_counter_exact_past_float32_precision():
    """The reason counters are int32 pairs: float32 loses bytes past 2**24.
    Accumulate well beyond that and match an arbitrary-precision oracle."""
    spec = _spec()
    m = metrics_init(spec)
    vals = jnp.full((1000,), 2**21 + 7, jnp.int32)      # ~2.1e9 per round
    oracle = 0
    for _ in range(9):
        m = counter_add(spec, m, "c", vals)
        oracle += 1000 * (2**21 + 7)
    assert counter_value(m, "c") == oracle              # ~1.9e10 >> 2**24
    assert float(np.float32(oracle)) != oracle          # float32 would drift
    # the stored pair is canonical (lo digit < 2**16) — bitwise-comparable
    assert int(m["c"][1]) < 2**16


def test_counter_masks_bools_and_rounds_floats():
    spec = _spec()
    m = metrics_init(spec)
    m = counter_add(spec, m, "c", jnp.asarray([3.0, 4.0, 100.0]),
                    mask=jnp.asarray([True, True, False]))
    m = counter_add(spec, m, "c", jnp.asarray([True, False, True]))
    assert counter_value(m, "c") == 7 + 2
    pair = int_pair_sum(jnp.asarray([70000, 70000]))    # digit-split is exact
    assert int_pair_total(pair) == 140000


def test_gauge_latest_wins_and_kind_checks():
    spec = _spec()
    m = metrics_init(spec)
    m = gauge_set(spec, m, "g", jnp.asarray(41))
    m = gauge_set(spec, m, "g", jnp.asarray(17))
    assert int(m["g"]) == 17
    with pytest.raises(ValueError, match="not a counter"):
        counter_add(spec, m, "g", jnp.asarray([1]))
    with pytest.raises(ValueError, match="not a gauge"):
        gauge_set(spec, m, "c", jnp.asarray(1))
    with pytest.raises(ValueError, match="not a histogram"):
        hist_observe(spec, m, "c", jnp.asarray([1.0]))
    with pytest.raises(KeyError, match="no lane"):
        spec.lane("nope")
    with pytest.raises(ValueError, match="duplicate lane"):
        MetricsSpec((counter("x"), gauge("x")))


def test_histogram_binning_log_and_categorical():
    spec = _spec()
    m = metrics_init(spec)
    # log lane: v <= lo -> bin 0, v > hi -> overflow bin (the last)
    m = hist_observe(spec, m, "h_log",
                     jnp.asarray([0.5, 1.0, 5.0, 99.0, 1e6]))
    counts = np.asarray(m["h_log"])
    assert counts.sum() == 5
    assert counts[0] == 2 and counts[-1] == 1
    # categorical lane: integer k lands in bin k, clipped into the last
    m = hist_observe(spec, m, "h_cat", jnp.asarray([0, 1, 1, 3, 9]),
                     mask=jnp.asarray([1, 1, 1, 1, 0]))
    np.testing.assert_array_equal(np.asarray(m["h_cat"]), [1, 2, 0, 1])
    assert lane_edges(spec.lane("h_cat")) == (0.5, 1.5, 2.5)


def test_categorical_counts_matches_bincount():
    rng = np.random.RandomState(0)
    codes = rng.randint(0, 6, size=(7, 11))
    mask = rng.rand(7, 11) < 0.6
    got = np.asarray(categorical_counts(jnp.asarray(codes), 6,
                                        jnp.asarray(mask)))
    np.testing.assert_array_equal(got,
                                  np.bincount(codes[mask], minlength=6))


def test_percentile_from_hist_interpolates():
    # 4 obs in [0, 1], 8 in (1, 2]: p50 target is 6 obs -> 1/4 into bin 1
    edges = [1.0, 2.0, 3.0]
    assert percentile_from_hist([4, 8, 0, 0], edges, 50.0) \
        == pytest.approx(1.25)
    assert percentile_from_hist([4, 8, 0, 0], edges, 100.0) \
        == pytest.approx(2.0)
    # overflow bin reports its lower edge ("at least hi")
    assert percentile_from_hist([0, 0, 0, 5], edges, 50.0) == 3.0
    assert np.isnan(percentile_from_hist([0, 0, 0, 0], edges, 50.0))


def test_merge_chain_equals_single_pass():
    """The streamed resume rule: merging per-segment metrics is bitwise the
    one-long-run lane state."""
    spec = _spec()
    rng = np.random.RandomState(3)
    segs = []
    one = metrics_init(spec)
    for i in range(3):
        m = metrics_init(spec)
        vals = jnp.asarray(rng.randint(0, 10**6, size=16))
        hv = jnp.asarray(rng.uniform(0.5, 200.0, size=16))
        m = counter_add(spec, m, "c", vals)
        m = gauge_set(spec, m, "g", jnp.asarray(i))
        m = hist_observe(spec, m, "h_log", hv)
        one = counter_add(spec, one, "c", vals)
        one = gauge_set(spec, one, "g", jnp.asarray(i))
        one = hist_observe(spec, one, "h_log", hv)
        segs.append(m)
    merged = None
    for m in segs:
        merged = metrics_merge(spec, merged, m)
    for name in spec.names():
        np.testing.assert_array_equal(np.asarray(merged[name]),
                                      np.asarray(one[name]), err_msg=name)
    summ = metrics_summary(spec, merged)
    assert summ["c"] == counter_value(one, "c") and summ["g"] == 2
    assert set(summ["h_log"]) == {"counts", "edges", "unit",
                                  "p50", "p95", "p99"}


# ---------------------------------------------------------------------------
# Fleet engine telemetry: off = bitwise-identical, on = lanes match the
# engine's own aggregates, streamed chain = one long run
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, S)
    harvest = fleet_harvest_traces(key, N, S)
    kw = dict(signatures=class_signatures(), qdnn_params=params,
              host_params=params, gen_params=gen, har_cfg=HAR,
              labels=labels, donate=False)
    return key, wins, harvest, kw


def test_fleet_telemetry_none_is_bitwise_identical(fleet_setup):
    key, wins, harvest, kw = fleet_setup
    off = seeker_fleet_simulate(wins, harvest, **kw)
    on = seeker_fleet_simulate(wins, harvest, telemetry=True, **kw)
    assert "telemetry" not in off
    for k in ("decisions", "payload_bytes", "stored_uj", "logits", "preds"):
        np.testing.assert_array_equal(np.asarray(on[k]), np.asarray(off[k]),
                                      err_msg=k)


def test_fleet_lanes_match_engine_aggregates(fleet_setup):
    key, wins, harvest, kw = fleet_setup
    res = seeker_fleet_simulate(wins, harvest, telemetry=True,
                                brownout=BrownoutConfig(off_uj=8.0,
                                                        restart_uj=28.0),
                                initial_uj=10.0, **kw)
    tel, spec = res["telemetry"], res["telemetry_spec"]
    assert spec is fleet_telemetry_spec(False)
    assert counter_value(tel, "fleet.wire_bytes") == wire_bytes_exact(res)
    assert counter_value(tel, "fleet.completed") == int(res["completed"])
    assert counter_value(tel, "fleet.alive_slots") == int(res["alive_slots"])
    assert counter_value(tel, "fleet.brownout_slots") \
        == int(res["brownout_slots"])
    assert counter_value(tel, "fleet.brownout_events") \
        == int(res["brownout_events"])
    np.testing.assert_array_equal(np.asarray(tel["fleet.decisions"]),
                                  np.asarray(res["decision_histogram"]))
    # gauge: the last slot's total stored charge over alive nodes
    last_alive = np.asarray(res["alive"])[-1]
    want = int(np.floor(np.asarray(res["stored_uj"])[-1])[last_alive].sum())
    assert int(tel["fleet.stored_uj"]) == want
    # non-DEFER alive slots == the completed counter (no intermittent lane)
    dec = np.asarray(res["decisions"])
    sent = (dec != DEFER) & np.asarray(res["alive"])
    assert counter_value(tel, "fleet.completed") == sent.sum()


def test_fleet_streamed_lanes_equal_one_long_run(fleet_setup):
    key, wins, harvest, kw = fleet_setup
    one = seeker_fleet_simulate(wins, harvest, telemetry=True, **kw)
    chunked = seeker_fleet_simulate_streamed(wins, harvest, chunk=4,
                                             telemetry=True, **kw)
    assert chunked["n_chunks"] == 2
    spec = one["telemetry_spec"]
    for name in spec.names():
        np.testing.assert_array_equal(
            np.asarray(chunked["telemetry"][name]),
            np.asarray(one["telemetry"][name]), err_msg=name)


def test_fleet_compile_budget_under_churny_aliveness(fleet_setup):
    """The generalized serve_trace_count contract on the fleet engine: alive
    masks that churn per run never change a tensor shape, so the engine
    stays within a 2-compiled-shape budget across repeated runs."""
    key, wins, harvest, kw = fleet_setup
    rng = np.random.RandomState(5)
    with compile_guard("fleet.run", 2):
        for _ in range(3):
            alive = jnp.asarray(rng.rand(N, S) < 0.7)
            seeker_fleet_simulate(wins, harvest, alive=alive,
                                  telemetry=True, **kw)


# ---------------------------------------------------------------------------
# Host telemetry: QoS percentiles, exactness, off = bitwise-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def host_setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, _ = har_stream(key, 8)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=12, iters=4))(wins)
    wire = encode_wire_coresets(centers, radii, counts)
    return key, params, gen, wire


def _host_cfg(**kw):
    base = dict(channels=HAR.channels, k=12, m=20, t=HAR.window,
                n_classes=HAR.n_classes, n_nodes=8, batch_size=4,
                queue_capacity=16, cache_capacity=16, qos_slots=4)
    base.update(kw)
    return HostServeConfig(**base)


def _serve_slots(cfg, wire, key, params, gen, n_slots=3):
    entries = cluster_entries(wire, cfg.m)
    nid = jnp.arange(8, dtype=jnp.int32)
    mask = jnp.ones((8,), bool)
    state = host_server_init(cfg)
    outs = []
    for _ in range(n_slots):
        state, out = host_serve_slot(state, entries, nid, mask, cfg=cfg,
                                     host_params=params, gen_params=gen,
                                     base_key=key)
        outs.append(out)
    return state, outs


def test_host_telemetry_off_is_bitwise_identical(host_setup):
    key, params, gen, wire = host_setup
    _, off = _serve_slots(_host_cfg(), wire, key, params, gen)
    _, on = _serve_slots(_host_cfg(telemetry=True), wire, key, params, gen)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a.logits),
                                      np.asarray(b.logits))
        np.testing.assert_array_equal(np.asarray(a.valid),
                                      np.asarray(b.valid))


def test_host_lanes_match_stats_and_percentiles(host_setup):
    key, params, gen, wire = host_setup
    cfg = _host_cfg(telemetry=True)
    state, _ = _serve_slots(cfg, wire, key, params, gen)
    stats = host_server_stats(state, cfg)
    tel = stats["telemetry"]
    assert tel["host.served"] == stats["served"] == 12
    assert tel["host.cache_hits"] == stats["cache_hits"]
    assert tel["host.cache_misses"] == stats["cache_misses"]
    assert tel["host.deadline_misses"] == stats["deadline_misses"]
    assert tel["host.drops_overflow"] == stats["drops_overflow"]
    assert tel["host.backlog"] == stats["backlog"]
    # every served payload's sojourn was recorded, all of them cluster-kind
    soj = tel["host.sojourn_slots"]
    assert sum(soj["counts"]) == stats["served"]
    assert sum(tel["host.sojourn_slots.cluster"]["counts"]) \
        == stats["served"]
    assert sum(tel["host.sojourn_slots.sampling"]["counts"]) == 0
    # percentiles: flattened floats, e2e = sojourn + the serve slot itself
    for k in ("sojourn_p50", "sojourn_p95", "sojourn_p99",
              "e2e_p50", "e2e_p95", "e2e_p99"):
        assert isinstance(stats[k], float) and stats[k] >= 0.0
    assert stats["e2e_p50"] >= stats["sojourn_p50"]
    assert stats["sojourn_p99"] <= cfg.qos_slots + 1
    assert CLUSTER_KIND == 0  # the kind code the per-class lanes split on


def test_host_spec_shared_across_service_rate_variants():
    """The lane spec depends only on the QoS window, so the per-slot and
    trace-mode configs of one deployment share a spec instance (one compile
    cache key, mergeable lanes)."""
    a = host_telemetry_spec(_host_cfg(telemetry=True))
    b = host_telemetry_spec(_host_cfg(telemetry=True, batch_size=8,
                                      queue_capacity=32))
    assert a is b
    assert host_telemetry_spec(_host_cfg(telemetry=True, qos_slots=9)) \
        is not a


def test_host_state_telemetry_mismatch_raises(host_setup):
    key, params, gen, wire = host_setup
    cfg = _host_cfg(telemetry=True)
    stale = host_server_init(_host_cfg())            # built without lanes
    entries = cluster_entries(wire, cfg.m)
    nid = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(ValueError, match="SAME telemetry"):
        host_serve_slot(stale, entries, nid, jnp.ones((8,), bool), cfg=cfg,
                        host_params=params, gen_params=gen, base_key=key)


# ---------------------------------------------------------------------------
# Compile guard + span tracer
# ---------------------------------------------------------------------------

def test_compile_guard_budget_raises():
    compile_event("obs.test_component", ("shape", 1))
    before = compile_count("obs.test_component")
    with compile_guard("obs.test_component", 2):
        compile_event("obs.test_component", ("shape", 2))
    assert compile_count("obs.test_component") == before + 1
    with pytest.raises(CompileBudgetError, match="budget of 1"):
        with compile_guard("obs.test_component", 1):
            for i in range(3):
                compile_event("obs.test_component", ("churn", i))


def test_trace_export_is_chrome_trace_json(tmp_path):
    was = trace.enabled()
    trace.clear()
    try:
        with trace.span("off.span"):                 # disabled: no event
            pass
        assert trace.events() == []
        trace.enable()
        with trace.span("work", cat="test", args={"n": 3},
                        flush=jnp.arange(4)):
            trace.instant("retrace", cat="test")
        path = tmp_path / "trace.json"
        assert trace.export_chrome_trace(str(path)) == 2
    finally:
        trace.enable(was)
        trace.clear()
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and len(evs) == 2
    kinds = {e["ph"]: e for e in evs}
    assert kinds["i"]["name"] == "retrace"
    sp = kinds["X"]
    assert sp["name"] == "work" and sp["args"] == {"n": 3}
    assert sp["dur"] >= 0 and {"ts", "pid", "tid"} <= sp.keys()
    # the instant fired inside the span's interval
    assert sp["ts"] <= kinds["i"]["ts"] <= sp["ts"] + sp["dur"]


# ---------------------------------------------------------------------------
# Benchmark regression gate
# ---------------------------------------------------------------------------

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_compare():
    spec = importlib.util.spec_from_file_location(
        "bench_compare", os.path.join(ROOT, "benchmarks", "compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_compare_detects_injected_regressions():
    cmp = _load_compare()
    base = {"x": {"name": "x", "completed_frac": 0.5, "us_per_call": 100.0,
                  "windows_per_s": 1000.0, "bitwise_equal": True},
            "y": {"name": "y", "reduction_x": 30.0}}
    ok = {k: dict(v) for k, v in base.items()}
    assert cmp.compare(ok, base, rtol=1e-6, timing_rtol=0.5) == []
    # deterministic drift beyond rtol -> regression
    bad = {k: dict(v) for k, v in base.items()}
    bad["x"]["completed_frac"] = 0.4
    assert any("completed_frac" in p
               for p in cmp.compare(bad, base, 1e-6, 0.5))
    # timing: 10x slower fails, 10x faster passes
    slow = {k: dict(v) for k, v in base.items()}
    slow["x"]["us_per_call"] = 1000.0
    assert any("us_per_call" in p for p in cmp.compare(slow, base, 1e-6, 0.5))
    fast = {k: dict(v) for k, v in base.items()}
    fast["x"]["us_per_call"] = 10.0
    fast["x"]["windows_per_s"] = 10000.0
    assert cmp.compare(fast, base, 1e-6, 0.5) == []
    # a vanished benchmark row is a regression; a flipped bool too
    missing = {"x": dict(base["x"])}
    assert any("missing" in p for p in cmp.compare(missing, base, 1e-6, 0.5))
    flipped = {k: dict(v) for k, v in base.items()}
    flipped["x"]["bitwise_equal"] = False
    assert any("bitwise_equal" in p
               for p in cmp.compare(flipped, base, 1e-6, 0.5))


def test_compare_cli_exit_codes(tmp_path):
    rows = [{"name": "m", "completed_frac": 0.75}]
    base = tmp_path / "base.json"
    base.write_text(json.dumps(rows))
    cur_ok = tmp_path / "ok.json"
    cur_ok.write_text(json.dumps(rows))
    cur_bad = tmp_path / "bad.json"
    cur_bad.write_text(json.dumps([{"name": "m", "completed_frac": 0.25}]))
    cmd = [sys.executable, "-m", "benchmarks.compare"]
    env = dict(os.environ)
    ok = subprocess.run(cmd + [str(cur_ok), "--baseline", str(base)],
                        cwd=ROOT, env=env, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(cmd + [str(cur_bad), "--baseline", str(base)],
                         cwd=ROOT, env=env, capture_output=True, text=True)
    assert bad.returncode != 0
    assert "REGRESSION" in bad.stdout
