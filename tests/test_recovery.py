"""Recoverable-coreset tests (paper §3.2.2 + A.1)."""
import jax
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.core import (
    ClusterCoreset, importance_coreset, init_discriminator, init_generator,
    discriminator_apply, kmeans_coreset, points_from_window,
    recover_cluster_points, recover_cluster_window, recover_sampling_window,
)


def _window(seed, t=60, c=3):
    k = jax.random.PRNGKey(seed)
    tt = jnp.linspace(0, 4 * jnp.pi, t)[:, None]
    return jnp.sin(tt) + 0.1 * jax.random.normal(k, (t, c))


def test_cluster_recovery_2r_property(key):
    """Recovered points lie within each source cluster's ball (the paper's
    2r-approximation: any two points in one cluster are <=2r apart)."""
    pts = points_from_window(_window(0))
    cs = kmeans_coreset(pts, k=8, iters=4)
    rec, mask = recover_cluster_points(cs, key, n_points=60)
    d = jnp.linalg.norm(rec[:, None] - cs.centers[None], axis=-1)
    mind = jnp.min(d, axis=1)
    maxr = jnp.max(cs.radii)
    valid = np.asarray(mask)
    assert bool(jnp.all(mind[valid] <= maxr + 1e-4))


def test_cluster_recovery_count_match(key):
    pts = points_from_window(_window(1))
    cs = kmeans_coreset(pts, k=12, iters=4)
    rec, mask = recover_cluster_points(cs, key, n_points=60)
    assert int(mask.sum()) == int(cs.counts.sum()) == 60


def test_cluster_recovered_window_close(key):
    """Recovered windows approximate the original well enough for inference
    (paper: ~85% accuracy on reconstructions) — check signal-level error."""
    w = _window(2)
    cs = kmeans_coreset(points_from_window(w), k=12, iters=4)
    rec = recover_cluster_window(cs, key, w.shape[0])
    assert rec.shape == w.shape
    err = float(jnp.mean(jnp.abs(rec - w)))
    scale = float(jnp.std(w))
    assert err < 0.75 * scale, (err, scale)


def test_generator_recovery_keeps_transmitted_points(key):
    """A.1: the samples the sensor DID send are written back verbatim."""
    w = _window(3)
    sc = importance_coreset(w, 20, key)
    gen = init_generator(key, w.shape[0], w.shape[1])
    rec = recover_sampling_window(gen, sc, key, w.shape[0])
    assert rec.shape == w.shape
    np.testing.assert_allclose(np.asarray(rec[sc.indices]),
                               np.asarray(sc.values), rtol=1e-5)


def test_generator_discriminator_shapes(key):
    gen = init_generator(key, 60, 3, n_classes=12)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(gen))
    assert n_params < 500_000        # paper: "few hundred thousand parameters"
    disc = init_discriminator(key, 60, 3)
    score = discriminator_apply(disc, _window(4))
    assert score.shape == ()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), k=st.integers(4, 16))
def test_recovery_mass_conservation(seed, k):
    key = jax.random.PRNGKey(seed)
    pts = jax.random.normal(key, (48, 3))
    cs = kmeans_coreset(pts, k=k, iters=4)
    rec, mask = recover_cluster_points(cs, key, n_points=48)
    # per-cluster recovered counts match the transmitted counts within the
    # proportional-slot rounding (+-1 per cluster)
    d = jnp.linalg.norm(rec[:, None] - cs.centers[None], axis=-1)
    assign = np.asarray(jnp.argmin(d, axis=1))[np.asarray(mask)]
    rec_counts = np.bincount(assign, minlength=k)
    src_counts = np.asarray(cs.counts)
    # empty clusters stay empty
    assert np.all(rec_counts[src_counts == 0] == 0)
    assert rec_counts.sum() == src_counts.sum()
