"""Gradient/activation coreset codec tests (the distributed C1-C3 mapping)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.compression import (
    CompressionConfig, kmeans1d, kmeans1d_decompress, topk_block_compress,
    topk_block_decompress, topk_compress, topk_decompress,
    wire_bytes_dense_psum, wire_bytes_kmeans1d, wire_bytes_topk_allgather,
)


def test_topk_roundtrip_exact_on_selected(key):
    g = jax.random.normal(key, (4096,))
    vals, idx = topk_compress(g, 128)
    dense = topk_decompress(vals, idx, g.size)
    np.testing.assert_allclose(np.asarray(dense[idx]), np.asarray(vals))
    # residual + decompressed == original (error-feedback identity)
    np.testing.assert_allclose(np.asarray(dense + (g - dense)),
                               np.asarray(g), rtol=1e-6)


def test_topk_selects_largest(key):
    g = jax.random.normal(key, (1024,))
    vals, idx = topk_compress(g, 64)
    thresh = float(jnp.min(jnp.abs(vals)))
    outside = jnp.delete(jnp.abs(g), idx, assume_unique_indices=True)
    assert float(jnp.max(outside)) <= thresh + 1e-6


def test_topk_block_codec_roundtrip(key):
    """Block-local top-k with int16 offsets: kept entries reproduced exactly,
    offsets fit int16, block-local maxima selected."""
    x = jax.random.normal(key, (65536,))
    vals, off = topk_block_compress(x, 1 / 64, block=32768)
    assert off.dtype == jnp.int16
    assert int(jnp.max(off)) < 32768
    dense = topk_block_decompress(vals, off, x.size)
    nz = np.asarray(dense) != 0
    np.testing.assert_allclose(np.asarray(dense)[nz], np.asarray(x)[nz],
                               rtol=1e-6)
    # each block keeps its own largest-|.| entry
    xb = np.asarray(x).reshape(2, 32768)
    kept = np.asarray(dense).reshape(2, 32768)
    for b in range(2):
        assert kept[b, np.argmax(np.abs(xb[b]))] != 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), k=st.sampled_from([4, 8, 16]))
def test_kmeans1d_reconstruction_bounded_by_radius(seed, k):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2048,))
    cs = kmeans1d(x, k=k, iters=4)
    assert int(cs.codes.max()) < k
    rec = kmeans1d_decompress(cs)
    err = jnp.abs(rec - x)
    assert bool(jnp.all(err <= cs.radii[cs.codes] + 1e-5))
    assert int(cs.counts.sum()) == x.size


def test_kmeans1d_better_than_naive_quant(key):
    """The clustering codebook beats uniform 4-bit quantization on gaussian
    gradients (the paper's Table-1 claim transposed to 1-D)."""
    x = jax.random.normal(key, (8192,))
    cs = kmeans1d(x, k=16, iters=4)
    rec = kmeans1d_decompress(cs)
    err_kmeans = float(jnp.mean((rec - x) ** 2))
    lo, hi = float(x.min()), float(x.max())
    q = jnp.round((x - lo) / (hi - lo) * 15) / 15 * (hi - lo) + lo
    err_uniform = float(jnp.mean((q - x) ** 2))
    assert err_kmeans < err_uniform


def test_wire_byte_accounting():
    n, ndev = 1 << 20, 16
    dense = wire_bytes_dense_psum(n, ndev)
    topk = wire_bytes_topk_allgather(n, ndev, ratio=1 / 64)
    km = wire_bytes_kmeans1d(n)
    assert dense > topk            # compression wins at 1/64
    assert km < n * 2              # 4-bit codes < bf16 dense
    # the paper's clustering payload: ~4 bits/elem + tiny codebook
    assert km == pytest.approx(n * 0.5, rel=0.01)


def test_error_feedback_recovers_signal(key):
    """With error feedback, repeated compression of a CONSTANT gradient
    converges: accumulated residual eventually pushes every coordinate
    through (DGC-style correctness of the C1 codec).

    Steady-state theory: every coordinate is flushed once per ~n/k rounds
    carrying ~ (n/k) * g_i, so |total/T - g| <= (n/k) * |g| / T + slack.
    """
    n, k, T = 512, 32, 96
    g = jax.random.normal(key, (n,))
    ef = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(T):
        flat = g + ef
        vals, idx = topk_compress(flat, k)
        sent = topk_decompress(vals, idx, g.size)
        ef = flat - sent
        total = total + sent
    cycle = n / k
    bound = 3.0 * cycle * jnp.abs(g) / T + 0.05
    err = jnp.abs(total / T - g)
    frac_ok = float(jnp.mean(err <= bound))
    assert frac_ok > 0.9, frac_ok
    # and the residual itself stays bounded (no coordinate starves forever):
    # steady-state |ef_i| is capped by the selection threshold ~ cycle * E|g|
    assert float(jnp.max(jnp.abs(ef))) < 2 * cycle * float(jnp.mean(jnp.abs(g)))
