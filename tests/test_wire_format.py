"""Wire-format tests for the distributed edge-host step: the int16/int8
quantize -> ppermute -> dequantize path and its byte accounting (the paper's
2 B center / 1 B radius / 4-bit count format, §3.2.2, scaled to tensors)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core.coreset import channel_cluster_coresets, cluster_payload_bytes
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (WirePayload, decode_wire_coresets,
                           decode_wire_samples, edge_host_serve_step,
                           encode_wire_coresets, encode_wire_samples,
                           wire_payload_from_bytes, wire_payload_nbytes,
                           wire_payload_to_bytes, wire_sample_nbytes)

K = 12


@pytest.fixture(scope="module")
def coresets():
    wins, _ = har_stream(jax.random.PRNGKey(3), 4)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=K, iters=4))(wins)
    return centers, radii, counts   # (B, C, k, 2), (B, C, k), (B, C, k)


def test_wire_dtypes_and_code_ranges(coresets):
    p = encode_wire_coresets(*coresets)
    assert p.c_codes.dtype == jnp.int16
    assert p.r_codes.dtype == jnp.int8
    assert p.n_codes.dtype == jnp.int8
    # codes must span the signed ranges without wrapping
    assert int(p.c_codes.min()) >= -32768 and int(p.c_codes.max()) <= 32767
    assert int(p.r_codes.min()) >= -128 and int(p.r_codes.max()) <= 127
    assert int(p.n_codes.min()) >= 0 and int(p.n_codes.max()) <= 15


def test_wire_roundtrip_error_bounds(coresets):
    """Dequantized centers/radii are within one quantization step of the
    originals (int16 over the center range, int8 over the radius range)."""
    centers, radii, counts = coresets
    p = encode_wire_coresets(centers, radii, counts)
    centers_r, radii_r, counts_r = decode_wire_coresets(p)

    c_step = np.asarray((p.hi - p.lo) / 65535.0)            # (B,1,1,1)
    c_err = np.abs(np.asarray(centers_r - centers))
    assert (c_err <= c_step * 0.5 + 1e-5).all(), c_err.max()

    r_step = np.asarray(p.rhi / 255.0)                      # (B,1,1)
    r_err = np.abs(np.asarray(radii_r - radii))
    assert (r_err <= r_step * 0.5 + 1e-5).all(), r_err.max()

    # counts <= 15 survive exactly (the 4-bit field)
    small = np.asarray(counts) <= 15
    np.testing.assert_array_equal(np.asarray(counts_r)[small],
                                  np.asarray(counts)[small])


def test_wire_counts_clip_at_4bit():
    centers = jnp.zeros((1, 1, 3, 2))
    radii = jnp.ones((1, 1, 3))
    counts = jnp.asarray([[[2, 15, 60]]])
    p = encode_wire_coresets(centers, radii, counts)
    np.testing.assert_array_equal(np.asarray(p.n_codes)[0, 0], [2, 15, 15])


def test_wire_payload_byte_accounting(coresets):
    """The code tensors' actual nbytes match wire_payload_nbytes, which is
    cluster_payload_bytes with the tensor field widths (2-D int16 center =
    4 B, int8 radius, counts byte-padded) per channel."""
    centers, radii, counts = coresets
    b, c, k, _ = centers.shape
    p = encode_wire_coresets(centers, radii, counts)
    actual = p.c_codes.nbytes + p.r_codes.nbytes + p.n_codes.nbytes
    assert actual == b * wire_payload_nbytes(k, c)
    assert wire_payload_nbytes(k, c) == c * cluster_payload_bytes(
        k, bytes_center=4, bytes_radius=1, bits_count=8)
    # and the paper's 42-B headline format is the 2B/1B/4-bit instance
    assert cluster_payload_bytes(12) == 42
    # coreset wire bytes stay well under the raw window even in tensor form
    assert wire_payload_nbytes(k, c) < 240 * c


def test_decode_rejects_wrong_dtypes(coresets):
    """The host queue ingests untrusted payloads: a float tensor smuggled in
    place of the int16 codes must raise, not silently dequantize."""
    p = encode_wire_coresets(*coresets)
    with pytest.raises(ValueError, match="c_codes must be int16"):
        decode_wire_coresets(p._replace(c_codes=p.c_codes.astype(jnp.float32)))
    with pytest.raises(ValueError, match="r_codes must be int8"):
        decode_wire_coresets(p._replace(r_codes=p.r_codes.astype(jnp.int16)))
    with pytest.raises(ValueError, match="n_codes must be int8"):
        decode_wire_coresets(p._replace(n_codes=p.n_codes.astype(jnp.int32)))


def test_decode_rejects_shape_mismatch(coresets):
    p = encode_wire_coresets(*coresets)
    with pytest.raises(ValueError, match="r_codes shape"):
        decode_wire_coresets(p._replace(r_codes=p.r_codes[:, :, :-1]))
    with pytest.raises(ValueError, match="n_codes shape"):
        decode_wire_coresets(p._replace(n_codes=p.n_codes[:-1]))
    with pytest.raises(ValueError, match=r"\(\.\.\., k, 2\)"):
        decode_wire_coresets(p._replace(c_codes=p.c_codes[..., :1]))


def test_decode_rejects_counts_outside_4bit_field(coresets):
    p = encode_wire_coresets(*coresets)
    bad = p._replace(n_codes=p.n_codes.at[0, 0, 0].set(16))
    with pytest.raises(ValueError, match=r"4-bit field"):
        decode_wire_coresets(bad)


def test_bytes_roundtrip_is_bitwise(coresets):
    p = encode_wire_coresets(*coresets)
    q = wire_payload_from_bytes(wire_payload_to_bytes(p))
    for a, b in zip(p, q):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the parsed frame decodes identically
    for a, b in zip(decode_wire_coresets(p), decode_wire_coresets(q)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bytes_rejects_malformed_frames(coresets):
    p = encode_wire_coresets(*coresets)
    buf = wire_payload_to_bytes(p)
    with pytest.raises(ValueError, match="truncated"):
        wire_payload_from_bytes(buf[:-3])
    with pytest.raises(ValueError, match="shorter than"):
        wire_payload_from_bytes(buf[:10])
    with pytest.raises(ValueError, match="magic"):
        wire_payload_from_bytes(b"\x00" * len(buf))
    # corrupt a count byte past 15 inside the frame: parse must reject
    b, c, k, _ = p.c_codes.shape
    n_off = 20 + 4 * b * c * k + b * c * k      # header + c_codes + r_codes
    bad = bytearray(buf)
    bad[n_off] = 200
    with pytest.raises(ValueError, match="4-bit field"):
        wire_payload_from_bytes(bytes(bad))


# ---------------------------------------------------------------------------
# Sampling (D4) wire format
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sample_coresets():
    from repro.core.coreset import importance_coreset
    wins, _ = har_stream(jax.random.PRNGKey(5), 4)
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    sc = jax.vmap(lambda w, k: importance_coreset(w, 20, k))(wins, keys)
    return sc


def test_sample_wire_roundtrip_error_bounds(sample_coresets):
    sc = sample_coresets
    p = encode_wire_samples(sc.indices, sc.values, sc.mean, sc.var)
    assert p.idx.dtype == jnp.int8 and p.v_codes.dtype == jnp.int16
    idx, vals, mean, var = decode_wire_samples(p)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(sc.indices))
    step = np.asarray((p.hi - p.lo) / 65535.0)
    err = np.abs(np.asarray(vals - sc.values))
    assert (err <= step * 0.5 + 1e-5).all(), err.max()
    np.testing.assert_array_equal(np.asarray(mean), np.asarray(sc.mean))
    np.testing.assert_array_equal(np.asarray(var), np.asarray(sc.var))


def test_sample_wire_defensive_decode(sample_coresets):
    sc = sample_coresets
    p = encode_wire_samples(sc.indices, sc.values, sc.mean, sc.var)
    with pytest.raises(ValueError, match="idx must be int8"):
        decode_wire_samples(p._replace(idx=p.idx.astype(jnp.int32)))
    with pytest.raises(ValueError, match="v_codes must be int16"):
        decode_wire_samples(p._replace(v_codes=p.v_codes.astype(jnp.int8)))
    with pytest.raises(ValueError, match="does not match v_codes"):
        decode_wire_samples(p._replace(idx=p.idx[:, :-1]))
    with pytest.raises(ValueError, match="moments"):
        decode_wire_samples(p._replace(mean=p.mean[:, :-1]))
    with pytest.raises(ValueError, match="negative time indices"):
        decode_wire_samples(p._replace(idx=p.idx.at[0, 0].set(-3)))
    with pytest.raises(ValueError, match="int8 wire field"):
        encode_wire_samples(sc.indices.at[0, 0].set(200), sc.values,
                            sc.mean, sc.var)


def test_sample_wire_byte_accounting(sample_coresets):
    """m=20, C=3: 20 x (1 B idx + 2 B x 3 values) + 2 x 2 B x 3 moments."""
    assert wire_sample_nbytes(20, 3) == 20 * (1 + 2 * 3) + 4 * 3
    # well under the raw (T, C) window, like the cluster format
    assert wire_sample_nbytes(20, 3) < 240 * 3


def test_serve_step_roundtrip_on_pod_mesh():
    """edge_host_serve_step end to end on a 1x1 ("pod","data") mesh: the
    payload crosses ppermute (self-edge), is dequantized and recovered, and
    host inference returns finite logits."""
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, _ = har_stream(key, 4)
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    logits = edge_host_serve_step(
        wins, signatures=class_signatures(), qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR, mesh=mesh, k=K)
    assert logits.shape == (4, HAR.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
