"""Wire-format tests for the distributed edge-host step: the int16/int8
quantize -> ppermute -> dequantize path and its byte accounting (the paper's
2 B center / 1 B radius / 4-bit count format, §3.2.2, scaled to tensors)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core.coreset import channel_cluster_coresets, cluster_payload_bytes
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (decode_wire_coresets, edge_host_serve_step,
                           encode_wire_coresets, wire_payload_nbytes)

K = 12


@pytest.fixture(scope="module")
def coresets():
    wins, _ = har_stream(jax.random.PRNGKey(3), 4)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=K, iters=4))(wins)
    return centers, radii, counts   # (B, C, k, 2), (B, C, k), (B, C, k)


def test_wire_dtypes_and_code_ranges(coresets):
    p = encode_wire_coresets(*coresets)
    assert p.c_codes.dtype == jnp.int16
    assert p.r_codes.dtype == jnp.int8
    assert p.n_codes.dtype == jnp.int8
    # codes must span the signed ranges without wrapping
    assert int(p.c_codes.min()) >= -32768 and int(p.c_codes.max()) <= 32767
    assert int(p.r_codes.min()) >= -128 and int(p.r_codes.max()) <= 127
    assert int(p.n_codes.min()) >= 0 and int(p.n_codes.max()) <= 15


def test_wire_roundtrip_error_bounds(coresets):
    """Dequantized centers/radii are within one quantization step of the
    originals (int16 over the center range, int8 over the radius range)."""
    centers, radii, counts = coresets
    p = encode_wire_coresets(centers, radii, counts)
    centers_r, radii_r, counts_r = decode_wire_coresets(p)

    c_step = np.asarray((p.hi - p.lo) / 65535.0)            # (B,1,1,1)
    c_err = np.abs(np.asarray(centers_r - centers))
    assert (c_err <= c_step * 0.5 + 1e-5).all(), c_err.max()

    r_step = np.asarray(p.rhi / 255.0)                      # (B,1,1)
    r_err = np.abs(np.asarray(radii_r - radii))
    assert (r_err <= r_step * 0.5 + 1e-5).all(), r_err.max()

    # counts <= 15 survive exactly (the 4-bit field)
    small = np.asarray(counts) <= 15
    np.testing.assert_array_equal(np.asarray(counts_r)[small],
                                  np.asarray(counts)[small])


def test_wire_counts_clip_at_4bit():
    centers = jnp.zeros((1, 1, 3, 2))
    radii = jnp.ones((1, 1, 3))
    counts = jnp.asarray([[[2, 15, 60]]])
    p = encode_wire_coresets(centers, radii, counts)
    np.testing.assert_array_equal(np.asarray(p.n_codes)[0, 0], [2, 15, 15])


def test_wire_payload_byte_accounting(coresets):
    """The code tensors' actual nbytes match wire_payload_nbytes, which is
    cluster_payload_bytes with the tensor field widths (2-D int16 center =
    4 B, int8 radius, counts byte-padded) per channel."""
    centers, radii, counts = coresets
    b, c, k, _ = centers.shape
    p = encode_wire_coresets(centers, radii, counts)
    actual = p.c_codes.nbytes + p.r_codes.nbytes + p.n_codes.nbytes
    assert actual == b * wire_payload_nbytes(k, c)
    assert wire_payload_nbytes(k, c) == c * cluster_payload_bytes(
        k, bytes_center=4, bytes_radius=1, bits_count=8)
    # and the paper's 42-B headline format is the 2B/1B/4-bit instance
    assert cluster_payload_bytes(12) == 42
    # coreset wire bytes stay well under the raw window even in tensor form
    assert wire_payload_nbytes(k, c) < 240 * c


def test_serve_step_roundtrip_on_pod_mesh():
    """edge_host_serve_step end to end on a 1x1 ("pod","data") mesh: the
    payload crosses ppermute (self-edge), is dequantized and recovered, and
    host inference returns finite logits."""
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, _ = har_stream(key, 4)
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    logits = edge_host_serve_step(
        wins, signatures=class_signatures(), qdnn_params=params,
        host_params=params, gen_params=gen, har_cfg=HAR, mesh=mesh, k=K)
    assert logits.shape == (4, HAR.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
