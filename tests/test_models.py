"""Per-arch smoke tests (deliverable f) + model-level correctness:
decode==forward consistency, chunked==dense attention, train-step sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import (decode_step, forward, init_cache, init_params)
from repro.models.config import ModelConfig, MoEConfig, pattern_runs
from repro.train import TrainHyper, init_train_state, make_train_step


def _inputs(cfg, key, b=2, s=16):
    s_tok = s - (cfg.vision_patches or 0)
    tokens = jax.random.randint(key, (b, s_tok), 0, cfg.vocab)
    extra = {}
    if cfg.vision_patches:
        extra["patch_embeds"] = jax.random.normal(
            key, (b, cfg.vision_patches, cfg.d_model))
    if cfg.encoder_layers:
        extra["enc_frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model))
    return tokens, extra


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_decode(arch, key):
    """Reduced same-family config: one forward + one decode step on CPU,
    asserting shapes and no NaNs (assignment requirement)."""
    cfg = get_smoke(arch)
    params = init_params(key, cfg)
    tokens, extra = _inputs(cfg, key)
    b, s = 2, 16
    logits = forward(params, cfg, tokens, **extra)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    if cfg.encoder_layers:
        _, cache = forward(params, cfg, tokens[:, :8], return_cache=True,
                           cache_len=32, **extra)
    else:
        cache = init_cache(cfg, b, 32)
    lg, cache2 = decode_step(params, cfg, cache, tokens[:, :1])
    assert lg.shape == (b, 1, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(lg, np.float32)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch, key):
    """One train step on CPU: finite loss, params actually move."""
    cfg = get_smoke(arch)
    hyper = TrainHyper(peak_lr=1e-3, warmup=1, total_steps=10)
    state = init_train_state(key, cfg, hyper)
    _, extra = _inputs(cfg, key, s=17)
    batch = {"tokens": jax.random.randint(
        key, (2, 17 - (cfg.vision_patches or 0)), 0, cfg.vocab), **extra}
    step = make_train_step(cfg, hyper)
    # two steps: step 0 runs at lr=0 (linear warmup), step 1 at ~peak lr
    mid_state, metrics = step(state, batch)
    new_state, metrics = step(mid_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


def test_full_configs_match_published_sizes():
    """Analytic parameter counts vs published model sizes."""
    expected = {
        "gemma-2b": 2.51e9, "gemma3-12b": 11.6e9, "tinyllama-1.1b": 1.10e9,
        "yi-34b": 34.4e9, "recurrentgemma-2b": 2.9e9,
        "deepseek-moe-16b": 16.4e9, "grok-1-314b": 316e9,
        "whisper-small": 0.27e9, "mamba2-130m": 0.129e9,
        "qwen2-vl-2b": 1.54e9,
    }
    for arch, exp in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - exp) / exp < 0.08, (arch, n, exp)


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()
    cfg = get_config("grok-1-314b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()


def test_pattern_runs_structure():
    g3 = get_config("gemma3-12b")
    runs = pattern_runs(g3)
    assert sum(r[3] for r in runs) == 48
    assert runs[0][0] == "local" and runs[0][3] == 5
    assert runs[1][0] == "attn" and runs[1][3] == 1
    rg = get_config("recurrentgemma-2b")
    runs = pattern_runs(rg)
    assert sum(r[3] for r in runs) == 26
    kinds = [r[0] for r in runs]
    assert kinds[:4] == ["rglru", "local", "rglru", "local"]
    assert kinds[-1] == "rglru"        # trailing R,R pair


BASE = dict(vocab=128, d_model=32, n_layers=3, n_heads=4, n_kv=2, d_ff=64,
            dtype=jnp.float32)
KINDS = {
    "dense": ModelConfig(name="d", **BASE),
    "local": ModelConfig(name="l", **BASE,
                         block_pattern=("local", "attn", "local"), window=4),
    "rglru": ModelConfig(name="r", **BASE, rnn_width=32,
                         block_pattern=("rglru", "rglru", "local"), window=4),
    "ssd": ModelConfig(name="s", **{**BASE, "d_ff": 0}, mlp="none",
                       block_pattern=("ssd",) * 3, ssm_state=8, ssm_headdim=8),
    "moe": ModelConfig(name="m", **BASE, moe_layers=(1, 2),
                       moe=MoEConfig(n_experts=4, top_k=2, d_expert=16,
                                     capacity_factor=2.0)),
}


@pytest.mark.parametrize("kind", list(KINDS))
def test_decode_matches_forward(kind, key):
    """Token-by-token decode reproduces the full-sequence forward exactly —
    validates KV ring buffers, RG-LRU/SSD state updates, rope positions."""
    cfg = KINDS[kind]
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    full = forward(params, cfg, tokens)
    cache = init_cache(cfg, 2, 16)
    outs = []
    for t in range(12):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("kind", ["dense", "local", "rglru", "ssd", "moe"])
def test_prefill_then_decode_continues_exactly(kind, key):
    cfg = KINDS[kind]
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    full = forward(params, cfg, tokens)
    _, cache = forward(params, cfg, tokens[:, :8], return_cache=True,
                       cache_len=16)
    lg, _ = decode_step(params, cfg, cache, tokens[:, 8:9])
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 8]),
                               rtol=2e-3, atol=2e-4)


def test_chunked_attention_equals_dense(key):
    cfg_d = ModelConfig(name="d", **BASE, dense_attn_max_seq=4096,
                        attn_chunk=16)
    cfg_c = dataclasses.replace(cfg_d, dense_attn_max_seq=8)
    params = init_params(key, cfg_d)
    tokens = jax.random.randint(key, (2, 64), 0, cfg_d.vocab)
    a = forward(params, cfg_d, tokens)
    b = forward(params, cfg_c, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_banded_attention_equals_dense_window(key):
    cfg_d = ModelConfig(name="l", **BASE, block_pattern=("local",) * 3,
                        window=24, dense_attn_max_seq=4096, attn_chunk=16)
    cfg_b = dataclasses.replace(cfg_d, dense_attn_max_seq=8)
    params = init_params(key, cfg_d)
    tokens = jax.random.randint(key, (2, 64), 0, cfg_d.vocab)
    a = forward(params, cfg_d, tokens)
    b = forward(params, cfg_b, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_vocab_padding_masks_logits(key):
    cfg = ModelConfig(name="p", **{**BASE, "vocab": 100})   # pads to 256
    assert cfg.padded_vocab == 256
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (1, 8), 0, 100)
    logits = forward(params, cfg, tokens)
    assert logits.shape[-1] == 256
    assert bool(jnp.all(logits[..., 100:] < -1e29))
