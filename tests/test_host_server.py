"""Host serving subsystem end-to-end: fixed-shape compile behaviour, the
recovery cache's bitwise contract, QoS accounting, resume, and the rewired
``fleet_serve_step`` queue mode (ISSUE 3 acceptance tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core.coreset import channel_cluster_coresets, importance_coreset
from repro.core.recovery import init_generator
from repro.data.sensors import har_stream
from repro.models.har import har_init
from repro.host import (HostServeConfig, cluster_entries, host_ensemble,
                        host_serve_slot, host_serve_trace, host_server_init,
                        host_server_stats, sampling_entries,
                        serve_trace_count)
from repro.serving import encode_wire_coresets, encode_wire_samples


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, 8)
    centers, radii, counts = jax.vmap(
        lambda w: channel_cluster_coresets(w, k=12, iters=4))(wins)
    wire = encode_wire_coresets(centers, radii, counts)
    return key, params, gen, wins, labels, wire


def _cfg(**kw):
    base = dict(channels=HAR.channels, k=12, m=20, t=HAR.window,
                n_classes=HAR.n_classes, n_nodes=8, batch_size=4,
                queue_capacity=16, cache_capacity=16, qos_slots=4)
    base.update(kw)
    return HostServeConfig(**base)


def _by_node(out):
    """{node_id: logits row} for the valid rows of a SlotOutput."""
    valid = np.asarray(out.valid)
    return {int(n): np.asarray(out.logits)[i]
            for i, n in enumerate(np.asarray(out.node_id)) if valid[i]}


# ---------------------------------------------------------------------------
# Cache: a hit is bitwise-identical to recomputation
# ---------------------------------------------------------------------------

def test_cache_hit_bitwise_identical_to_recomputation(setup):
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=8)
    entries = cluster_entries(wire, cfg.m)
    nid = jnp.arange(8, dtype=jnp.int32)
    mask = jnp.ones((8,), bool)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state = host_server_init(cfg)
    state, first = host_serve_slot(state, entries, nid, mask, **kw)
    assert host_server_stats(state)["cache_misses"] == 8
    # same payloads again: all served from the cache ...
    state, again = host_serve_slot(state, entries, nid, mask, **kw)
    stats = host_server_stats(state)
    assert stats["cache_hits"] == 8 and stats["cache_misses"] == 8
    assert bool(np.asarray(again.cache_hit)[np.asarray(again.valid)].all())
    # ... bitwise equal to the first (recomputed) answers
    a, b = _by_node(first), _by_node(again)
    assert a.keys() == b.keys()
    for n in a:
        np.testing.assert_array_equal(a[n], b[n])
    # and a FRESH server recomputing from scratch reproduces them bitwise
    # (payload-deterministic recovery PRNG: key = fold_in(base_key, sig))
    state2, recomputed = host_serve_slot(host_server_init(cfg), entries, nid,
                                         mask, **kw)
    c = _by_node(recomputed)
    for n in a:
        np.testing.assert_array_equal(a[n], c[n])


def test_cache_is_exact_match_not_approximate(setup):
    """Perturbing ONE code in a payload must miss the cache."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=1, n_nodes=1)
    one = jax.tree_util.tree_map(lambda a: a[:1], wire)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)
    nid = jnp.zeros((1,), jnp.int32)
    mask = jnp.ones((1,), bool)

    state = host_server_init(cfg)
    state, _ = host_serve_slot(state, cluster_entries(one, cfg.m), nid, mask,
                               **kw)
    tweaked = one._replace(c_codes=one.c_codes.at[0, 0, 0, 0].add(1))
    state, out = host_serve_slot(state, cluster_entries(tweaked, cfg.m), nid,
                                 mask, **kw)
    assert host_server_stats(state)["cache_misses"] == 2
    assert not bool(np.asarray(out.cache_hit)[0])


# ---------------------------------------------------------------------------
# Fixed-shape batch assembly: churny trace, <= 2 compiled shapes
# ---------------------------------------------------------------------------

def test_churny_trace_compiles_at_most_two_shapes(setup):
    """Acceptance: over a churny trace with VARYING per-slot payload counts,
    the serve slot (queue push + EDF assembly + recovery + DNN) traces at
    most twice — fleet churn never changes a tensor shape."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=3, queue_capacity=24, qos_slots=2)
    entries = cluster_entries(wire, cfg.m)
    nid = jnp.arange(8, dtype=jnp.int32)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    before = serve_trace_count(cfg)
    state = host_server_init(cfg)
    rng = np.random.RandomState(7)
    for slot in range(10):
        active = rng.rand(8) < rng.uniform(0.1, 0.9)   # nodes drop in/out
        state, _ = host_serve_slot(state, entries, nid,
                                   jnp.asarray(active), **kw)
    assert serve_trace_count(cfg) - before <= 2


def test_payload_conservation_over_churny_trace(setup):
    """Every ingested payload is served, missed, dropped, or still queued."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, queue_capacity=8, qos_slots=1)
    entries = cluster_entries(wire, cfg.m)
    nid = jnp.arange(8, dtype=jnp.int32)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state = host_server_init(cfg)
    rng = np.random.RandomState(3)
    total = 0
    for slot in range(8):
        active = rng.rand(8) < 0.7
        total += int(active.sum())
        state, _ = host_serve_slot(state, entries, nid,
                                   jnp.asarray(active), **kw)
    stats = host_server_stats(state)
    assert (stats["served"] + stats["deadline_misses"]
            + stats["drops_overflow"] + stats["backlog"]) == total


# ---------------------------------------------------------------------------
# QoS accounting: EDF service order, deadline misses, overflow drops
# ---------------------------------------------------------------------------

def test_backlog_served_before_fresh_arrivals(setup):
    """EDF across slots: slot-0 leftovers (earlier deadlines) must be served
    before slot-1 arrivals."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, qos_slots=4)
    entries = cluster_entries(wire, cfg.m)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state = host_server_init(cfg)
    four = jax.tree_util.tree_map(lambda a: a[:4], entries)
    state, out0 = host_serve_slot(state, four, jnp.arange(4, dtype=jnp.int32),
                                  jnp.ones((4,), bool), **kw)
    assert sorted(_by_node(out0)) == [0, 1]        # 2 served, 2 backlogged
    two = jax.tree_util.tree_map(lambda a: a[4:6], entries)
    state, out1 = host_serve_slot(state, two,
                                  jnp.asarray([4, 5], jnp.int32),
                                  jnp.ones((2,), bool), **kw)
    assert sorted(_by_node(out1)) == [2, 3]        # backlog first (EDF)
    assert host_server_stats(state)["backlog"] == 2


def test_deadline_misses_counted_not_served(setup):
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, qos_slots=0)
    entries = cluster_entries(wire, cfg.m)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state = host_server_init(cfg)
    four = jax.tree_util.tree_map(lambda a: a[:4], entries)
    state, _ = host_serve_slot(state, four, jnp.arange(4, dtype=jnp.int32),
                               jnp.ones((4,), bool), **kw)
    # qos 0: the 2 unserved leftovers expire at the next slot's assembly
    state, out = host_serve_slot(
        state, four, jnp.arange(4, dtype=jnp.int32), jnp.zeros((4,), bool),
        **kw)
    stats = host_server_stats(state)
    assert stats["served"] == 2 and stats["deadline_misses"] == 2
    assert int(np.asarray(out.valid).sum()) == 0


def test_overflow_drops_counted(setup):
    """A lane that FITS the ring can still overflow it across slots once a
    backlog accumulates — those drops must be counted.  (A lane wider than
    the ring is rejected outright: see test_ingest_lane_wider_than_capacity
    _raises.)"""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, queue_capacity=4, qos_slots=8)
    four = jax.tree_util.tree_map(lambda a: a[:4], cluster_entries(wire,
                                                                   cfg.m))
    nid = jnp.arange(4, dtype=jnp.int32)
    mask = jnp.ones((4,), bool)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state = host_server_init(cfg)
    # slot 0: 4 arrivals fill the ring exactly; 2 served, 2 backlogged
    state, _ = host_serve_slot(state, four, nid, mask, **kw)
    # slot 1: 4 more arrivals meet 2 free slots -> 2 inserted, 2 dropped
    state, _ = host_serve_slot(state, four, nid, mask, **kw)
    stats = host_server_stats(state)
    assert stats["drops_overflow"] == 2
    assert stats["served"] == 4 and stats["backlog"] == 2


# ---------------------------------------------------------------------------
# HostServeConfig validation (the silently-truncating configs now raise)
# ---------------------------------------------------------------------------

def test_config_batch_size_over_capacity_raises():
    """batch_size > queue_capacity silently clamps edf_pop_batch to the
    capacity (order[:batch_size] over a capacity-long array) — rejected."""
    with pytest.raises(ValueError, match="batch_size=32 exceeds "
                                         "queue_capacity=16"):
        _cfg(batch_size=32, queue_capacity=16)


@pytest.mark.parametrize("field", ["channels", "k", "m", "t", "n_classes",
                                   "n_nodes", "batch_size", "queue_capacity",
                                   "cache_capacity"])
def test_config_nonpositive_dims_raise(field):
    with pytest.raises(ValueError, match=f"{field} must be >= 1"):
        _cfg(**{field: 0})


@pytest.mark.parametrize("field", ["qos_slots", "batches_per_slot"])
def test_config_negative_counts_raise(field):
    with pytest.raises(ValueError, match=f"{field} must be >= 0"):
        _cfg(**{field: -1})


def test_config_zero_qos_and_probe_key_still_legal():
    """qos_slots=0 (serve-now-or-miss) and batches_per_slot=0 (the
    serve_trace_count normalization key) must stay constructible."""
    _cfg(qos_slots=0)
    dataclasses.replace(_cfg(), batches_per_slot=0)


def test_ingest_lane_wider_than_capacity_raises(setup):
    """An 8-wide lane into a 4-slot ring would overflow EVERY slot by
    construction — rejected at the entry point, not silently dropped."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, queue_capacity=4)
    entries = cluster_entries(wire, cfg.m)          # lane width 8
    with pytest.raises(ValueError, match="ingest lane of 8 entries exceeds "
                                         "queue_capacity=4"):
        host_serve_slot(host_server_init(cfg), entries,
                        jnp.arange(8, dtype=jnp.int32), jnp.ones((8,), bool),
                        cfg=cfg, host_params=params, gen_params=gen,
                        base_key=key)


# ---------------------------------------------------------------------------
# Mixed payload kinds + trace/resume
# ---------------------------------------------------------------------------

def test_sampling_payloads_take_the_gan_path(setup):
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=4, n_nodes=4)
    sc = jax.vmap(lambda w, k_: importance_coreset(w, cfg.m, k_))(
        wins[:4], jax.random.split(key, 4))
    swire = encode_wire_samples(sc.indices, sc.values, sc.mean, sc.var)
    s_entries = sampling_entries(swire, cfg.k)
    c_entries = cluster_entries(jax.tree_util.tree_map(lambda a: a[:4], wire),
                                cfg.m)
    nid = jnp.arange(4, dtype=jnp.int32)
    mask = jnp.ones((4,), bool)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    _, out_s = host_serve_slot(host_server_init(cfg), s_entries, nid, mask,
                               **kw)
    _, out_c = host_serve_slot(host_server_init(cfg), c_entries, nid, mask,
                               **kw)
    ls, lc = _by_node(out_s), _by_node(out_c)
    assert ls.keys() == lc.keys() == {0, 1, 2, 3}
    assert all(np.isfinite(ls[n]).all() for n in ls)
    # the two recovery paths answer differently for the same windows
    assert any(not np.array_equal(ls[n], lc[n]) for n in ls)


def test_serve_trace_resume_equals_one_long_run(setup):
    """Resumable carry, fleet-engine style: scanning 6 slots equals chaining
    3 + 3 through the returned state, bitwise."""
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=2, queue_capacity=32)
    entries = cluster_entries(wire, cfg.m)
    s, a = 6, 8
    tr_entries = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (s,) + x.shape), entries)
    nids = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None], (s, a))
    rng = np.random.RandomState(11)
    masks = jnp.asarray(rng.rand(s, a) < 0.5)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    full_state, full_out = host_serve_trace(
        host_server_init(cfg), tr_entries, nids, masks, **kw)
    half = s // 2
    st1, out1 = host_serve_trace(
        host_server_init(cfg),
        jax.tree_util.tree_map(lambda x: x[:half], tr_entries),
        nids[:half], masks[:half], **kw)
    st2, out2 = host_serve_trace(
        st1, jax.tree_util.tree_map(lambda x: x[half:], tr_entries),
        nids[half:], masks[half:], **kw)

    for leaf_full, leaf_2 in zip(jax.tree_util.tree_leaves(full_out),
                                 jax.tree_util.tree_leaves(out2)):
        np.testing.assert_array_equal(np.asarray(leaf_full)[half:],
                                      np.asarray(leaf_2))
    for leaf_full, leaf_2 in zip(jax.tree_util.tree_leaves(full_state),
                                 jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(leaf_full),
                                      np.asarray(leaf_2))


def test_ensemble_accumulates_per_node(setup):
    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=4, n_nodes=4)
    entries = cluster_entries(jax.tree_util.tree_map(lambda a: a[:4], wire),
                              cfg.m)
    nid = jnp.asarray([0, 0, 1, 2], jnp.int32)     # node 0 twice
    mask = jnp.ones((4,), bool)
    kw = dict(cfg=cfg, host_params=params, gen_params=gen, base_key=key)

    state, out = host_serve_slot(host_server_init(cfg), entries, nid, mask,
                                 **kw)
    ens = host_ensemble(state)
    np.testing.assert_array_equal(np.asarray(ens["counts"]), [2, 1, 1, 0])
    valid = np.asarray(out.valid)
    logits = np.asarray(out.logits)[valid]
    nodes = np.asarray(out.node_id)[valid]
    want0 = logits[nodes == 0].sum(axis=0) / 2.0
    np.testing.assert_allclose(np.asarray(ens["mean_logits"])[0], want0,
                               rtol=1e-6)
    assert int(ens["pred_mean"][0]) == int(np.argmax(want0))


# ---------------------------------------------------------------------------
# fleet_serve_step queue mode (the rewire)
# ---------------------------------------------------------------------------

def test_fleet_serve_step_feeds_host_server(setup):
    from repro.serving import fleet_serve_step
    from repro.sharding import make_mesh_compat

    key, params, gen, wins, labels, wire = setup
    mesh = make_mesh_compat((jax.device_count(),), ("data",))
    cfg = _cfg(batch_size=4, n_nodes=6, queue_capacity=8)
    state = host_server_init(cfg)
    out = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                           mesh=mesh, key=key, host_state=state,
                           serve_cfg=cfg, gen_params=gen)
    stats = host_server_stats(out["host_state"])
    assert stats["served"] == 6 and stats["deadline_misses"] == 0
    served = _by_node(out["slot_output"])
    assert sorted(served) == [0, 1, 2, 3, 4, 5]
    assert all(np.isfinite(v).all() for v in served.values())
    assert out["wire_bytes"] < out["raw_bytes"]
    # a second round of the same windows is fully cache-served
    out2 = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                            mesh=mesh, key=key,
                            host_state=out["host_state"], serve_cfg=cfg,
                            gen_params=gen)
    stats2 = host_server_stats(out2["host_state"])
    assert stats2["cache_hits"] == 6
    a, b = served, _by_node(out2["slot_output"])
    for n in a:
        np.testing.assert_array_equal(a[n], b[n])


def test_fleet_serve_step_queue_mode_requires_cfg(setup):
    from repro.serving import fleet_serve_step
    from repro.sharding import make_mesh_compat

    key, params, gen, wins, labels, wire = setup
    mesh = make_mesh_compat((jax.device_count(),), ("data",))
    cfg = _cfg()
    with pytest.raises(ValueError, match="serve_cfg"):
        fleet_serve_step(wins[:4], host_params=params, har_cfg=HAR,
                         mesh=mesh, key=key,
                         host_state=host_server_init(cfg))


def test_fleet_serve_step_alive_mask_keeps_dead_nodes_out(setup):
    """Churn round: dead nodes' payloads never enqueue — not served, not
    backlogged, not counted anywhere; wire bytes count only transmitters."""
    from repro.serving import fleet_serve_step
    from repro.sharding import make_mesh_compat

    key, params, gen, wins, labels, wire = setup
    mesh = make_mesh_compat((jax.device_count(),), ("data",))
    cfg = _cfg(batch_size=4, n_nodes=6, queue_capacity=8)
    alive = jnp.asarray([True, False, True, True, False, True])
    out = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                           mesh=mesh, key=key,
                           host_state=host_server_init(cfg), serve_cfg=cfg,
                           gen_params=gen, alive=alive)
    stats = host_server_stats(out["host_state"])
    assert (stats["served"] + stats["deadline_misses"]
            + stats["drops_overflow"] + stats["backlog"]) == 4
    served = _by_node(out["slot_output"])
    assert sorted(served) == [0, 2, 3, 5]          # alive nodes only
    # the full fleet would have shipped 6 frames; only 4 transmitted
    full = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                            mesh=mesh, key=key,
                            host_state=host_server_init(cfg), serve_cfg=cfg,
                            gen_params=gen)
    assert out["wire_bytes"] == full["wire_bytes"] * 4 // 6
    # alive nodes' answers are unaffected by who else was up (payload-
    # deterministic recovery PRNG)
    ref = _by_node(full["slot_output"])
    for n in served:
        np.testing.assert_array_equal(served[n], ref[n])


def test_fleet_serve_step_alive_requires_queue_mode(setup):
    from repro.serving import fleet_serve_step
    from repro.sharding import make_mesh_compat

    key, params, gen, wins, labels, wire = setup
    mesh = make_mesh_compat((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="queue-mode argument"):
        fleet_serve_step(wins[:4], host_params=params, har_cfg=HAR,
                         mesh=mesh, key=key,
                         alive=jnp.ones((4,), bool))
    with pytest.raises(ValueError, match="queue-mode argument"):
        fleet_serve_step(wins[:4], host_params=params, har_cfg=HAR,
                         mesh=mesh, key=key,
                         engine_alive=jnp.ones((4,), bool))


def test_fleet_serve_step_engine_alive_composes(setup):
    """ISSUE 5: the host's per-round mask comes from the engine's emitted
    alive trace, not just the caller's — a browned-out node (engine lane)
    transmits no frame, exactly like an exogenously-dead one, and the two
    masks compose by AND."""
    from repro.serving import fleet_serve_step
    from repro.sharding import make_mesh_compat

    key, params, gen, wins, labels, wire = setup
    mesh = make_mesh_compat((jax.device_count(),), ("data",))
    cfg = _cfg(batch_size=4, n_nodes=6, queue_capacity=8)
    caller = jnp.asarray([True, False, True, True, True, True])
    engine = jnp.asarray([True, True, True, False, True, True])   # browned
    out = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                           mesh=mesh, key=key,
                           host_state=host_server_init(cfg), serve_cfg=cfg,
                           gen_params=gen, alive=caller,
                           engine_alive=engine)
    assert sorted(_by_node(out["slot_output"])) == [0, 2, 4, 5]
    # identical to handing the composed mask in as `alive`
    both = fleet_serve_step(wins[:6], host_params=params, har_cfg=HAR,
                            mesh=mesh, key=key,
                            host_state=host_server_init(cfg), serve_cfg=cfg,
                            gen_params=gen, alive=caller & engine)
    assert out["wire_bytes"] == both["wire_bytes"]
    a, b = _by_node(out["slot_output"]), _by_node(both["slot_output"])
    for n in a:
        np.testing.assert_array_equal(a[n], b[n])


# ---------------------------------------------------------------------------
# Heterogeneous task fleets on the host tier (ISSUE 9)
# ---------------------------------------------------------------------------

def test_task_id_rides_payload_into_cache_and_weights(setup):
    """The SAME wire payload sent by an HAR node and a bearing node must (a)
    occupy two distinct cache rows — the task id is a payload leaf, so the
    signature differs — and (b) come back through that task's stacked host
    weights, not a shared tree."""
    from repro.models.har import har_init as _init
    from repro.serving import stack_task_params

    key, params, gen, wins, labels, wire = setup
    params_b = _init(jax.random.fold_in(key, 5), HAR)
    cfg = _cfg(batch_size=2, n_nodes=2, n_tasks=2)
    stacked = stack_task_params((params, params_b))
    two = jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a[:1], (2,)
                                                            + a.shape[1:]),
                                 wire)
    entries = cluster_entries(two, cfg.m, tasks=jnp.asarray([0, 1]))
    nid = jnp.arange(2, dtype=jnp.int32)
    mask = jnp.ones((2,), bool)
    kw = dict(cfg=cfg, host_params=stacked, gen_params=gen, base_key=key)

    state, out = host_serve_slot(host_server_init(cfg), entries, nid, mask,
                                 **kw)
    assert host_server_stats(state)["cache_misses"] == 2   # no collision
    a = _by_node(out)
    assert not np.array_equal(a[0], a[1]), \
        "identical payload, different tasks -> different host weights"

    # node 0 (task 0, weights == the shared tree) matches the n_tasks=1 path
    cfg1 = _cfg(batch_size=2, n_nodes=2)
    e1 = cluster_entries(two, cfg1.m)
    _, out1 = host_serve_slot(host_server_init(cfg1), e1, nid, mask,
                              cfg=cfg1, host_params=params, gen_params=gen,
                              base_key=key)
    np.testing.assert_array_equal(a[0], _by_node(out1)[0])


def test_batch_task_counts_masks_invalid_rows(setup):
    from repro.host import batch_task_counts
    from repro.host.queue import queue_init, queue_push_batch
    from repro.host.scheduler import edf_pop_batch

    key, params, gen, wins, labels, wire = setup
    cfg = _cfg(batch_size=4, n_nodes=8)
    entries = cluster_entries(jax.tree_util.tree_map(lambda a: a[:3], wire),
                              cfg.m, tasks=jnp.asarray([0, 1, 1]))
    q = queue_init(jax.tree_util.tree_map(lambda a: a[0], entries),
                   cfg.queue_capacity)
    arr = jnp.zeros((3,), jnp.int32)
    q, _ = queue_push_batch(q, entries, jnp.arange(3, dtype=jnp.int32),
                            arr, arr + cfg.qos_slots, jnp.ones((3,), bool))
    q, batch, _ = edf_pop_batch(q, cfg.batch_size)
    counts = np.asarray(batch_task_counts(batch, 2))
    assert counts.tolist() == [1, 2]                  # 4th row is padding
    assert counts.sum() == int(np.asarray(batch.valid).sum())
