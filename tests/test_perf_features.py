"""Tests for the §Perf structural features: head padding, flash-vjp
attention, sharding prefix fallback, pure-DP rules, elastic re-mesh."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import forward, init_params
from repro.models.config import ModelConfig
from repro.models.flash import flash_banded_attention, flash_causal_attention
from repro.models.layers import dense_attention

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_head_padding_exact_forward_and_grad(key):
    """Zero-padded q-heads + expanded KV == unpadded math exactly."""
    cfg = ModelConfig(name="t", vocab=256, d_model=36, n_layers=2, n_heads=6,
                      n_kv=2, head_dim=8, d_ff=64, dtype=jnp.float32)
    cfg_pad = dataclasses.replace(cfg, head_pad_multiple=8)
    assert cfg_pad.padded_heads == 8
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, 256)
    a = forward(params, cfg, tokens)
    b = forward(params, cfg_pad, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)

    def loss(p, c):
        lg = forward(p, c, tokens)[..., :cfg.vocab]     # exclude vocab pad
        return jnp.mean(lg.astype(jnp.float32) ** 2)

    g1 = jax.grad(lambda p: loss(p, cfg))(params)
    g2 = jax.grad(lambda p: loss(p, cfg_pad))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("softcap", [0.0, 10.0])
def test_flash_causal_matches_dense(softcap, key):
    q = jax.random.normal(key, (2, 64, 2, 3, 16)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    ref = dense_attention(q, k, v, causal=True, softcap=softcap)
    out = flash_causal_attention(q, k, v, 16, softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    g_ref = jax.grad(lambda q, k, v: jnp.sum(dense_attention(
        q, k, v, causal=True, softcap=softcap) ** 2), (0, 1, 2))(q, k, v)
    g_out = jax.grad(lambda q, k, v: jnp.sum(flash_causal_attention(
        q, k, v, 16, softcap) ** 2), (0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_out):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


def test_flash_banded_matches_dense_window(key):
    q = jax.random.normal(key, (2, 64, 2, 3, 16)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    ref = dense_attention(q, k, v, causal=True, window=24)
    out = flash_banded_attention(q, k, v, 24, 16, 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    g_ref = jax.grad(lambda q, k, v: jnp.sum(dense_attention(
        q, k, v, causal=True, window=24) ** 2), (0, 1, 2))(q, k, v)
    g_out = jax.grad(lambda q, k, v: jnp.sum(flash_banded_attention(
        q, k, v, 24, 16, 0.0) ** 2), (0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_out):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_spec_for_prefix_fallback():
    """batch=32 on a 512-way ("pod","data","model") rule shards over the
    longest divisible prefix instead of dropping entirely."""
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd
    from repro.sharding import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
    s = shd.spec_for(("batch",), (8,), mesh, shd.PURE_DP_RULES)
    assert s == P(("pod", "data", "model")), s
    s = shd.spec_for(("batch",), (4,), mesh, shd.PURE_DP_RULES)
    assert s == P(("pod", "data")), s
    s = shd.spec_for(("batch",), (2,), mesh, shd.PURE_DP_RULES)
    assert s in (P("pod"), P(("pod",))), s   # singleton unwraps; newer jax
    # normalizes the two spellings to equality, 0.4.x does not
    s = shd.spec_for(("batch",), (3,), mesh, shd.PURE_DP_RULES)
    assert s == P(None), s
    print("OK")
    """
    assert "OK" in _run(code, devices=8)


@pytest.mark.slow
def test_elastic_remesh_restore():
    """A checkpoint written under one mesh restores onto a different mesh
    (elastic scaling), with identical values."""
    code = """
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.sharding import make_mesh_compat
    mesh8 = make_mesh_compat((8,), ("data",))
    mesh24 = make_mesh_compat((2, 4), ("data", "model"))
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh8, P("data", None)))
    tree = {"w": x}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        abstract = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        sh = {"w": NamedSharding(mesh24, P("model", "data"))}
        back = restore_checkpoint(d, 1, abstract, sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(x))
    assert back["w"].sharding.mesh.shape == {"data": 2, "model": 4}
    print("OK")
    """
    assert "OK" in _run(code, devices=8)
