"""Training substrate tests: optimizer, loop, fault tolerance, checkpoints."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, list_steps, restore_checkpoint,
                              save_checkpoint)
from repro.data.lm import LMTask, lm_batches
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_init, adamw_update, warmup_cosine
from repro.train import (TrainHyper, TrainLoopConfig, init_train_state,
                         make_train_step, run_training)

CFG = ModelConfig(name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=4,
                  n_kv=2, d_ff=64, dtype=jnp.float32)
TASK = LMTask(vocab=64, seq_len=32, batch=8)


def test_adamw_descends_quadratic(key):
    p = {"w": jax.random.normal(key, (16,))}
    opt = adamw_init(p, OptConfig(weight_decay=0.0))
    cfg = OptConfig(weight_decay=0.0)
    for _ in range(200):
        g = jax.tree_util.tree_map(lambda x: 2 * x, p)   # grad of ||x||^2
        p, opt, _ = adamw_update(p, g, opt, cfg, jnp.float32(0.05))
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), 1.0, 10, 100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.01)
    assert lrs[5] < lrs[9]                 # warming up
    assert lrs[50] > lrs[99]               # decaying
    assert lrs[99] >= 0.1 - 1e-6           # floor


def test_loss_decreases(key):
    hyper = TrainHyper(peak_lr=3e-3, warmup=5, total_steps=50)
    state = init_train_state(key, CFG, hyper)
    step = jax.jit(make_train_step(CFG, hyper))
    losses = []
    for s in range(50):
        state, m = step(state, lm_batches(TASK, s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_microbatch_accumulation_matches_full_batch(key):
    hyper_full = TrainHyper(peak_lr=1e-3, warmup=1, total_steps=10)
    hyper_micro = TrainHyper(peak_lr=1e-3, warmup=1, total_steps=10,
                             microbatch=2)
    s0 = init_train_state(key, CFG, hyper_full)
    batch = lm_batches(TASK, 0)
    s_full, m_full = make_train_step(CFG, hyper_full)(s0, batch)
    s_micro, m_micro = make_train_step(CFG, hyper_micro)(s0, batch)
    assert float(m_full["loss"]) == pytest.approx(float(m_micro["loss"]),
                                                  rel=1e-5)
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        s_full["params"], s_micro["params"])
    assert max(jax.tree_util.tree_leaves(diff)) < 1e-5


def test_checkpoint_roundtrip(key):
    hyper = TrainHyper()
    state = init_train_state(key, CFG, hyper)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, state)
        assert list_steps(d) == [7]
        back = restore_checkpoint(d, 7, abstract)
        diff = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            state, back)
        assert max(jax.tree_util.tree_leaves(diff)) == 0.0


def test_checkpoint_prune_and_abort_safety(key):
    state = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, state, keep=2)
        assert list_steps(d) == [3, 4]
        # an aborted write (no manifest) is invisible
        os.makedirs(os.path.join(d, "step_0000000099"))
        assert latest_step(d) == 4


def test_preemption_restart_is_bit_exact(key):
    """Crash at step 35, resume from the step-20 checkpoint: final params
    match an uninterrupted run exactly (deterministic data pipeline)."""
    hyper = TrainHyper(peak_lr=3e-3, warmup=5, total_steps=40)
    step = jax.jit(make_train_step(CFG, hyper))
    batch_fn = lambda s: lm_batches(TASK, s)

    def run(preempt, d):
        state = init_train_state(key, CFG, hyper)
        loop = TrainLoopConfig(total_steps=40, ckpt_dir=d, ckpt_every=20,
                               log_every=100, preempt_at=preempt)
        return run_training(state, step, batch_fn, loop)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        s_crash, log_crash = run((35,), d1)
        s_clean, _ = run((), d2)
    assert any(m.get("event") == "preempted" for m in log_crash)
    assert any(m.get("event") == "resume" for m in log_crash)
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s_crash["params"], s_clean["params"])
    assert max(jax.tree_util.tree_leaves(diff)) == 0.0


def test_budget_throttling_defers_steps(key):
    """EH-budget gating (the paper's store-and-execute at pod scale):
    with a too-expensive per-step cost some steps defer, but training
    still completes the schedule."""
    hyper = TrainHyper(peak_lr=3e-3, warmup=5, total_steps=30)
    state = init_train_state(key, CFG, hyper)
    step = jax.jit(make_train_step(CFG, hyper))
    loop = TrainLoopConfig(total_steps=30, budget_source="rf",
                           budget_cost_uj=25.0, log_every=5)
    _, log = run_training(state, step, lambda s: lm_batches(TASK, s), loop)
    deferred = [m for m in log if m.get("deferred")]
    executed = [m for m in log if "loss" in m]
    assert deferred, "expected some deferred slots under RF harvest"
    assert executed, "expected some executed steps"
