"""Sharding resolution tests + subprocess dry-run/mesh integration.

The main pytest process stays single-device (per the assignment: only the
dry-run sees 512 devices); anything needing a mesh runs in a subprocess
with its own XLA_FLAGS.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["DRYRUN_DEVICES"] = str(devices)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_spec_for_divisibility_fallback():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd
    from repro.sharding import make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    # divisible: shard; non-divisible: replicate
    s = shd.spec_for(("batch", "ff"), (8, 12), mesh, shd.FSDP_RULES)
    assert s == P("data", "model"), s
    s = shd.spec_for(("batch", "ff"), (8, 13), mesh, shd.FSDP_RULES)
    assert s == P("data", None), s
    # duplicate mesh-axis use: first dim wins
    s = shd.spec_for(("heads", "ff"), (8, 8), mesh, shd.FSDP_RULES)
    assert s == P("model", None), s
    # missing mesh axis dropped (pod rule on a pod-less mesh)
    s = shd.spec_for(("batch",), (8,), mesh, shd.FSDP_RULES)
    assert s == P("data"), s
    print("OK")
    """
    assert "OK" in _run(code, devices=8)


def test_constrain_noop_without_context():
    import jax.numpy as jnp
    from repro.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "ff")
    assert (x == y).all()


@pytest.mark.slow
def test_dryrun_single_cell_compiles_256_devices():
    """End-to-end dry-run of one real cell on the production (16,16) mesh."""
    code = """
    from repro.launch.dryrun import run_cell
    res = run_cell("tinyllama-1.1b", "decode_32k", multi_pod=False)
    assert res["status"] == "ok", res.get("error")
    assert res["n_devices"] == 256
    ma = res["memory_analysis"]
    total = (ma["argument_bytes"] + ma["temp_bytes"]) / 2**30
    assert total < 16, f"does not fit HBM: {total} GiB"
    assert res["hlo_analysis"]["flops"] > 0
    print("OK", total)
    """
    out = _run(code, devices=256)
    assert "OK" in out


@pytest.mark.slow
def test_multipod_mesh_compiles_512_devices():
    """The multi-pod (2,16,16) mesh lowers + compiles a small cell — proves
    the pod axis shards."""
    code = """
    from repro.launch.dryrun import run_cell
    res = run_cell("mamba2-130m", "decode_32k", multi_pod=True)
    assert res["status"] == "ok", res.get("error")
    assert res["n_devices"] == 512
    print("OK")
    """
    out = _run(code, devices=512)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_dp_reduces_collective_bytes():
    """Seeker gradient coresets cut the DP all-reduce wire bytes in the
    lowered HLO (paper C1 at pod scale)."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import sharding as shd
    from repro.core.compression import CompressionConfig
    from repro.data.lm import LMTask, lm_batches
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.models.config import ModelConfig
    from repro.train import (TrainHyper, init_train_state,
                             make_compressed_train_step, make_train_step)

    from repro.sharding import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    cfg = ModelConfig(name="t", vocab=256, d_model=64, n_layers=2, n_heads=4,
                      n_kv=2, d_ff=256, dtype=jnp.float32)
    hyper = TrainHyper()
    ccfg = CompressionConfig(topk_ratio=1/64, min_size=1024)
    task = LMTask(vocab=256, seq_len=64, batch=16)
    batch = lm_batches(task, 0)

    with shd.use_sharding(mesh, shd.DP_TP_RULES):
        state = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, hyper, ccfg))
        sh_state = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), state)
        sh_batch = {"tokens": NamedSharding(mesh, P("data"))}

        dense = make_train_step(cfg, hyper)
        state_d = {k: v for k, v in state.items() if k != "ef"}
        sh_d = {k: v for k, v in sh_state.items() if k != "ef"}
        lowered_d = jax.jit(dense, in_shardings=(sh_d, sh_batch)).lower(
            state_d, batch)
        comp = make_compressed_train_step(cfg, hyper, ccfg, mesh, ("data",))
        lowered_c = jax.jit(comp).lower(state, batch)

    b_dense = analyze_hlo(lowered_d.compile().as_text())
    b_comp = analyze_hlo(lowered_c.compile().as_text())
    ar_d = b_dense.collective_bytes["all-reduce"]
    total_c = b_comp.total_collective_bytes
    print("dense all-reduce:", ar_d, " compressed total:", total_c)
    assert total_c < ar_d, (total_c, ar_d)
    print("OK")
    """
    out = _run(code, devices=8)
    assert "OK" in out
