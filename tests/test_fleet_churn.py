"""Churn + streaming fleet engine, single-device (ISSUE 4).

The contracts pinned here (the sharded mirrors live in
tests/test_fleet_sharded.py's subprocess snippets):

* an all-True ``alive`` trace is BITWISE the churn-free engine;
* a dead slot freezes the node — supercapacitor charge, predictor history
  and the PRNG stream all hold, the node emits DEFER with zero payload, and
  on rejoin it continues exactly where it browned out;
* aggregates (decision histogram, completion, accuracy) count only alive
  slots — a browned-out node's forced DEFER is absence, not a decision;
* :func:`seeker_fleet_simulate_streamed` chunked runs are bitwise one long
  run, traces and final keys, churn and per-node labels included;
* the per-node-label accuracy contract: (S, N) tracks score each node
  against its OWN stream; a shared (S,) track with per-node streams raises
  (the silent bug this PR fixes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import DEFER, fleet_alive_traces, fleet_harvest_traces, \
    fleet_phase_offsets
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (seeker_fleet_simulate,
                           seeker_fleet_simulate_streamed)

S, N = 12, 4


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, S)
    harvest = fleet_harvest_traces(key, N, S)
    kw = dict(signatures=sigs, qdnn_params=params, host_params=params,
              gen_params=gen, har_cfg=HAR, key=key, donate=False)
    return key, wins, labels, harvest, kw


# ---------------------------------------------------------------------------
# Alive-trace generation
# ---------------------------------------------------------------------------

def test_alive_traces_shape_seeding_and_duty(key):
    tr = fleet_alive_traces(key, 6, 32, duty=0.5, period=8)
    assert tr.shape == (6, 32) and tr.dtype == bool
    # seeded like fleet_harvest_traces: reproducible, per-node folds
    np.testing.assert_array_equal(
        np.asarray(tr),
        np.asarray(fleet_alive_traces(key, 6, 32, duty=0.5, period=8)))
    # duty-cycled: every node both drops out and rejoins
    t = np.asarray(tr)
    assert ((~t).any(axis=1)).all() and (t.any(axis=1)).all()
    # phase offsets desynchronize nodes
    assert not all(np.array_equal(t[0], t[i]) for i in range(1, 6))
    phases = np.asarray(fleet_phase_offsets(key, 6, 8))
    assert phases.shape == (6,) and (phases >= 0).all() and (phases < 8).all()


def test_alive_traces_full_duty_is_all_true(key):
    tr = fleet_alive_traces(key, 4, 16, duty=1.0, p_glitch=0.0)
    assert bool(jnp.all(tr))


def test_alive_traces_bad_duty_raises(key):
    with pytest.raises(ValueError, match="duty"):
        fleet_alive_traces(key, 2, 4, duty=1.5)


# ---------------------------------------------------------------------------
# Churn equivalence + semantics
# ---------------------------------------------------------------------------

def test_all_true_alive_is_bitwise_churn_free(setup):
    """Acceptance: alive=ones == no alive argument, bit for bit (traces,
    aggregates, final state AND final PRNG keys)."""
    key, wins, labels, harvest, kw = setup
    base = seeker_fleet_simulate(wins, harvest, labels=labels, **kw)
    allT = seeker_fleet_simulate(wins, harvest, labels=labels,
                                 alive=jnp.ones((N, S), bool), **kw)
    for k in ("decisions", "payload_bytes", "stored_uj", "k_trace", "logits",
              "decision_histogram", "completed", "correct"):
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(allT[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(base["final_keys"]),
                                  np.asarray(allT["final_keys"]))
    np.testing.assert_array_equal(
        np.asarray(base["final_state"].stored_uj),
        np.asarray(allT["final_state"].stored_uj))
    assert int(allT["alive_slots"]) == S * N


def test_dead_slots_defer_zero_payload_frozen_state(setup):
    key, wins, labels, harvest, kw = setup
    alive = fleet_alive_traces(key, N, S, duty=0.5, period=4, p_glitch=0.1)
    res = seeker_fleet_simulate(wins, harvest, alive=alive, **kw)
    a = np.asarray(alive).T                                  # (S, N)
    assert a.sum() < S * N, "fixture must actually churn"
    dec = np.asarray(res["decisions"])
    assert (dec[~a] == DEFER).all()
    assert (np.asarray(res["payload_bytes"])[~a] == 0).all()
    assert (np.asarray(res["logits"])[~a] == 0).all()
    assert (np.asarray(res["k_trace"])[~a] == 0).all()
    # stored µJ holds its previous value through every dead slot
    stored = np.asarray(res["stored_uj"])
    for node in range(N):
        prev = 50.0
        for t in range(S):
            if not a[t, node]:
                assert stored[t, node] == prev, (t, node)
            prev = stored[t, node]


def test_always_dead_node_is_fully_inert(setup):
    """A node dead for the whole deployment neither consumes PRNG draws nor
    moves its state — and the other nodes are bitwise unaffected."""
    key, wins, labels, harvest, kw = setup
    alive = jnp.ones((N, S), bool).at[1].set(False)
    res = seeker_fleet_simulate(wins, harvest, alive=alive, **kw)
    base = seeker_fleet_simulate(wins, harvest, **kw)
    # node 1: untouched key and charge
    np.testing.assert_array_equal(
        np.asarray(res["final_keys"][1]),
        np.asarray(jax.random.fold_in(key, 1)))
    assert float(res["final_state"].stored_uj[1]) == 50.0
    assert (np.asarray(res["decisions"])[:, 1] == DEFER).all()
    # every other node: bitwise the churn-free trajectory
    keep = [0, 2, 3]
    for k in ("decisions", "payload_bytes", "stored_uj", "logits"):
        np.testing.assert_array_equal(np.asarray(res[k])[:, keep],
                                      np.asarray(base[k])[:, keep], err_msg=k)


def test_rejoin_continues_prng_stream(setup):
    """A node that sleeps through a PREFIX of the deployment wakes into
    exactly the trajectory of a fresh node at its rejoin charge: frozen
    slots consume no randomness (the PRNG lane is part of the freeze)."""
    key, wins, labels, harvest, kw = setup
    half = S // 2
    alive = jnp.ones((N, S), bool).at[0, :half].set(False)
    res = seeker_fleet_simulate(wins, harvest, alive=alive, **kw)
    # oracle: simulate only the tail, node 0 starting fresh at 50 µJ
    tail = seeker_fleet_simulate(wins[half:], harvest[:, half:], **kw)
    np.testing.assert_array_equal(np.asarray(res["decisions"])[half:, 0],
                                  np.asarray(tail["decisions"])[:, 0])
    np.testing.assert_array_equal(np.asarray(res["stored_uj"])[half:, 0],
                                  np.asarray(tail["stored_uj"])[:, 0])
    np.testing.assert_array_equal(np.asarray(res["final_keys"][0]),
                                  np.asarray(tail["final_keys"][0]))


def test_aggregates_respect_alive_mask(setup):
    key, wins, labels, harvest, kw = setup
    alive = fleet_alive_traces(key, N, S, duty=0.6, period=4)
    res = seeker_fleet_simulate(wins, harvest, alive=alive, labels=labels,
                                **kw)
    a = np.asarray(alive).T
    dec = np.asarray(res["decisions"])
    np.testing.assert_array_equal(
        np.asarray(res["decision_histogram"]),
        np.bincount(dec[a].ravel(), minlength=6))
    sent = (dec != DEFER) & a
    assert int(res["completed"]) == sent.sum()
    assert int(res["alive_slots"]) == a.sum()
    assert float(res["completed_frac"]) == pytest.approx(
        sent.sum() / max(a.sum(), 1), abs=1e-6)
    correct = ((np.asarray(res["preds"]) == np.asarray(labels)[:, None])
               & sent).sum()
    assert int(res["correct"]) == correct


def test_alive_wrong_shape_raises(setup):
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match="alive must be"):
        seeker_fleet_simulate(wins, harvest, alive=jnp.ones((N, S + 1), bool),
                              **kw)


# ---------------------------------------------------------------------------
# Per-node labels (the headline bugfix)
# ---------------------------------------------------------------------------

def test_shared_labels_with_per_node_streams_raise(setup):
    """The old engine silently scored every node's own stream against ONE
    label track; now it refuses."""
    key, wins, labels, harvest, kw = setup
    wn = jnp.stack([wins + 0.01 * i for i in range(N)])
    with pytest.raises(ValueError, match="ambiguous"):
        seeker_fleet_simulate(wn, harvest, labels=labels, **kw)


def test_labels_bad_shape_raises(setup):
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match="labels must be"):
        seeker_fleet_simulate(wins, harvest, labels=labels[: S - 1], **kw)


def test_swapped_label_tracks_regression(setup):
    """Two nodes playing each other's streams with correspondingly swapped
    (S, N) label tracks must score IDENTICALLY to the unswapped fleet — and
    NOT whatever comparing both nodes against track A would give (what the
    old shard body's ``preds == labels[:, None]`` did)."""
    key, wins, labels, harvest, kw = setup
    wins_b, labels_b = har_stream(jax.random.fold_in(key, 3), S)
    harvest2 = jnp.broadcast_to(harvest[:1], (2, S))   # same energy, 2 nodes

    streams = jnp.stack([wins, wins_b])                # node0=A, node1=B
    tracks = jnp.stack([labels, labels_b], axis=1)     # (S, 2)
    res = seeker_fleet_simulate(streams, harvest2, labels=tracks, **kw)

    swapped = seeker_fleet_simulate(
        streams[::-1], harvest2, labels=tracks[:, ::-1], **kw)
    # per-node scoring is permutation-equivariant: same counts either way
    assert int(res["correct"]) == int(swapped["correct"])
    assert int(res["completed"]) == int(swapped["completed"])

    # the OLD behaviour — both nodes scored against track A — differs:
    # recompute it from the traces and require the fixed engine NOT match it
    preds = np.asarray(res["preds"])
    sent = np.asarray(res["decisions"]) != DEFER
    old_correct = ((preds == np.asarray(labels)[:, None]) & sent).sum()
    new_correct = ((preds == np.asarray(tracks)) & sent).sum()
    assert int(res["correct"]) == new_correct
    assert new_correct != old_correct, \
        "fixture failed to distinguish the label tracks; change the seed"


# ---------------------------------------------------------------------------
# Streaming driver
# ---------------------------------------------------------------------------

def test_streamed_matches_one_long_run_bitwise(setup):
    """Acceptance: chunked segments through the resume contract == one long
    run, traces, counters and final keys, with churn + labels in play."""
    key, wins, labels, harvest, kw = setup
    alive = fleet_alive_traces(key, N, S, duty=0.7, period=4)
    full = seeker_fleet_simulate(wins, harvest, alive=alive, labels=labels,
                                 **kw)
    for chunk in (3, 5, S):          # divisible, ragged tail, single chunk
        stream = seeker_fleet_simulate_streamed(
            wins, harvest, chunk=chunk, alive=alive, labels=labels, **kw)
        for k in ("decisions", "payload_bytes", "stored_uj", "k_trace",
                  "logits", "preds"):
            np.testing.assert_array_equal(
                np.asarray(stream[k]), np.asarray(full[k]),
                err_msg=f"{k} (chunk={chunk})")
        np.testing.assert_array_equal(np.asarray(stream["final_keys"]),
                                      np.asarray(full["final_keys"]))
        np.testing.assert_array_equal(
            np.asarray(stream["final_state"].stored_uj),
            np.asarray(full["final_state"].stored_uj))
        for k in ("decision_histogram", "completed", "alive_slots",
                  "correct"):
            np.testing.assert_array_equal(np.asarray(stream[k]),
                                          np.asarray(full[k]), err_msg=k)
        assert stream["n_chunks"] == -(-S // chunk)
        np.testing.assert_allclose(float(stream["bytes_on_wire"]),
                                   float(full["bytes_on_wire"]), rtol=1e-6)


def test_streamed_accepts_window_callable(setup):
    """The point of streaming: the full (N, S, T, C) tensor never exists —
    a callable materializes one chunk at a time."""
    key, wins, labels, harvest, kw = setup
    wn = jnp.stack([wins + 0.01 * i for i in range(N)])   # (N, S, T, C)
    calls = []

    def window_fn(start, stop):
        calls.append((start, stop))
        return wn[:, start:stop]

    full = seeker_fleet_simulate(wn, harvest, **kw)
    stream = seeker_fleet_simulate_streamed(window_fn, harvest, chunk=4, **kw)
    assert calls == [(0, 4), (4, 8), (8, 12)]
    for k in ("decisions", "stored_uj", "logits"):
        np.testing.assert_array_equal(np.asarray(stream[k]),
                                      np.asarray(full[k]), err_msg=k)


def test_streamed_bad_chunk_raises(setup):
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match="chunk"):
        seeker_fleet_simulate_streamed(wins, harvest, chunk=0, **kw)
