"""Serving tests: LM engine + the Seeker edge-host system simulation."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR, SYSTEM
from repro.core import DEFER, harvest_trace
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.models.har import har_init
from repro.serving import generate, seeker_simulate

LM = ModelConfig(name="t", vocab=64, d_model=32, n_layers=2, n_heads=4,
                 n_kv=2, d_ff=64, dtype=jnp.float32)


def test_generate_shapes_and_determinism(key):
    params = init_params(key, LM)
    prompt = jax.random.randint(key, (2, 8), 0, 64)
    a = generate(params, LM, prompt, max_new=6)
    b = generate(params, LM, prompt, max_new=6)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.all((a >= 0) & (a < LM.padded_vocab)))


def test_generate_greedy_matches_incremental_forward(key):
    """Greedy generate == argmax over repeated full forward (the engine's
    cache path is exact)."""
    from repro.models import forward
    params = init_params(key, LM)
    prompt = jax.random.randint(key, (1, 8), 0, 64)
    gen = generate(params, LM, prompt, max_new=4)
    seq = prompt
    for t in range(4):
        logits = forward(params, LM, seq)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        assert int(nxt[0]) == int(gen[0, t])
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


@pytest.fixture(scope="module")
def seeker_setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    sigs = class_signatures()
    wins, labels = har_stream(key, 48)
    return key, params, gen, sigs, wins, labels


def test_seeker_simulation_invariants(seeker_setup):
    key, params, gen, sigs, wins, labels = seeker_setup
    res = seeker_simulate(wins, labels, harvest_trace(key, 48, "rf"),
                          signatures=sigs, qdnn_params=params,
                          host_params=params, gen_params=gen, har_cfg=HAR)
    # supercap never negative / above cap
    assert bool(jnp.all(res["stored_uj"] >= 0))
    assert bool(jnp.all(res["stored_uj"] <= 200.0))
    # payload always below raw transmission (the paper's whole point)
    assert bool(jnp.all(res["payload_bytes"] <= res["raw_bytes"]))
    # decisions in range
    assert bool(jnp.all((res["decisions"] >= 0) & (res["decisions"] <= DEFER)))
    assert 0.0 <= float(res["completed_frac"]) <= 1.0


def test_seeker_richer_harvest_completes_more(seeker_setup):
    key, params, gen, sigs, wins, labels = seeker_setup
    res_rf = seeker_simulate(wins, labels, harvest_trace(key, 48, "rf"),
                             signatures=sigs, qdnn_params=params,
                             host_params=params, gen_params=gen, har_cfg=HAR)
    res_solar = seeker_simulate(wins, labels, harvest_trace(key, 48, "solar"),
                                signatures=sigs, qdnn_params=params,
                                host_params=params, gen_params=gen,
                                har_cfg=HAR)
    assert (float(res_solar["completed_frac"])
            >= float(res_rf["completed_frac"]))


def test_seeker_communication_reduction(seeker_setup):
    """Mean payload is a large factor below raw bytes (paper: 8.9x with AAC;
    even without a trained AAC table the coreset wire format is >=5x)."""
    key, params, gen, sigs, wins, labels = seeker_setup
    res = seeker_simulate(wins, labels, harvest_trace(key, 48, "wifi"),
                          signatures=sigs, qdnn_params=params,
                          host_params=params, gen_params=gen, har_cfg=HAR)
    sent = res["decisions"] != DEFER
    mean_payload = float(jnp.sum(res["payload_bytes"] * sent)
                         / jnp.maximum(jnp.sum(sent), 1))
    assert mean_payload * 5 < 240.0, mean_payload
