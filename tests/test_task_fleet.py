"""Heterogeneous multi-workload fleets — the `task` lane (ISSUE 9).

One fleet mixes HAR wearables (task 0) and bearing-vibration monitors
(task 1) through the registered lane protocol:

* per-task aggregate splits (`completed_by_task`, `deadline_miss_by_task`,
  `correct_by_task` / `accuracy_by_task`) PARTITION the fleet totals;
* task-switched energy costs bite ONLY the scaled task — HAR nodes stay
  bitwise-identical to the task-less engine;
* per-node (S, N) label tracks score each node against its own task's
  stream; a shared (S,) track with per-node streams is rejected with an
  error that names the offending shapes and the accepted forms;
* ``per_task_host`` routes each node through its own stacked host weights
  without touching the other task's outputs;
* malformed ``tasks`` arrays fail loudly.

The streamed/chunked contract for this lane is swept (with every other
lane combination) by tests/test_resume_contract.py; the sharded psum-exact
contract lives in tests/test_fleet_sharded.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import fleet_harvest_traces
from repro.core.decision import DEFER
from repro.core.recovery import init_generator
from repro.data.sensors import bearing_stream, class_signatures, har_stream
from repro.models.har import har_init
from repro.serving import (TaskLaneConfig, seeker_fleet_simulate,
                           stack_task_params)

S, N = 10, 4


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    gen = init_generator(key, HAR.window, HAR.channels)
    wins, labels = har_stream(key, S)
    harvest = fleet_harvest_traces(key, N, S)
    kw = dict(signatures=class_signatures(), qdnn_params=params,
              host_params=params, gen_params=gen, har_cfg=HAR, key=key,
              donate=False)
    return key, wins, labels, harvest, kw


def _mixed_streams(key):
    """Per-node (N, S, T, C) streams: even nodes play HAR windows, odd nodes
    bearing vibration resampled to the shared (T, C) grid; (S, N) labels."""
    har_w, har_l = har_stream(key, S)
    brg_w, brg_l = bearing_stream(jax.random.fold_in(key, 11), S, t=HAR.window)
    brg_w = jnp.tile(brg_w, (1, 1, HAR.channels))        # (S, T, 1) -> (S, T, C)
    streams = jnp.stack([har_w if i % 2 == 0 else brg_w for i in range(N)])
    labels = jnp.stack([har_l if i % 2 == 0 else brg_l for i in range(N)],
                       axis=1)                           # (S, N)
    return streams, labels


def test_per_task_aggregates_partition_fleet_totals(setup):
    key, wins, labels, harvest, kw = setup
    res = seeker_fleet_simulate(wins, harvest, labels=labels,
                                task=TaskLaneConfig(), **kw)
    assert res["task_names"] == ("har", "bearing")
    comp = np.asarray(res["completed_by_task"])
    miss = np.asarray(res["deadline_miss_by_task"])
    corr = np.asarray(res["correct_by_task"])
    assert comp.shape == miss.shape == corr.shape == (2,)
    assert comp.sum() == int(res["completed"])
    assert corr.sum() == int(res["correct"])
    # every alive slot either completed or missed its deadline
    assert comp.sum() + miss.sum() == int(res["alive_slots"])
    acc = np.asarray(res["accuracy_by_task"])
    np.testing.assert_allclose(acc, corr / np.maximum(comp, 1), rtol=1e-6)
    # recompute the split from the traces
    tasks = np.asarray(res["tasks"])
    sent = (np.asarray(res["decisions"]) != DEFER) & np.asarray(res["alive"])
    for t in range(2):
        assert comp[t] == sent[:, tasks == t].sum()


def test_cost_scale_bites_scaled_task_only(setup):
    """Doubling task 1's energy costs changes bearing nodes' decisions and
    leaves every HAR node's traces BITWISE untouched — task identity is
    per-node, not fleet-global."""
    key, wins, labels, harvest, kw = setup
    base = seeker_fleet_simulate(wins, harvest, labels=labels, **kw)
    mixed = seeker_fleet_simulate(
        wins, harvest, labels=labels,
        task=TaskLaneConfig(cost_scale=(1.0, 2.0)), **kw)
    tasks = np.asarray(mixed["tasks"])
    har_nodes, brg_nodes = tasks == 0, tasks == 1
    for k in ("decisions", "stored_uj", "payload_bytes", "k_trace"):
        np.testing.assert_array_equal(
            np.asarray(mixed[k])[:, har_nodes],
            np.asarray(base[k])[:, har_nodes], err_msg=f"HAR {k}")
    assert (np.asarray(mixed["decisions"])[:, brg_nodes]
            != np.asarray(base["decisions"])[:, brg_nodes]).any(), \
        "cost_scale=2.0 never changed a bearing decision; weaken harvest"


def test_unit_cost_scale_is_bitwise_costless(setup):
    """A task lane with all-1.0 scales splits aggregates but cannot perturb
    a single trace bit."""
    key, wins, labels, harvest, kw = setup
    base = seeker_fleet_simulate(wins, harvest, labels=labels, **kw)
    res = seeker_fleet_simulate(
        wins, harvest, labels=labels,
        task=TaskLaneConfig(cost_scale=(1.0, 1.0)), **kw)
    for k in ("decisions", "stored_uj", "payload_bytes", "logits"):
        np.testing.assert_array_equal(np.asarray(res[k]),
                                      np.asarray(base[k]), err_msg=k)
    assert int(np.asarray(res["completed_by_task"]).sum()) \
        == int(base["completed"])


def test_per_node_label_tracks_score_each_task(setup):
    """Mixed streams + per-task (S, N) label tracks: correct_by_task equals
    scoring each node's preds against ITS OWN track."""
    key, wins, labels, harvest, kw = setup
    streams, tracks = _mixed_streams(key)
    res = seeker_fleet_simulate(streams, harvest, labels=tracks,
                                task=TaskLaneConfig(), **kw)
    sent = (np.asarray(res["decisions"]) != DEFER) & np.asarray(res["alive"])
    ok = np.asarray(res["preds"]) == np.asarray(tracks)
    tasks = np.asarray(res["tasks"])
    for t in range(2):
        want = (ok & sent)[:, tasks == t].sum()
        assert int(res["correct_by_task"][t]) == want, t


def test_mixed_fleet_shared_labels_raise_with_shapes(setup):
    """The satellite-6 negative: per-node streams + one shared (S,) label
    track is ambiguous, and the error names the offending shape AND both
    accepted forms so the fix is in the message."""
    key, wins, labels, harvest, kw = setup
    streams, _ = _mixed_streams(key)
    with pytest.raises(ValueError, match="ambiguous") as ei:
        seeker_fleet_simulate(streams, harvest, labels=labels,
                              task=TaskLaneConfig(), **kw)
    msg = str(ei.value)
    assert f"({S},)" in msg and f"({S}, {N})" in msg, msg
    assert "accepted forms" in msg, msg


def test_tasks_validation(setup):
    key, wins, labels, harvest, kw = setup
    with pytest.raises(ValueError, match=r"tasks must be \(N,\)"):
        seeker_fleet_simulate(wins, harvest,
                              tasks=jnp.zeros((N - 1,), jnp.int32), **kw)
    with pytest.raises(ValueError, match="declares 2 tasks"):
        seeker_fleet_simulate(wins, harvest,
                              tasks=jnp.full((N,), 5, jnp.int32),
                              task=TaskLaneConfig(), **kw)


def test_per_task_host_routes_stacked_weights(setup):
    """per_task_host: nodes of task 0 are bitwise-blind to what task 1's
    host weights are — each node infers through its own stacked tree."""
    key, wins, labels, harvest, kw = setup
    params_b = har_init(jax.random.fold_in(key, 21), HAR)
    cfg = TaskLaneConfig(per_task_host=True)
    kw_a = {k: v for k, v in kw.items() if k != "host_params"}
    same = seeker_fleet_simulate(
        wins, harvest, labels=labels, task=cfg,
        host_params=(kw["host_params"], kw["host_params"]), **kw_a)
    split = seeker_fleet_simulate(
        wins, harvest, labels=labels, task=cfg,
        host_params=(kw["host_params"], params_b), **kw_a)
    tasks = np.asarray(same["tasks"])
    np.testing.assert_array_equal(
        np.asarray(split["logits"])[:, tasks == 0],
        np.asarray(same["logits"])[:, tasks == 0])
    assert not np.array_equal(np.asarray(split["logits"])[:, tasks == 1],
                              np.asarray(same["logits"])[:, tasks == 1])
    # malformed: per_task_host demands one tree per task
    with pytest.raises(ValueError, match="per_task_host"):
        seeker_fleet_simulate(wins, harvest, labels=labels, task=cfg,
                              host_params=(kw["host_params"],), **kw_a)


def test_stack_task_params_shapes():
    key = jax.random.PRNGKey(0)
    a = har_init(key, HAR)
    b = har_init(jax.random.fold_in(key, 1), HAR)
    stacked = stack_task_params((a, b))
    la = jax.tree_util.tree_leaves(a)
    for leaf, ref in zip(jax.tree_util.tree_leaves(stacked), la):
        assert leaf.shape == (2,) + ref.shape
