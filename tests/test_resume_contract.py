"""The resume contract as a PROPERTY of the lane registry (ISSUE 9).

One harness over :data:`repro.serving.FLEET_LANES` instead of per-lane
copies (the brown-out and intermittent variants this file replaced lived in
tests/test_brownout.py / tests/test_intermittent.py):

* for EVERY combination of configurable lanes, the streamed chunked driver
  equals one long run bitwise — traces, counters, and every lane's declared
  ``resume_out`` state;
* lanes that are off emit their registered off-state (``lane=None`` is
  bitwise the lane-absent engine: empty brown-out lane, all-True alive
  lane, no intermittent/task keys at all);
* the telemetry lane is a pure observer: adding it to any combination
  changes no other output bit;
* spelling every lane kwarg out as ``None`` is bitwise identical to never
  mentioning them.

The combinations and the keys compared are DERIVED from the registry
(``config_kwarg``, ``trace_keys``, ``counter_keys``, ``resume_out``), so a
new registered lane is swept here without editing this file — the
conformance companion (tests/test_lane_conformance.py) fails if a lane
skips the declarations this harness relies on.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.seeker_har import HAR
from repro.core import (BrownoutConfig, IntermittentConfig,
                        fleet_alive_traces, fleet_harvest_traces)
from repro.core.recovery import init_generator
from repro.data.sensors import class_signatures, har_stream
from repro.models.har import har_aux_init, har_init
from repro.serving import (FLEET_LANES, TaskLaneConfig,
                           seeker_fleet_simulate,
                           seeker_fleet_simulate_streamed)
from repro.serving.fleet import _active_lanes
from repro.serving.fleet_lanes import fleet_counter_keys, fleet_trace_keys

S, N, CHUNK = 6, 3, 2
SCARCITY = 0.04           # scarce enough that brown-outs and DEFERs happen
BO = BrownoutConfig(off_uj=8.0, restart_uj=28.0)
IT = IntermittentConfig()
TASK = TaskLaneConfig()

CONFIGURABLE = tuple(ln.name for ln in FLEET_LANES
                     if ln.config_kwarg is not None)
COMBOS = [frozenset(c) for r in range(len(CONFIGURABLE) + 1)
          for c in itertools.combinations(CONFIGURABLE, r)]


def _combo_id(combo):
    return "+".join(sorted(combo)) or "none"


@pytest.fixture(scope="module")
def ctx():
    key = jax.random.PRNGKey(0)
    params = har_init(key, HAR)
    wins, labels = har_stream(key, S)
    return dict(
        key=key, wins=wins, labels=labels,
        harvest=fleet_harvest_traces(key, N, S) * SCARCITY,
        alive=fleet_alive_traces(jax.random.fold_in(key, 3), N, S, duty=0.8),
        aux=har_aux_init(jax.random.fold_in(key, 7), HAR),
        kw=dict(signatures=class_signatures(), qdnn_params=params,
                host_params=params,
                gen_params=init_generator(key, HAR.window, HAR.channels),
                har_cfg=HAR, key=key, donate=False, initial_uj=12.0))


_MEMO: dict = {}


def _combo_kw(ctx, combo):
    kw = dict(ctx["kw"], labels=ctx["labels"])
    if "churn" in combo:
        kw["alive"] = ctx["alive"]
    if "brownout" in combo:
        kw["brownout"] = BO
    if "intermittent" in combo:
        kw.update(intermittent=IT, aux_params=ctx["aux"])
    if "telemetry" in combo:
        kw["telemetry"] = True
    if "task" in combo:
        kw["task"] = TASK
    return kw


def _run(ctx, combo):
    if combo not in _MEMO:
        kw = _combo_kw(ctx, combo)
        full = seeker_fleet_simulate(ctx["wins"], ctx["harvest"], **kw)
        streamed = seeker_fleet_simulate_streamed(
            ctx["wins"], ctx["harvest"], chunk=CHUNK, **kw)
        _MEMO[combo] = (full, streamed)
    return _MEMO[combo]


def _active(combo):
    return _active_lanes(IT if "intermittent" in combo else None,
                         TASK if "task" in combo else None,
                         BO if "brownout" in combo else None)


def _lane_on(ln, combo):
    """Is this registered lane enabled for this kwarg combo (always-on
    lanes and always-emitting output lanes included)?"""
    return (ln.config_kwarg is None or ln.outputs_when_off
            or ln.name in combo)


def _is_static(v):
    """Non-array metadata (e.g. ``task_names``) — compared by ``==``;
    NamedTuple carries are pytrees, not metadata, despite being tuples."""
    return isinstance(v, (int, float, str)) or (
        isinstance(v, tuple) and all(isinstance(x, str) for x in v))


def _assert_tree_equal(a, b, msg):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("combo", COMBOS, ids=_combo_id)
def test_streamed_chunks_equal_one_long_run(ctx, combo):
    """Registry sweep of the contract: chunked streaming == one long run,
    bitwise, for every lane combination — the keys compared are the lanes'
    own trace/counter/resume declarations."""
    full, streamed = _run(ctx, combo)
    active = _active(combo)
    for k in fleet_trace_keys(active):
        np.testing.assert_array_equal(
            np.asarray(streamed[k]), np.asarray(full[k]),
            err_msg=f"trace {k} [{_combo_id(combo)}]")
    for k in fleet_counter_keys(active):
        if k in full:
            assert np.array_equal(np.asarray(streamed[k]),
                                  np.asarray(full[k])), \
                f"counter {k} [{_combo_id(combo)}]"
    for ln in FLEET_LANES:
        if not _lane_on(ln, combo):
            continue
        for k in ln.resume_out:
            if k in full or k in streamed:
                _assert_tree_equal(full[k], streamed[k],
                                   f"{ln.name}.{k} [{_combo_id(combo)}]")


@pytest.mark.parametrize(
    "combo", [c for c in COMBOS if "telemetry" not in c], ids=_combo_id)
def test_telemetry_lane_is_pure_observer(ctx, combo):
    """Folding the metrics carry into any combination changes nothing else:
    every non-telemetry output of the telemetered run is bitwise the bare
    run's."""
    bare, _ = _run(ctx, combo)
    tel, _ = _run(ctx, combo | {"telemetry"})
    assert "telemetry" in tel and "telemetry" not in bare
    for k, v in bare.items():
        if _is_static(v):
            assert tel[k] == v, k
        else:
            _assert_tree_equal(v, tel[k], f"{k} perturbed by telemetry")


@pytest.mark.parametrize("combo", COMBOS, ids=_combo_id)
def test_off_lanes_emit_registered_off_state(ctx, combo):
    """A lane that is off is ABSENT, not zeroed: no traces, no counters, no
    resume keys — except the always-on output lanes (alive, brownout),
    which emit their registered inert values."""
    full, _ = _run(ctx, combo)
    if "brownout" not in combo:
        assert not bool(np.any(np.asarray(full["brownout"])))
        assert int(full["brownout_slots"]) == 0
        assert int(full["brownout_events"]) == 0
        if "churn" not in combo:
            assert bool(np.all(np.asarray(full["alive"])))
    for ln in FLEET_LANES:
        if _lane_on(ln, combo):
            continue
        for k in (*ln.trace_keys, *ln.counter_keys, *ln.aggregates,
                  *ln.resume_out):
            assert k not in full, \
                f"off lane {ln.name} leaked key {k} [{_combo_id(combo)}]"


def test_explicit_none_kwargs_equal_absent(ctx):
    """``lane=None`` spelled out for every registered lane is bitwise the
    run that never heard of any of them."""
    kw = dict(ctx["kw"], labels=ctx["labels"])
    a = seeker_fleet_simulate(ctx["wins"], ctx["harvest"], **kw)
    b = seeker_fleet_simulate(
        ctx["wins"], ctx["harvest"], alive=None, brownout=None,
        brownout_state0=None, intermittent=None, intermittent_state0=None,
        aux_params=None, tasks=None, task=None, telemetry=None,
        telemetry_state0=None, **kw)
    assert set(a) == set(b)
    for k, v in a.items():
        if _is_static(v):
            assert b[k] == v, k
        else:
            _assert_tree_equal(v, b[k], k)


def test_cross_segment_emission_rescored_bitwise(ctx):
    """The hard path of the streamed contract: an inference SUSPENDED in one
    segment and emitted in a later one must keep its globally indexed source
    slot, and the driver's cross-segment accuracy rescore (``correct``,
    ``correct_by_task``) must still equal the long run exactly.  Uses a
    longer scarce trace than the sweep so the regime provably crosses a
    boundary."""
    s2, chunk = 18, 3
    key = ctx["key"]
    wins, labels = har_stream(key, s2)
    harvest = fleet_harvest_traces(key, N, s2) * SCARCITY
    kw = dict(ctx["kw"], labels=labels, brownout=BO, intermittent=IT,
              aux_params=ctx["aux"], task=TASK)
    full = seeker_fleet_simulate(wins, harvest, **kw)
    streamed = seeker_fleet_simulate_streamed(wins, harvest, chunk=chunk,
                                              **kw)
    emit = np.asarray(streamed["it_emit"])
    src = np.asarray(streamed["it_src"])
    slots = np.arange(s2)[:, None]
    assert int(streamed["brownout_slots"]) > 0, "fixture must brown out"
    assert ((emit > 0) & (src // chunk < slots // chunk)).any(), \
        "no emission crossed a segment boundary — weaken the harvest"
    for k in ("decisions", "it_emit", "it_src", "it_label", "stored_uj"):
        np.testing.assert_array_equal(np.asarray(streamed[k]),
                                      np.asarray(full[k]), err_msg=k)
    for k in ("correct", "correct_ladder", "it_correct_full",
              "it_correct_early", "completed"):
        assert int(streamed[k]) == int(full[k]), k
    np.testing.assert_array_equal(np.asarray(streamed["correct_by_task"]),
                                  np.asarray(full["correct_by_task"]))
    np.testing.assert_array_equal(
        np.asarray(streamed["completed_by_task"]),
        np.asarray(full["completed_by_task"]))
